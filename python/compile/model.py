"""L2: the split-trainable JAX model.

A compact CNN classifier over 16x16x3 synthetic images with **four stages**
and three legal cut points between them, mirroring the paper's device/server
split (Sec. III-A):

    stage 0: conv3x3(16) stride 1 + relu          -> (B,16,16,16)
    stage 1: conv3x3(32) stride 2 + relu          -> (B, 8, 8,32)
    stage 2: flatten + dense(64) + relu           -> (B,64)
    stage 3: dense(10) logits + softmax xent loss

Every conv is im2col + the L1 Pallas matmul kernel, so the whole fwd/bwd
graph lowers through the kernel. For each cut k in {1,2,3} the AOT compiler
(aot.py) exports three functions, which is exactly what the rust runtime
executes per local iteration:

    dev_fwd_k  (x, dev_params)                    -> smashed
    srv_step_k (smashed, labels, srv_params, lr)  -> loss, d_smashed, new_srv_params
    dev_bwd_k  (x, dev_params, d_smashed, lr)     -> new_dev_params

plus `full_step` (the central baseline: everything on the server) and
`predict` for evaluation. SGD is applied inside the step functions so the
rust hot path never touches Python.
"""

from typing import List, Tuple

import jax
import jax.numpy as jnp

from compile.kernels.matmul import matmul

# Fixed compile-time geometry (PJRT executables are shape-specialized).
BATCH = 32
IMG = 16
CHANNELS = 3
NUM_CLASSES = 10
STAGES = 4
CUTS = (1, 2, 3)  # legal cut points: device runs stages [0, k)


def im2col(x, kh: int, kw: int, stride: int):
    """NHWC -> (B*OH*OW, KH*KW*C) patch matrix with SAME padding.

    Static Python loops over the (small) kernel window produce slice ops
    only, which the PJRT CPU backend of xla_extension 0.5.1 handles.
    """
    b, h, w, c = x.shape
    ph, pw = kh // 2, kw // 2
    xp = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
    oh = (h + 2 * ph - kh) // stride + 1
    ow = (w + 2 * pw - kw) // stride + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            patch = xp[:, i : i + (oh - 1) * stride + 1 : stride,
                       j : j + (ow - 1) * stride + 1 : stride, :]
            cols.append(patch)
    stacked = jnp.concatenate(cols, axis=-1)  # (B, OH, OW, KH*KW*C)
    return stacked.reshape(b * oh * ow, kh * kw * c), (b, oh, ow)


def conv2d(x, w, b, stride: int):
    """SAME conv as im2col + Pallas matmul. w: (KH,KW,C,O), b: (O,)."""
    kh, kw, c, o = w.shape
    cols, (bsz, oh, ow) = im2col(x, kh, kw, stride)
    out = matmul(cols, w.reshape(kh * kw * c, o)) + b
    return out.reshape(bsz, oh, ow, o)


def dense(x, w, b):
    """Dense layer on the Pallas matmul."""
    return matmul(x, w) + b


# --------------------------------------------------------------------------
# Parameters. Flat list of arrays; stage s owns params[PARAM_SLICES[s]].
# --------------------------------------------------------------------------

PARAM_SHAPES: List[Tuple[int, ...]] = [
    (3, 3, CHANNELS, 16), (16,),        # stage 0 conv
    (3, 3, 16, 32), (32,),              # stage 1 conv
    (8 * 8 * 32, 64), (64,),            # stage 2 dense
    (64, NUM_CLASSES), (NUM_CLASSES,),  # stage 3 dense
]
PARAM_SLICES = [slice(0, 2), slice(2, 4), slice(4, 6), slice(6, 8)]


def init_params(seed: int = 0):
    """He-style init, deterministic in `seed`."""
    key = jax.random.PRNGKey(seed)
    params = []
    for shape in PARAM_SHAPES:
        key, sub = jax.random.split(key)
        if len(shape) == 1:
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = 1
            for d in shape[:-1]:
                fan_in *= d
            scale = jnp.sqrt(2.0 / fan_in)
            params.append(scale * jax.random.normal(sub, shape, jnp.float32))
    return params


def stage_apply(s: int, x, stage_params):
    """Run stage `s` on activation `x`."""
    if s == 0:
        w, b = stage_params
        return jax.nn.relu(conv2d(x, w, b, stride=1))
    if s == 1:
        w, b = stage_params
        return jax.nn.relu(conv2d(x, w, b, stride=2))
    if s == 2:
        w, b = stage_params
        flat = x.reshape(x.shape[0], -1)
        return jax.nn.relu(dense(flat, w, b))
    if s == 3:
        w, b = stage_params
        return dense(x, w, b)  # logits
    raise ValueError(f"no stage {s}")


def smashed_shape(cut: int) -> Tuple[int, ...]:
    """Activation shape crossing the wire for a given cut."""
    return {
        1: (BATCH, IMG, IMG, 16),
        2: (BATCH, IMG // 2, IMG // 2, 32),
        3: (BATCH, 64),
    }[cut]


def forward_range(x, params, start: int, stop: int):
    """Apply stages [start, stop)."""
    for s in range(start, stop):
        x = stage_apply(s, x, params[PARAM_SLICES[s]])
    return x


def loss_from_logits(logits, labels):
    """Mean softmax cross-entropy; labels are int32 class ids."""
    onehot = (labels[:, None] == jnp.arange(NUM_CLASSES)[None, :]).astype(jnp.float32)
    shifted = logits - jax.lax.stop_gradient(logits.max(axis=-1, keepdims=True))
    logz = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1, keepdims=True))
    return -jnp.mean(jnp.sum(onehot * (shifted - logz), axis=-1))


# --------------------------------------------------------------------------
# The three split functions per cut + the central step.
# --------------------------------------------------------------------------

def dev_params_of(params, cut: int):
    return params[: 2 * cut]


def srv_params_of(params, cut: int):
    return params[2 * cut :]


def dev_fwd(cut: int):
    """(x, *dev_params) -> smashed activation."""

    def f(x, *dev_params):
        return (forward_range(x, list(dev_params), 0, cut),)

    return f


def srv_step(cut: int):
    """(smashed, labels, lr, *srv_params) -> (loss, d_smashed, *new_srv)."""

    def f(smashed, labels, lr, *srv_params):
        def server_loss(smashed_in, srv):
            # Reconstruct a full param list view for forward_range.
            full = [None] * (2 * cut) + list(srv)
            logits = forward_range(smashed_in, full, cut, STAGES)
            return loss_from_logits(logits, labels)

        (loss, (d_smashed, d_srv)) = jax.value_and_grad(
            server_loss, argnums=(0, 1)
        )(smashed, list(srv_params))
        new_srv = [p - lr * g for p, g in zip(srv_params, d_srv)]
        return (loss, d_smashed, *new_srv)

    return f


def dev_bwd(cut: int):
    """(x, d_smashed, lr, *dev_params) -> (*new_dev_params,).

    Recomputes the device forward (standard SL: the device kept its
    activations; re-running the forward inside one fused artifact is the
    AOT-friendly equivalent) and applies the chain rule with the gradient
    received from the server.
    """

    def f(x, d_smashed, lr, *dev_params):
        def device_fwd(dev):
            return forward_range(x, list(dev), 0, cut)

        _, vjp = jax.vjp(device_fwd, list(dev_params))
        (d_dev,) = vjp(d_smashed)
        return tuple(p - lr * g for p, g in zip(dev_params, d_dev))

    return f


def full_step():
    """Central baseline: (x, labels, lr, *params) -> (loss, *new_params)."""

    def f(x, labels, lr, *params):
        def total_loss(ps):
            logits = forward_range(x, list(ps), 0, STAGES)
            return loss_from_logits(logits, labels)

        loss, grads = jax.value_and_grad(total_loss)(list(params))
        new = [p - lr * g for p, g in zip(params, grads)]
        return (loss, *new)

    return f


def predict():
    """(x, *params) -> logits (for accuracy evaluation)."""

    def f(x, *params):
        return (forward_range(x, list(params), 0, STAGES),)

    return f
