"""L1: Pallas tiled matmul — the compute hot-spot of every dense layer and
(via im2col) every convolution in the L2 split model.

TPU adaptation of the paper's GPU kernels (DESIGN.md §Hardware-Adaptation):
the HBM<->VMEM schedule is expressed with a (M/bm, N/bn, K/bk) grid and
BlockSpecs; the MXU sees bm x bk @ bk x bn tiles with an accumulator kept in
the output ref across the K grid dimension (standard Pallas matmul idiom in
place of CUDA threadblock tiling).

Must run with interpret=True: real TPU lowering emits a Mosaic custom-call
the CPU PJRT plugin cannot execute. Gradients are provided via custom_vjp
whose backward pass is also expressed as Pallas matmuls, so the entire
fwd+bwd graph lowers through this kernel.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default VMEM-friendly tile sizes. Three f32 tiles of 128x128 occupy
# 3 * 64 KiB = 192 KiB, far below the ~16 MiB VMEM budget; see
# DESIGN.md §Perf for the roofline estimate.
BLOCK_M = 128
BLOCK_N = 128
BLOCK_K = 128


def _matmul_kernel(x_ref, y_ref, o_ref):
    """One (bm, bn) output tile; accumulates over the K grid dimension."""

    @pl.when(pl.program_id(2) == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def _ceil_to(x: int, b: int) -> int:
    return (x + b - 1) // b * b


def _matmul_padded(x, y, bm, bn, bk):
    """Pallas matmul over inputs already padded to block multiples."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"inner dims {k} != {k2}"
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)


def _matmul_impl(x, y, bm=BLOCK_M, bn=BLOCK_N, bk=BLOCK_K):
    """Pad-to-block wrapper so arbitrary shapes hit the tiled kernel."""
    m, k = x.shape
    _, n = y.shape
    bm = min(bm, _ceil_to(m, 8))
    bn = min(bn, _ceil_to(n, 8))
    bk = min(bk, _ceil_to(k, 8))
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    xp = jnp.pad(x.astype(jnp.float32), ((0, mp - m), (0, kp - k)))
    yp = jnp.pad(y.astype(jnp.float32), ((0, kp - k), (0, np_ - n)))
    return _matmul_padded(xp, yp, bm, bn, bk)[:m, :n]


@jax.custom_vjp
def matmul(x, y):
    """`x @ y` computed by the Pallas kernel, differentiable.

    The VJP is expressed with the same kernel:
    dx = g @ y^T, dy = x^T @ g.
    """
    return _matmul_impl(x, y)


def _matmul_fwd(x, y):
    return _matmul_impl(x, y), (x, y)


def _matmul_bwd(res, g):
    x, y = res
    dx = _matmul_impl(g, y.T)
    dy = _matmul_impl(x.T, g)
    return dx, dy


matmul.defvjp(_matmul_fwd, _matmul_bwd)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul_jit(x, y, bm=BLOCK_M, bn=BLOCK_N, bk=BLOCK_K):
    """Jitted non-differentiable entry point (micro-bench / tests)."""
    return _matmul_impl(x, y, bm, bn, bk)
