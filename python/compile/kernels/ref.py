"""Pure-jnp oracles for the Pallas kernels and the model building blocks.

These are the correctness ground truth: pytest sweeps shapes/dtypes with
hypothesis and asserts the Pallas kernel (and the im2col convolution built
on it) match these references to float32 tolerance.
"""

import jax.numpy as jnp
from jax import lax


def matmul_ref(x, y):
    """Reference for kernels.matmul: plain jnp.dot in f32."""
    return jnp.dot(x.astype(jnp.float32), y.astype(jnp.float32))


def conv2d_ref(x_nhwc, w_hwio, stride: int):
    """Reference NHWC conv via lax.conv_general_dilated with the model's
    symmetric k//2 padding (XLA's "SAME" pads asymmetrically for strided
    even-size inputs; the model defines symmetric padding instead)."""
    kh, kw = w_hwio.shape[0], w_hwio.shape[1]
    return lax.conv_general_dilated(
        x_nhwc.astype(jnp.float32),
        w_hwio.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=((kh // 2, kh // 2), (kw // 2, kw // 2)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def dense_ref(x, w, b):
    """Reference dense layer."""
    return matmul_ref(x, w) + b


def softmax_xent_ref(logits, labels_onehot):
    """Reference mean softmax cross-entropy."""
    logz = jnp.log(jnp.sum(jnp.exp(logits - logits.max(axis=-1, keepdims=True)), axis=-1))
    logp = logits - logits.max(axis=-1, keepdims=True) - logz[..., None]
    return -jnp.mean(jnp.sum(labels_onehot * logp, axis=-1))
