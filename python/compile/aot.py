"""AOT compiler: lower every split artifact of model.py to HLO **text** and
write artifacts/manifest.json describing shapes for the rust runtime.

HLO text (never `.serialize()`): jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids, which xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`).
The text parser reassigns ids, so text round-trips cleanly (see
/opt/xla-example/README.md).
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from compile import model  # noqa: E402


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation (tupled results) -> HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def param_specs(params):
    return [spec(p.shape) for p in params]


def shapes_json(specs):
    return [{"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs]


def build_artifacts(out_dir: str, verbose: bool = True):
    os.makedirs(out_dir, exist_ok=True)
    params = model.init_params(0)
    x_spec = spec((model.BATCH, model.IMG, model.IMG, model.CHANNELS))
    labels_spec = spec((model.BATCH,), jnp.int32)
    lr_spec = spec(())

    manifest = {
        "batch": model.BATCH,
        "img": model.IMG,
        "channels": model.CHANNELS,
        "num_classes": model.NUM_CLASSES,
        "stages": model.STAGES,
        "cuts": list(model.CUTS),
        "param_shapes": [list(s) for s in model.PARAM_SHAPES],
        "artifacts": {},
    }

    def emit(name, fn, in_specs):
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": shapes_json(in_specs),
        }
        if verbose:
            print(f"  wrote {path} ({len(text)} chars)")

    for cut in model.CUTS:
        dev = model.dev_params_of(params, cut)
        srv = model.srv_params_of(params, cut)
        smash = spec(model.smashed_shape(cut))
        emit(f"dev_fwd_cut{cut}", model.dev_fwd(cut), [x_spec, *param_specs(dev)])
        emit(
            f"srv_step_cut{cut}",
            model.srv_step(cut),
            [smash, labels_spec, lr_spec, *param_specs(srv)],
        )
        emit(
            f"dev_bwd_cut{cut}",
            model.dev_bwd(cut),
            [x_spec, smash, lr_spec, *param_specs(dev)],
        )

    emit("full_step", model.full_step(), [x_spec, labels_spec, lr_spec, *param_specs(params)])
    emit("predict", model.predict(), [x_spec, *param_specs(params)])

    # Initial parameter values ship as JSON so the rust side needs no numpy.
    init = [p.tolist() for p in model.init_params(0)]
    with open(os.path.join(out_dir, "init_params.json"), "w") as f:
        json.dump(init, f)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    if verbose:
        print(f"  wrote {out_dir}/manifest.json + init_params.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="output directory")
    args = ap.parse_args()
    build_artifacts(args.out)


if __name__ == "__main__":
    main()
