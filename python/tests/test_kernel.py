"""L1 correctness: Pallas matmul vs the pure-jnp oracle, across
hypothesis-swept shapes and dtypes, plus gradient checks of the custom_vjp.
This is the core correctness signal for the kernel layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.matmul import matmul, matmul_jit
from compile.kernels import ref


def rand(shape, seed, dtype=np.float32):
    rng = np.random.RandomState(seed)
    return rng.uniform(-1, 1, size=shape).astype(dtype)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 70),
    seed=st.integers(0, 2**16),
)
def test_matmul_matches_ref_swept_shapes(m, k, n, seed):
    x = rand((m, k), seed)
    y = rand((k, n), seed + 1)
    got = np.asarray(matmul(x, y))
    want = np.asarray(ref.matmul_ref(x, y))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_matmul_block_boundary_shapes(seed):
    # Shapes straddling the 128 tile boundary exercise the padding path.
    for m, k, n in [(128, 128, 128), (129, 127, 130), (1, 128, 1), (257, 5, 64)]:
        x = rand((m, k), seed)
        y = rand((k, n), seed + 7)
        np.testing.assert_allclose(
            np.asarray(matmul(x, y)),
            np.asarray(ref.matmul_ref(x, y)),
            rtol=1e-5,
            atol=1e-5,
        )


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_matmul_dtypes(dtype):
    x = rand((33, 17), 3, dtype)
    y = rand((17, 29), 4, dtype)
    got = np.asarray(matmul(jnp.asarray(x), jnp.asarray(y)))
    want = np.asarray(ref.matmul_ref(x, y))
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(2, 40),
    k=st.integers(2, 40),
    n=st.integers(2, 40),
    seed=st.integers(0, 2**16),
)
def test_matmul_gradients_match_ref(m, k, n, seed):
    x = jnp.asarray(rand((m, k), seed))
    y = jnp.asarray(rand((k, n), seed + 1))

    def f_kernel(x, y):
        return jnp.sum(jnp.sin(matmul(x, y)))

    def f_ref(x, y):
        return jnp.sum(jnp.sin(ref.matmul_ref(x, y)))

    gx_k, gy_k = jax.grad(f_kernel, argnums=(0, 1))(x, y)
    gx_r, gy_r = jax.grad(f_ref, argnums=(0, 1))(x, y)
    np.testing.assert_allclose(np.asarray(gx_k), np.asarray(gx_r), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gy_k), np.asarray(gy_r), rtol=1e-4, atol=1e-5)


def test_matmul_jit_custom_blocks():
    x = rand((64, 48), 9)
    y = rand((48, 96), 10)
    got = np.asarray(matmul_jit(x, y, bm=32, bn=32, bk=16))
    np.testing.assert_allclose(got, np.asarray(ref.matmul_ref(x, y)), rtol=1e-5, atol=1e-5)


def test_matmul_zero_and_identity():
    x = rand((16, 16), 11)
    eye = np.eye(16, dtype=np.float32)
    np.testing.assert_allclose(np.asarray(matmul(x, eye)), x, rtol=1e-6, atol=1e-6)
    zero = np.zeros((16, 16), np.float32)
    np.testing.assert_allclose(np.asarray(matmul(x, zero)), zero, atol=0)
