"""L2 correctness: conv-as-im2col vs lax.conv, stage shapes, loss
sanity, and the split-consistency invariant — running (dev_fwd, srv_step,
dev_bwd) at any cut must produce exactly the same loss and updated
parameters as the monolithic full_step.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def rand(shape, seed):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.uniform(-1, 1, size=shape).astype(np.float32))


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 4),
    hw=st.sampled_from([4, 6, 8]),
    cin=st.integers(1, 4),
    cout=st.integers(1, 6),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**16),
)
def test_conv2d_matches_lax(b, hw, cin, cout, stride, seed):
    x = rand((b, hw, hw, cin), seed)
    w = rand((3, 3, cin, cout), seed + 1)
    bias = rand((cout,), seed + 2)
    got = model.conv2d(x, w, bias, stride)
    want = ref.conv2d_ref(x, w, stride) + bias
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_stage_shapes():
    params = model.init_params(0)
    x = rand((model.BATCH, model.IMG, model.IMG, model.CHANNELS), 0)
    for cut in model.CUTS:
        smashed = model.forward_range(x, params, 0, cut)
        assert smashed.shape == model.smashed_shape(cut), f"cut={cut}"
    logits = model.forward_range(x, params, 0, model.STAGES)
    assert logits.shape == (model.BATCH, model.NUM_CLASSES)


def test_loss_sanity():
    logits = jnp.zeros((8, model.NUM_CLASSES))
    labels = jnp.arange(8, dtype=jnp.int32) % model.NUM_CLASSES
    loss = model.loss_from_logits(logits, labels)
    np.testing.assert_allclose(float(loss), np.log(model.NUM_CLASSES), rtol=1e-6)


def test_loss_matches_reference_oracle():
    logits = rand((16, model.NUM_CLASSES), 5)
    labels = jnp.asarray(np.random.RandomState(6).randint(0, 10, size=16), jnp.int32)
    onehot = jax.nn.one_hot(labels, model.NUM_CLASSES)
    np.testing.assert_allclose(
        float(model.loss_from_logits(logits, labels)),
        float(ref.softmax_xent_ref(logits, onehot)),
        rtol=1e-5,
    )


@pytest.mark.parametrize("cut", model.CUTS)
def test_split_equals_full_step(cut):
    """The paper's SL invariant: splitting must not change the math."""
    params = model.init_params(3)
    x = rand((model.BATCH, model.IMG, model.IMG, model.CHANNELS), 7)
    labels = jnp.asarray(
        np.random.RandomState(8).randint(0, model.NUM_CLASSES, size=model.BATCH),
        jnp.int32,
    )
    lr = jnp.float32(0.05)

    # Monolithic step.
    full_out = model.full_step()(x, labels, lr, *params)
    loss_full, new_full = full_out[0], list(full_out[1:])

    # Split step.
    dev = model.dev_params_of(params, cut)
    srv = model.srv_params_of(params, cut)
    (smashed,) = model.dev_fwd(cut)(x, *dev)
    srv_out = model.srv_step(cut)(smashed, labels, lr, *srv)
    loss_split, d_smashed, new_srv = srv_out[0], srv_out[1], list(srv_out[2:])
    new_dev = list(model.dev_bwd(cut)(x, d_smashed, lr, *dev))

    np.testing.assert_allclose(float(loss_split), float(loss_full), rtol=1e-5)
    recombined = new_dev + new_srv
    assert len(recombined) == len(new_full)
    for i, (a, b) in enumerate(zip(recombined, new_full)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5, err_msg=f"param {i}"
        )


def test_training_reduces_loss():
    """A few full steps on a learnable synthetic task reduce the loss."""
    params = model.init_params(1)
    rng = np.random.RandomState(0)
    proj = rng.randn(model.IMG * model.IMG * model.CHANNELS, model.NUM_CLASSES)
    x = rng.uniform(-1, 1, size=(model.BATCH, model.IMG, model.IMG, model.CHANNELS))
    y = np.argmax(x.reshape(model.BATCH, -1) @ proj, axis=1).astype(np.int32)
    x, y = jnp.asarray(x, jnp.float32), jnp.asarray(y)

    step = jax.jit(model.full_step())
    lr = jnp.float32(0.1)
    first = None
    loss = None
    for _ in range(15):
        out = step(x, y, lr, *params)
        loss, params = float(out[0]), list(out[1:])
        first = first if first is not None else loss
    assert loss < first * 0.8, f"loss {first} -> {loss}"
