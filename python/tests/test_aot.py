"""AOT pipeline checks: every artifact lowers to parseable HLO text with an
ENTRY computation, the manifest covers all cuts, and the HLO text contains
no Mosaic custom-calls (which the CPU PJRT plugin could not run — the
Pallas kernel must have lowered through interpret=True).
"""

import json
import os
import tempfile

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    aot.build_artifacts(out, verbose=False)
    return out


def test_manifest_covers_all_cuts(built):
    with open(os.path.join(built, "manifest.json")) as f:
        manifest = json.load(f)
    names = set(manifest["artifacts"])
    for cut in model.CUTS:
        for prefix in ("dev_fwd", "srv_step", "dev_bwd"):
            assert f"{prefix}_cut{cut}" in names
    assert "full_step" in names
    assert "predict" in names
    assert manifest["batch"] == model.BATCH


def test_hlo_text_is_wellformed(built):
    with open(os.path.join(built, "manifest.json")) as f:
        manifest = json.load(f)
    for name, info in manifest["artifacts"].items():
        path = os.path.join(built, info["file"])
        with open(path) as f:
            text = f.read()
        assert "ENTRY" in text, name
        assert "HloModule" in text, name
        # interpret=True must have eliminated Mosaic custom-calls.
        assert "tpu_custom_call" not in text, name
        assert "mosaic" not in text.lower(), name


def test_input_shapes_recorded(built):
    with open(os.path.join(built, "manifest.json")) as f:
        manifest = json.load(f)
    fwd1 = manifest["artifacts"]["dev_fwd_cut1"]["inputs"]
    assert fwd1[0]["shape"] == [model.BATCH, model.IMG, model.IMG, model.CHANNELS]
    srv1 = manifest["artifacts"]["srv_step_cut1"]["inputs"]
    assert srv1[0]["shape"] == list(model.smashed_shape(1))
    assert srv1[1]["dtype"] == "int32"


def test_init_params_match_declared_shapes(built):
    with open(os.path.join(built, "init_params.json")) as f:
        init = json.load(f)
    assert len(init) == len(model.PARAM_SHAPES)

    def shape_of(x):
        s = []
        while isinstance(x, list):
            s.append(len(x))
            x = x[0]
        return tuple(s)

    for val, shape in zip(init, model.PARAM_SHAPES):
        assert shape_of(val) == tuple(shape)
