//! Quickstart: partition a model for split learning in ~20 lines.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use fastsplit::models;
use fastsplit::partition::{blockwise_partition, general_partition, Link, Problem};
use fastsplit::profiles::{CostGraph, DeviceProfile, TrainCfg};
use fastsplit::sim::DelayBreakdown;
use fastsplit::util::fmt_secs;

fn main() {
    // 1. Pick a model from the zoo (or build your own layer graph).
    let model = models::by_name("resnet18").unwrap();
    println!(
        "model: {} ({} layers, {:.1} GFLOPs)",
        model.name(),
        model.len(),
        model.total_flops() as f64 / 1e9
    );

    // 2. Derive per-layer costs for a device/server pair and batch config.
    let costs = CostGraph::build(
        &model,
        &DeviceProfile::jetson_tx2(),
        &DeviceProfile::rtx_a6000(),
        &TrainCfg {
            batch: 32,
            n_loc: 10,
            bwd_ratio: 2.0,
        },
    );

    // 3. Describe the wireless link (bytes/s) and solve.
    let link = Link {
        up_bps: 25e6 / 8.0,   // 25 Mbit/s uplink
        down_bps: 120e6 / 8.0, // 120 Mbit/s downlink
    };
    let problem = Problem::new(&costs, link);

    let general = general_partition(&problem);
    let blockwise = blockwise_partition(&problem);
    println!("general    : {}", general.describe());
    println!("block-wise : {}", blockwise.describe());
    assert!((general.delay - blockwise.delay).abs() < 1e-9 * general.delay.max(1.0));

    // 4. Inspect where the time goes (Eq. (7) decomposition).
    let b = DelayBreakdown::of(&problem, &blockwise.device_set);
    println!(
        "breakdown: device {} | server {} | activations {} | model transfer {}",
        fmt_secs(b.device_compute),
        fmt_secs(b.server_compute),
        fmt_secs(b.activation_transfer),
        fmt_secs(b.model_transfer)
    );
}
