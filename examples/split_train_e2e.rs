//! End-to-end split training (the required full-stack driver): loads the
//! AOT-compiled L2 model (whose dense/conv compute is the L1 Pallas
//! kernel), and runs real split learning for a few hundred steps over the
//! simulated edge network — the coordinator re-partitions per epoch, the
//! PJRT runtime executes dev_fwd/srv_step/dev_bwd with real numerics, and
//! the loss curve is logged alongside the simulated Eq. (7) delays.
//!
//! ```sh
//! make artifacts && cargo run --release --example split_train_e2e [-- epochs n_loc]
//! ```

use fastsplit::coordinator::{Coordinator, CoordinatorConfig};
use fastsplit::net::NetConfig;
use fastsplit::profiles::TrainCfg;
use fastsplit::util::{fmt_bytes, fmt_secs};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let epochs: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(50);
    let n_loc: u32 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(6);

    if !fastsplit::runtime::artifacts_available(fastsplit::runtime::DEFAULT_ARTIFACTS_DIR) {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        std::process::exit(1);
    }

    // Sub-6 GHz with poor shadowing + Rayleigh fading: link rates vary
    // enough relative to the small model's compute that the optimal cut
    // moves between epochs (on mmWave this model is transmission-trivial
    // and central-with-upload always wins).
    let cfg = CoordinatorConfig {
        net: NetConfig {
            band: fastsplit::net::Band::n1(),
            condition: fastsplit::net::ChannelCondition::Poor,
            rayleigh: true,
            num_devices: 4,
            max_radius_m: 400.0,
            ..NetConfig::default()
        },
        train: TrainCfg {
            batch: 32,
            n_loc,
            bwd_ratio: 2.0,
        },
        lr: 0.1,
        epochs,
        seed: 7,
        ..CoordinatorConfig::default()
    };
    println!(
        "end-to-end split training: {} epochs x {} local iterations = {} real PJRT steps",
        epochs,
        n_loc,
        epochs * n_loc as usize
    );
    println!("{:-<100}", "");

    let mut coord = Coordinator::new(cfg)?;
    let mut first_loss = None;
    let mut last = None;
    let mut cut_histogram = [0usize; 5];
    for _ in 0..epochs {
        let r = coord.run_epoch()?;
        first_loss.get_or_insert(r.mean_loss);
        cut_histogram[r.cut.min(4)] += 1;
        if r.epoch % 5 == 0 || r.epoch + 1 == epochs {
            println!
            (
                "epoch {:>3} dev {} ({:<16}) cut {} | loss {:.4} acc {:>5.1}% | sim {} (act-xfer {}) wire {} | decide {}",
                r.epoch,
                r.device,
                r.device_tier,
                r.cut,
                r.mean_loss,
                r.accuracy * 100.0,
                fmt_secs(r.sim_delay),
                fmt_secs(r.breakdown.activation_transfer),
                fmt_bytes(r.wire_bytes as f64),
                fmt_secs(r.decision_time),
            );
        }
        last = Some(r);
    }
    let last = last.unwrap();
    let first_loss = first_loss.unwrap();
    println!("{:-<100}", "");
    println!(
        "loss {:.4} -> {:.4} | final accuracy {:.1}% | total simulated time {} | cut histogram {:?}",
        first_loss,
        last.mean_loss,
        last.accuracy * 100.0,
        fmt_secs(coord.sim_time()),
        cut_histogram
    );
    anyhow::ensure!(
        last.mean_loss < first_loss,
        "training did not reduce the loss"
    );
    println!("e2e OK: all three layers composed (Pallas kernel -> JAX model -> rust coordinator)");
    Ok(())
}
