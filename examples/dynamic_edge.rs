//! Dynamic edge network scenario (Sec. VII-B): 20 heterogeneous Jetson
//! devices on mobility trajectories under a fading mmWave channel; the
//! coordinator re-partitions GoogLeNet every epoch and is compared against
//! the static and heuristic baselines.
//!
//! ```sh
//! cargo run --release --example dynamic_edge [-- epochs]
//! ```

use fastsplit::net::{Band, ChannelCondition, NetConfig};
use fastsplit::sim::{SimConfig, Trainer};
use fastsplit::util::fmt_secs;
use fastsplit::util::stats::Summary;
use fastsplit::util::table::Table;

fn main() {
    let epochs: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(60);

    println!("dynamic edge scenario: GoogLeNet, mmWave (n257), Rayleigh fading, {epochs} epochs\n");
    let mut table = Table::new(&[
        "method",
        "mean/epoch",
        "p95/epoch",
        "total",
        "mean decision",
    ]);
    for method in ["proposed", "oss", "device-only", "regression"] {
        let cfg = SimConfig {
            model: "googlenet".into(),
            net: NetConfig {
                band: Band::n257(),
                condition: ChannelCondition::Normal,
                rayleigh: true,
                num_devices: 20,
                ..NetConfig::default()
            },
            method: method.into(),
            seed: 42,
            ..SimConfig::default()
        };
        let mut trainer = Trainer::new(cfg);
        let res = trainer.run_epochs(epochs);
        let delays: Vec<f64> = res.records.iter().map(|r| r.delay).collect();
        let s = Summary::of(&delays);
        table.row(&[
            method.to_string(),
            fmt_secs(s.mean),
            fmt_secs(s.p95),
            fmt_secs(res.total_delay),
            fmt_secs(res.mean_decision_time),
        ]);
    }
    table.print();
    println!("\nper-epoch adaptivity (proposed): cut position follows the channel");
    let cfg = SimConfig {
        model: "googlenet".into(),
        net: NetConfig {
            band: Band::n257(),
            rayleigh: true,
            ..NetConfig::default()
        },
        method: "proposed".into(),
        seed: 42,
        ..SimConfig::default()
    };
    let mut trainer = Trainer::new(cfg);
    for r in trainer.run_epochs(12).records {
        println!(
            "  epoch {:>2}: device {:>2} ({:<16}) uplink {:>9.2} Mb/s -> {:>3} device layers, {}",
            r.epoch,
            r.device,
            r.device_tier,
            r.link.up_bps * 8.0 / 1e6,
            r.device_layers,
            fmt_secs(r.delay)
        );
    }
}
