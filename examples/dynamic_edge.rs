//! Dynamic edge network scenario (Sec. VII-B): 20 heterogeneous Jetson
//! devices on mobility trajectories under a fading mmWave channel; the
//! coordinator re-partitions GoogLeNet every epoch and is compared against
//! the static and heuristic baselines.
//!
//! ```sh
//! cargo run --release --example dynamic_edge [-- epochs]
//! ```

use fastsplit::daemon::{DaemonConfig, DaemonEvent, PlannerDaemon, SimClock};
use fastsplit::models;
use fastsplit::net::{Band, ChannelCondition, EdgeNetwork, NetConfig};
use fastsplit::partition::{
    general_partition, FleetPlanner, FleetSpec, JointPlanner, PartitionPlanner, Problem,
};
use fastsplit::profiles::{CostGraph, DeviceProfile, TrainCfg};
use fastsplit::sim::{SimConfig, Trainer};
use fastsplit::util::fmt_secs;
use fastsplit::util::stats::Summary;
use fastsplit::util::table::Table;
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let epochs: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(60);

    println!("dynamic edge scenario: GoogLeNet, mmWave (n257), Rayleigh fading, {epochs} epochs\n");
    let mut table = Table::new(&[
        "method",
        "mean/epoch",
        "p95/epoch",
        "total",
        "mean decision",
    ]);
    for method in ["proposed", "oss", "device-only", "regression"] {
        let cfg = SimConfig {
            model: "googlenet".into(),
            net: NetConfig {
                band: Band::n257(),
                condition: ChannelCondition::Normal,
                rayleigh: true,
                num_devices: 20,
                ..NetConfig::default()
            },
            method: method.into(),
            seed: 42,
            ..SimConfig::default()
        };
        let mut trainer = Trainer::new(cfg);
        let res = trainer.run_epochs(epochs);
        let delays: Vec<f64> = res.records.iter().map(|r| r.delay).collect();
        let s = Summary::of(&delays);
        table.row(&[
            method.to_string(),
            fmt_secs(s.mean),
            fmt_secs(s.p95),
            fmt_secs(res.total_delay),
            fmt_secs(res.mean_decision_time),
        ]);
    }
    table.print();
    println!("\nper-epoch adaptivity (proposed): cut position follows the channel");
    let cfg = SimConfig {
        model: "googlenet".into(),
        net: NetConfig {
            band: Band::n257(),
            rayleigh: true,
            ..NetConfig::default()
        },
        method: "proposed".into(),
        seed: 42,
        ..SimConfig::default()
    };
    let mut trainer = Trainer::new(cfg);
    for r in trainer.run_epochs(12).records {
        println!(
            "  epoch {:>2}: device {:>2} ({:<16}) uplink {:>9.2} Mb/s -> {:>3} device layers, {}",
            r.epoch,
            r.device,
            r.device_tier,
            r.link.up_bps * 8.0 / 1e6,
            r.device_layers,
            fmt_secs(r.delay)
        );
    }

    // Amortized re-partitioning on the same fading link trace: the planner
    // builds the transformed flow network once, then each epoch's decision
    // is an O(E) capacity refresh + warm Dinic solve. Compare against the
    // cold path that rebuilds everything per epoch (identical results —
    // asserted below — at a fraction of the decision time).
    println!("\namortized replanning (GoogLeNet, {epochs} link samples): cold rebuild vs warm refresh");
    let model = models::by_name("googlenet").unwrap();
    let costs = CostGraph::build(
        &model,
        &DeviceProfile::jetson_tx2(),
        &DeviceProfile::rtx_a6000(),
        &TrainCfg::default(),
    );
    let mut net = EdgeNetwork::new(NetConfig {
        band: Band::n257(),
        rayleigh: true,
        ..NetConfig::default()
    });
    let links: Vec<_> = (0..epochs)
        .map(|e| net.sample_link(0, e as f64).to_link())
        .collect();
    let t0 = Instant::now();
    let cold: Vec<_> = links
        .iter()
        .map(|&link| general_partition(&Problem::new(&costs, link)))
        .collect();
    let cold_time = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let mut planner = PartitionPlanner::new(&costs);
    let build_time = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let warm: Vec<_> = links.iter().map(|&link| planner.partition(link)).collect();
    let warm_time = t0.elapsed().as_secs_f64();
    for (c, w) in cold.iter().zip(&warm) {
        assert_eq!(c.device_set, w.device_set, "warm replan diverged from cold");
    }
    println!(
        "  cold: {} total ({}/decision)   warm: {} build + {} total ({}/decision)   speedup {:.1}x",
        fmt_secs(cold_time),
        fmt_secs(cold_time / links.len() as f64),
        fmt_secs(build_time),
        fmt_secs(warm_time),
        fmt_secs(warm_time / links.len() as f64),
        cold_time / warm_time.max(1e-12),
    );

    // Fleet-scale epoch decisions: the FleetPlanner facade answers a whole
    // fleet in one plan() call. Devices deduplicate into four Jetson tiers
    // sharing one struct-of-arrays capacity layout, and each tier's channel
    // state is sampled once per epoch, so the epoch costs O(tiers · E) —
    // not O(devices · E) — no matter how large the fleet grows.
    println!("\nfleet-scale epoch decision (GoogLeNet, deduplicated Jetson tiers, per-tier links)");
    let server = DeviceProfile::rtx_a6000();
    for n in [10usize, 100, 1000] {
        let devices = DeviceProfile::fleet_of(n);
        let spec = FleetSpec::from_fleet(&devices, |d| {
            CostGraph::build(&model, d, &server, &TrainCfg::default())
        });
        let tiers = spec.num_tiers();
        let mut planner = FleetPlanner::new(spec);
        let mut total = 0.0;
        let fleet_epochs = 12usize;
        for epoch in 0..fleet_epochs {
            let tier_links: Vec<_> = (0..tiers)
                .map(|t| net.sample_link(0, (epoch * tiers + t) as f64).to_link())
                .collect();
            let requests = planner.spec().requests(|tier| tier_links[tier]);
            let t0 = Instant::now();
            let decisions = planner.plan(&requests);
            total += t0.elapsed().as_secs_f64();
            assert_eq!(decisions.len(), n);
        }
        let stats = planner.stats();
        println!(
            "  {n:>4} devices / {tiers} tiers: {} per epoch ({} per device), {} refreshes over {} epochs",
            fmt_secs(total / fleet_epochs as f64),
            fmt_secs(total / (fleet_epochs * n) as f64),
            stats.refreshes,
            fleet_epochs,
        );
    }

    // Joint partitioning under a shared, finite server: the same fleet
    // epoch, but the server's throughput is a budget the devices compete
    // for. As capacity shrinks, the congestion price loop pushes layers
    // back onto the devices and the optimal fleet makespan grows — every
    // price probe riding the warm incremental re-solve path.
    println!("\njoint fleet partitioning (GoogLeNet, 20 devices, shared server capacity sweep)");
    let devices = DeviceProfile::fleet_of(20);
    let tier_links: Vec<_> = (0..4)
        .map(|t| net.sample_link(0, (100 + t) as f64).to_link())
        .collect();
    for capacity in [f64::INFINITY, 8.0, 3.0, 1.0] {
        let spec = FleetSpec::from_fleet(&devices, |d| {
            CostGraph::build(&model, d, &server, &TrainCfg::default())
        });
        let mut joint = JointPlanner::with_capacity(spec, capacity);
        let requests = joint.spec().requests(|tier| tier_links[tier]);
        let t0 = Instant::now();
        let decisions = joint.plan(&requests);
        let elapsed = t0.elapsed().as_secs_f64();
        let device_layers: usize = decisions.iter().map(|d| d.partition.device_layers()).sum();
        let stats = joint.stats();
        println!(
            "  capacity {:>8}: makespan {}, {} total device layers, {} price iters / {} probes, {} per epoch",
            if capacity.is_infinite() {
                "inf".to_string()
            } else {
                format!("{capacity}")
            },
            fmt_secs(joint.makespan().unwrap_or(0.0)),
            device_layers,
            stats.price_iterations,
            stats.joint_resolves,
            fmt_secs(elapsed),
        );
    }

    // Crash-safe planning: the same fleet behind a PlannerDaemon with a
    // write-ahead journal. Every accepted event hits disk before the
    // coalescer sees it, so killing the process mid-run (here: abandoning
    // the handle with no drain) loses nothing — recovery replays the
    // snapshot + journal tail and lands on the exact pre-crash state.
    println!("\ncrash-safe daemon (GoogLeNet, 20 devices, write-ahead journal)");
    let dir =
        std::env::temp_dir().join(format!("fastsplit-example-journal-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let spec = FleetSpec::from_fleet(&devices, |d| {
        CostGraph::build(&model, d, &server, &TrainCfg::default())
    });
    let clock = SimClock::new(0);
    let daemon = PlannerDaemon::spawn(
        spec,
        DaemonConfig {
            replan_every: 1,
            lease_ttl: Some(4),
            journal_dir: Some(dir.clone()),
            ..DaemonConfig::default()
        },
        Arc::new(clock.clone()),
    );
    let crash_ticks = 10u64;
    let mut planned = 0usize;
    for tick in 1..=crash_ticks {
        clock.set(tick);
        for device in 0..devices.len() {
            let link = net.sample_link(0, (tick as usize * 7 + device) as f64).to_link();
            let _ = daemon.send(DaemonEvent::Report { device, link, tick });
        }
        planned += daemon.pump().epochs.len();
    }
    let pre_crash = daemon.metrics();
    daemon.abandon(); // simulated crash: the journal ends without a drain frame
    println!("  {planned} epochs planned over {crash_ticks} ticks, then crashed (no drain frame)");

    let (recovered, report) = PlannerDaemon::recover(&dir, Arc::new(SimClock::new(crash_ticks)))
        .expect("recovery from the crashed journal");
    println!(
        "  recovered: snapshot at tick {}, {} frames replayed ({} events), torn {}, shutdown {:?}",
        report.snapshot_tick,
        report.replayed_frames,
        report.replayed_events,
        report.torn_frames,
        report.shutdown, // None: the journal proves this was a crash, not a stop
    );
    let stable = |scrape: &str| -> String {
        scrape
            .lines()
            .filter(|l| !l.contains("fastsplit_journal_") && !l.contains("fastsplit_ingest_shed"))
            .collect::<Vec<_>>()
            .join("\n")
    };
    assert_eq!(
        stable(&pre_crash),
        stable(&recovered.metrics()),
        "recovered scrape diverged from the pre-crash daemon"
    );
    let next = recovered.plan_now();
    println!(
        "  scrape bit-identical to the pre-crash daemon; next epoch plans {} devices",
        next.decisions.len()
    );
    recovered.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
