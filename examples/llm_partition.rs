//! LLM partitioning (Sec. VI-E / Fig. 14): GPT-2 as a block-structured
//! model — embedding, transformer blocks, and head are treated as blocks by
//! the block-wise algorithm, which finds the optimal split in microseconds
//! on a graph reduced from ~100 layers to a few dozen vertices.
//!
//! ```sh
//! cargo run --release --example llm_partition
//! ```

use fastsplit::models;
use fastsplit::partition::blockwise::blockwise_partition_instrumented;
use fastsplit::partition::general::general_partition_instrumented;
use fastsplit::partition::{Link, Problem};
use fastsplit::profiles::{CostGraph, DeviceProfile, TrainCfg};
use fastsplit::util::{fmt_bytes, fmt_secs};
use std::time::Instant;

fn main() {
    let model = models::by_name("gpt2").unwrap();
    println!(
        "GPT-2 small: {} layers, {:.1}M params, {:.1} GFLOPs/sample (T=128)",
        model.len(),
        model.total_params() as f64 / 1e6,
        model.total_flops() as f64 / 1e9
    );

    let costs = CostGraph::build(
        &model,
        &DeviceProfile::jetson_agx_orin(),
        &DeviceProfile::rtx_a6000(),
        &TrainCfg {
            batch: 8,
            n_loc: 10,
            bwd_ratio: 2.0,
        },
    );

    println!("\nuplink sweep (downlink = 4x uplink):");
    println!(
        "{:<12} {:>14} {:>14} {:>12} {:>12} {:>10}",
        "uplink", "general", "block-wise", "dev layers", "delay", "reduced-V"
    );
    for up_mbps in [5.0, 20.0, 100.0, 400.0, 2000.0] {
        let link = Link {
            up_bps: up_mbps * 1e6 / 8.0,
            down_bps: 4.0 * up_mbps * 1e6 / 8.0,
        };
        let p = Problem::new(&costs, link);
        let t0 = Instant::now();
        let gen = general_partition_instrumented(&p);
        let t_gen = t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        let bw = blockwise_partition_instrumented(&p);
        let t_bw = t1.elapsed().as_secs_f64();
        assert!((gen.partition.delay - bw.partition.delay).abs() < 1e-9 * gen.partition.delay);
        println!(
            "{:<12} {:>14} {:>14} {:>12} {:>12} {:>10}",
            format!("{up_mbps} Mb/s"),
            fmt_secs(t_gen),
            fmt_secs(t_bw),
            format!(
                "{}/{}",
                bw.partition.device_layers(),
                costs.len()
            ),
            fmt_secs(bw.partition.delay),
            format!("{}→{}", gen.flow_vertices, bw.flow_vertices),
        );
    }

    // Where does the optimal cut sit? Show the boundary activations.
    let link = Link {
        up_bps: 20e6 / 8.0,
        down_bps: 80e6 / 8.0,
    };
    let p = Problem::new(&costs, link);
    let part = fastsplit::partition::blockwise_partition(&p);
    println!("\ncut at 20 Mb/s uplink: {}", part.describe());
    for v in 0..costs.len() {
        if part.device_set[v]
            && costs
                .dag
                .out_edges(v)
                .iter()
                .any(|&e| !part.device_set[costs.dag.edge(e).to])
        {
            println!(
                "  boundary layer {:<14} activation {}",
                costs.dag.label(v),
                fmt_bytes(costs.act_bytes[v])
            );
        }
    }
}
