#!/usr/bin/env python3
"""Sanity-check the committed bench artifacts.

Every BENCH_PR*.json at the repo root must parse as JSON and carry a
boolean `measured` flag (False marks a placeholder awaiting a toolchain
run — fine; a file that does not parse, or silently dropped the flag, is
not). Run from anywhere; CI runs it after the bench smokes.
"""

import glob
import json
import os
import sys


def main() -> int:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    paths = sorted(glob.glob(os.path.join(root, "BENCH_PR*.json")))
    if not paths:
        print("check_bench_json: no BENCH_PR*.json files found", file=sys.stderr)
        return 1
    failures = 0
    for path in paths:
        name = os.path.basename(path)
        try:
            with open(path, encoding="utf-8") as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"check_bench_json: {name}: does not parse: {e}", file=sys.stderr)
            failures += 1
            continue
        if not isinstance(doc, dict) or not isinstance(doc.get("measured"), bool):
            print(
                f"check_bench_json: {name}: missing boolean 'measured' flag",
                file=sys.stderr,
            )
            failures += 1
            continue
        state = "measured" if doc["measured"] else "placeholder"
        print(f"check_bench_json: {name}: ok ({state})")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
