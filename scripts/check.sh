#!/usr/bin/env bash
# Repo-wide lint + build + test gate (run locally or from CI).
#
#   scripts/check.sh           # everything
#   scripts/check.sh --fast    # skip the release build
set -euo pipefail
cd "$(dirname "$0")/.."

fast=0
[[ "${1:-}" == "--fast" ]] && fast=1

echo "==> cargo fmt --check"
cargo fmt --all -- --check

echo "==> cargo clippy -D warnings"
cargo clippy --workspace --all-targets -- -D warnings

if [[ $fast -eq 0 ]]; then
  echo "==> cargo build --release"
  cargo build --release
fi

echo "==> cargo test -q"
cargo test -q

if [[ $fast -eq 0 ]]; then
  # Bench smoke: compile + run the bench binaries so they cannot bit-rot.
  # Output files are disabled (-) so committed BENCH_*.json results are
  # only ever replaced by deliberate full runs.
  echo "==> cargo bench --bench replan -- --quick (smoke)"
  FASTSPLIT_REPLAN_OUT=- cargo bench --bench replan -- --quick
  echo "==> cargo bench --bench fleet -- --smoke"
  FASTSPLIT_FLEET_OUT=- cargo bench --bench fleet -- --smoke
fi

echo "OK"
