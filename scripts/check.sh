#!/usr/bin/env bash
# Repo-wide lint + build + test gate (run locally or from CI).
#
#   scripts/check.sh           # everything
#   scripts/check.sh --fast    # skip the release build
set -euo pipefail
cd "$(dirname "$0")/.."

fast=0
[[ "${1:-}" == "--fast" ]] && fast=1

echo "==> cargo fmt --check"
cargo fmt --all -- --check

echo "==> cargo clippy -D warnings"
cargo clippy --workspace --all-targets -- -D warnings

if [[ $fast -eq 0 ]]; then
  echo "==> cargo build --release"
  cargo build --release
fi

echo "==> cargo test -q"
cargo test -q

if [[ $fast -eq 0 ]]; then
  # Property suites at optimized speed (they only ran in debug before PR 3).
  echo "==> cargo test -q --release"
  cargo test -q --release

  # The cost-equivalence suite must hold for any seed; re-run it under two
  # fixed seeds so CI covers more of the generator matrix than the default
  # stream (replay recipe: PERF.md "Deterministic seeds").
  echo "==> equivalence suite under two fixed seeds"
  PALLAS_TEST_SEED=1 cargo test -q --release equivalence
  PALLAS_TEST_SEED=0xC0FFEE cargo test -q --release equivalence

  # Chaos lane (PR 6): the churn-replay suite — seeded fault injection
  # (join/leave/migrate/stale over fading walks) pinned bit-identical to a
  # fresh planner at the final spec, with every degraded decision feasible
  # inside the stale-σ envelope. The property must hold for any seed; two
  # fixed seeds widen the generator matrix, and the suite runs in both
  # feature configs (serial here, parallel below).
  echo "==> churn-replay suite under two fixed seeds"
  PALLAS_TEST_SEED=1 cargo test -q --release churn
  PALLAS_TEST_SEED=0xC0FFEE cargo test -q --release churn

  # Daemon soak lane (PR 7): the planner-daemon suite — coalesced ingest
  # replaying bit-identical to the raw uncoalesced service, timer-wheel
  # scheduling/lease expiry, graceful drain, and the byte-stable metrics
  # scrape — under the same two fixed seeds and both feature configs.
  echo "==> daemon suite under two fixed seeds"
  PALLAS_TEST_SEED=1 cargo test -q --release daemon
  PALLAS_TEST_SEED=0xC0FFEE cargo test -q --release daemon

  # Durability lane (PR 9): the write-ahead-journal suite — a seeded
  # crash harness kills the daemon at every frame boundary of a churn
  # script and demands bit-identical recovery from disk; corruption fuzz
  # (bit-flips / truncations) must recover a prefix or refuse typed,
  # never panic; cross-version and foreign-model journals are refused
  # typed. Both seeds, both feature configs (serial here, parallel
  # below). Contracts: RESILIENCE.md "Durability contracts".
  echo "==> journal crash-recovery suite under two fixed seeds"
  PALLAS_TEST_SEED=1 cargo test -q --release journal
  PALLAS_TEST_SEED=0xC0FFEE cargo test -q --release journal

  # End-to-end recovery through the CLI: simulate with a journal
  # directory, crash the daemon mirror, recover from disk, and verify
  # the recovered scrape — the command exits non-zero on divergence.
  echo "==> fastsplit simulate --journal-dir (crash/recover demo)"
  journal_dir="$(mktemp -d)"
  cargo run --release -q -- simulate --model googlenet --method proposed \
    --band mmwave --condition normal --epochs 6 --devices 8 \
    --journal-dir "$journal_dir"
  rm -rf "$journal_dir"

  # Scale lane (PR 8): the σ-quantizer suite (bucket-bound property over
  # the seeded zoo, boundary/sub-resolution edge cases) and the sharded
  # planner pins (bit-identical to the flat engine with quantization off,
  # shard-count-independent bucket grids with it on) — both under the
  # same two fixed seeds and both feature configs (serial here, parallel
  # below).
  echo "==> quantizer + sharded suites under two fixed seeds"
  PALLAS_TEST_SEED=1 cargo test -q --release quantiz
  PALLAS_TEST_SEED=0xC0FFEE cargo test -q --release quantiz
  PALLAS_TEST_SEED=1 cargo test -q --release sharded
  PALLAS_TEST_SEED=0xC0FFEE cargo test -q --release sharded

  # Topology lane (PR 10): the multi-hop K-segment suite (stage
  # separability, nested-cut DP, pooling fallback, K=1 bit-identity,
  # nested-tuple oracle) and the device→server assignment suite
  # (1-server bit-identity, assignment oracle, capacity/server
  # monotonicity, local-search repair) — under the same two fixed seeds
  # and both feature configs (serial here, parallel below).
  echo "==> multihop + assign suites under two fixed seeds"
  PALLAS_TEST_SEED=1 cargo test -q --release multihop
  PALLAS_TEST_SEED=0xC0FFEE cargo test -q --release multihop
  PALLAS_TEST_SEED=1 cargo test -q --release assign
  PALLAS_TEST_SEED=0xC0FFEE cargo test -q --release assign

  # Feature matrix: the rayon parallel dirty-tier sweep must compile and
  # stay bit-identical to the serial loop (the determinism test runs under
  # both configurations).
  echo "==> cargo test -q --features parallel"
  cargo test -q --features parallel

  echo "==> churn-replay suite under two fixed seeds (features parallel)"
  PALLAS_TEST_SEED=1 cargo test -q --release --features parallel churn
  PALLAS_TEST_SEED=0xC0FFEE cargo test -q --release --features parallel churn

  echo "==> daemon suite under two fixed seeds (features parallel)"
  PALLAS_TEST_SEED=1 cargo test -q --release --features parallel daemon
  PALLAS_TEST_SEED=0xC0FFEE cargo test -q --release --features parallel daemon

  echo "==> journal crash-recovery suite under two fixed seeds (features parallel)"
  PALLAS_TEST_SEED=1 cargo test -q --release --features parallel journal
  PALLAS_TEST_SEED=0xC0FFEE cargo test -q --release --features parallel journal

  echo "==> quantizer + sharded suites under two fixed seeds (features parallel)"
  PALLAS_TEST_SEED=1 cargo test -q --release --features parallel quantiz
  PALLAS_TEST_SEED=0xC0FFEE cargo test -q --release --features parallel quantiz
  PALLAS_TEST_SEED=1 cargo test -q --release --features parallel sharded
  PALLAS_TEST_SEED=0xC0FFEE cargo test -q --release --features parallel sharded

  echo "==> multihop + assign suites under two fixed seeds (features parallel)"
  PALLAS_TEST_SEED=1 cargo test -q --release --features parallel multihop
  PALLAS_TEST_SEED=0xC0FFEE cargo test -q --release --features parallel multihop
  PALLAS_TEST_SEED=1 cargo test -q --release --features parallel assign
  PALLAS_TEST_SEED=0xC0FFEE cargo test -q --release --features parallel assign

  # Bench smoke: compile + run the bench binaries so they cannot bit-rot.
  # Output files are disabled (-) so committed BENCH_*.json results are
  # only ever replaced by deliberate full runs.
  echo "==> cargo bench --bench replan -- --smoke"
  FASTSPLIT_REPLAN_OUT=- FASTSPLIT_REPLAN4_OUT=- cargo bench --bench replan -- --smoke
  echo "==> cargo bench --bench fleet -- --smoke"
  FASTSPLIT_FLEET_OUT=- FASTSPLIT_FLEET_BLOCK_OUT=- FASTSPLIT_FLEET_SCALE_OUT=- cargo bench --bench fleet -- --smoke
  echo "==> cargo bench --bench joint -- --smoke"
  FASTSPLIT_JOINT_OUT=- cargo bench --bench joint -- --smoke
  echo "==> cargo bench --bench churn -- --smoke"
  FASTSPLIT_CHURN_OUT=- cargo bench --bench churn -- --smoke
  echo "==> cargo bench --bench daemon -- --smoke"
  FASTSPLIT_DAEMON_OUT=- cargo bench --bench daemon -- --smoke
  echo "==> cargo bench --bench multihop -- --smoke"
  FASTSPLIT_MULTIHOP_OUT=- cargo bench --bench multihop -- --smoke
  echo "==> bench smoke with --features parallel"
  FASTSPLIT_REPLAN_OUT=- FASTSPLIT_REPLAN4_OUT=- cargo bench --bench replan --features parallel -- --smoke
  FASTSPLIT_FLEET_OUT=- FASTSPLIT_FLEET_BLOCK_OUT=- FASTSPLIT_FLEET_SCALE_OUT=- cargo bench --bench fleet --features parallel -- --smoke
  FASTSPLIT_JOINT_OUT=- cargo bench --bench joint --features parallel -- --smoke
  FASTSPLIT_CHURN_OUT=- cargo bench --bench churn --features parallel -- --smoke
  FASTSPLIT_DAEMON_OUT=- cargo bench --bench daemon --features parallel -- --smoke
  FASTSPLIT_MULTIHOP_OUT=- cargo bench --bench multihop --features parallel -- --smoke
fi

# Committed bench artifacts must stay parseable and carry the `measured`
# flag (placeholders are fine; silent corruption is not).
echo "==> bench JSON artifacts"
python3 scripts/check_bench_json.py

echo "OK"
