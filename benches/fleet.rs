//! Benchmark: fleet-scale epoch decisions through the `FleetPlanner`
//! facade — one `plan()` call answering a whole 10/100/1000-device fleet.
//! Devices deduplicate into four Jetson tiers sharing one struct-of-arrays
//! capacity layout, so a dirty epoch costs O(tiers · E) solve work plus
//! O(devices) fan-out, and a clean epoch (links unchanged) is pure fan-out.
//!
//! ```sh
//! cargo bench --bench fleet [-- filter] [--quick] [--smoke]
//! ```
//!
//! `--smoke` is the CI fast mode: tiny measurement windows, the 1000-device
//! sweep skipped, no JSON written — it exists so the bench compiles and
//! runs on every push. A full run writes the epoch decision times to
//! `BENCH_PR2.json` (override with `FASTSPLIT_FLEET_OUT`, disable with
//! `FASTSPLIT_FLEET_OUT=-`) so the perf trajectory is tracked in-repo
//! (see PERF.md).

use fastsplit::partition::{FleetPlanner, FleetSpec, Link, PartitionPlanner};
use fastsplit::profiles::{CostGraph, DeviceProfile, TrainCfg};
use fastsplit::util::bench::{BenchConfig, Bencher};
use fastsplit::util::json::Json;
use std::time::Duration;

const MODEL: &str = "googlenet";

fn costs(device: &DeviceProfile) -> CostGraph {
    let m = fastsplit::models::by_name(MODEL).unwrap();
    CostGraph::build(
        &m,
        device,
        &DeviceProfile::rtx_a6000(),
        &TrainCfg::default(),
    )
}

/// Deterministic per-(tier, epoch) link: every tier is dirty every epoch.
fn epoch_link(tier: usize, epoch: u64) -> Link {
    let phase = (epoch % 13 + 1) as f64;
    Link {
        up_bps: 2e5 * (1.0 + tier as f64) * phase,
        down_bps: 8e5 * (1.0 + tier as f64) * phase,
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut b = if smoke {
        Bencher::with_config(BenchConfig {
            measure_time: Duration::from_millis(40),
            warmup_time: Duration::from_millis(10),
            max_samples: 200,
        })
    } else {
        Bencher::from_env()
    };
    let fleet_sizes: &[usize] = if smoke { &[10, 100] } else { &[10, 100, 1000] };

    // Correctness gate before timing: fleet decisions must be bit-identical
    // to per-tier PartitionPlanner solves over the same link trace.
    {
        let devices = DeviceProfile::fleet_of(100);
        let spec = FleetSpec::from_fleet(&devices, costs);
        let num_tiers = spec.num_tiers();
        let mut reference: Vec<PartitionPlanner> = (0..num_tiers)
            .map(|t| PartitionPlanner::new(spec.tier_costs(t)))
            .collect();
        let mut fleet = FleetPlanner::new(spec);
        for epoch in 0..8u64 {
            let reqs = fleet.spec().requests(|t| epoch_link(t, epoch));
            // One reference solve per (tier, link) — all devices of a tier
            // share the epoch link, so per-request solves would only
            // re-check bit-exact cache copies at 100x the cost.
            let want: Vec<_> = (0..num_tiers)
                .map(|t| reference[t].partition(epoch_link(t, epoch)))
                .collect();
            for (r, d) in reqs.iter().zip(fleet.plan(&reqs)) {
                assert_eq!(
                    d.partition.device_set, want[r.tier].device_set,
                    "fleet decision diverged from per-device planner"
                );
                assert_eq!(d.partition.delay.to_bits(), want[r.tier].delay.to_bits());
            }
        }
        let s = fleet.stats();
        assert_eq!(
            s.refreshes,
            8 * fleet.spec().num_tiers() as u64,
            "expected exactly one refresh per dirty tier per epoch"
        );
    }

    let mut rows: Vec<Json> = Vec::new();
    for &n in fleet_sizes {
        let devices = DeviceProfile::fleet_of(n);
        let spec = FleetSpec::from_fleet(&devices, costs);
        let num_tiers = spec.num_tiers();

        // Dirty epoch: fresh per-tier links every iteration — the facade
        // refreshes + re-solves each tier, then fans decisions out.
        let mut planner = FleetPlanner::new(spec);
        let before = b.results().len();
        let mut epoch = 0u64;
        b.bench(&format!("fleet/{MODEL}/{n}dev/epoch-dirty"), || {
            epoch += 1;
            let reqs = planner.spec().requests(|t| epoch_link(t, epoch));
            planner.plan(&reqs)
        });
        let dirty = (b.results().len() > before).then(|| b.results()[before].summary.mean);

        // Clean epoch: identical links every iteration — after the first
        // solve the epoch is pure cache fan-out (the facade's floor).
        let before = b.results().len();
        b.bench(&format!("fleet/{MODEL}/{n}dev/epoch-clean"), || {
            let reqs = planner.spec().requests(|t| epoch_link(t, 0));
            planner.plan(&reqs)
        });
        let clean = (b.results().len() > before).then(|| b.results()[before].summary.mean);

        if let (Some(dirty), Some(clean)) = (dirty, clean) {
            println!(
                "fleet/{n}dev: dirty epoch {dirty:.3e}s ({:.3e}s/device), clean epoch {clean:.3e}s",
                dirty / n as f64
            );
            rows.push(Json::obj(vec![
                ("devices", Json::num(n as f64)),
                ("tiers", Json::num(num_tiers as f64)),
                ("epoch_dirty_mean_s", Json::num(dirty)),
                ("epoch_dirty_per_device_s", Json::num(dirty / n as f64)),
                ("epoch_clean_mean_s", Json::num(clean)),
            ]));
        }
    }
    b.finish();

    if smoke {
        println!("smoke mode: skipping BENCH_PR2.json");
        return;
    }
    let out = std::env::var("FASTSPLIT_FLEET_OUT").unwrap_or_else(|_| "BENCH_PR2.json".into());
    if out == "-" || rows.is_empty() {
        return;
    }
    let doc = Json::obj(vec![
        ("bench", Json::str("fleet")),
        ("measured", Json::Bool(true)),
        (
            "note",
            Json::str(
                "FleetPlanner::plan epoch decision over 10/100/1000-device fleets \
                 (googlenet, 4 deduplicated Jetson tiers, per-tier links); dirty = fresh \
                 links each epoch (refresh+solve per tier), clean = unchanged links \
                 (cache fan-out only)",
            ),
        ),
        ("results", Json::Arr(rows)),
    ]);
    match std::fs::write(&out, doc.pretty() + "\n") {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
}
