//! Benchmark: fleet-scale epoch decisions through the `FleetPlanner`
//! facade — one `plan()` call answering a whole 10/100/1000-device fleet.
//! Devices deduplicate into four Jetson tiers sharing one struct-of-arrays
//! capacity layout, so a dirty epoch costs O(tiers · E) solve work plus
//! O(devices) fan-out, and a clean epoch (links unchanged) is pure fan-out.
//! A second sweep times the fleet-level Theorem 2 block reduction on
//! block-structured fleets (ResNet-18 / GPT-2): the same dirty epoch with
//! the engine solving the reduced DAG vs the full general DAG.
//!
//! ```sh
//! cargo bench --bench fleet [-- filter] [--quick] [--smoke]
//! ```
//!
//! `--smoke` is the CI fast mode: tiny measurement windows, the 1000-device
//! sweep skipped, smaller block fleets, no JSON written — it exists so the
//! bench compiles and runs on every push. A full run writes the epoch
//! decision times to `BENCH_PR2.json` and the reduced-vs-full sweep to
//! `BENCH_PR3.json` (override with `FASTSPLIT_FLEET_OUT` /
//! `FASTSPLIT_FLEET_BLOCK_OUT`, disable either with `=-`) so the perf
//! trajectory is tracked in-repo (see PERF.md).

use fastsplit::partition::{
    FleetOptions, FleetPlanner, FleetSpec, Link, PartitionPlanner, PlanRequest, Problem,
};
use fastsplit::profiles::{CostGraph, DeviceProfile, TrainCfg};
use fastsplit::util::bench::{BenchConfig, Bencher};
use fastsplit::util::json::Json;
use fastsplit::util::prop::{assert_cut_cost_equal, fading_walk};
use fastsplit::util::rng::Rng;
use fastsplit::util::stats::Summary;
use std::time::{Duration, Instant};

const MODEL: &str = "googlenet";

fn costs_for(model: &str, device: &DeviceProfile) -> CostGraph {
    let m = fastsplit::models::by_name(model).unwrap();
    CostGraph::build(
        &m,
        device,
        &DeviceProfile::rtx_a6000(),
        &TrainCfg::default(),
    )
}

fn costs(device: &DeviceProfile) -> CostGraph {
    costs_for(MODEL, device)
}

/// Deterministic per-(tier, epoch) link: every tier is dirty every epoch.
fn epoch_link(tier: usize, epoch: u64) -> Link {
    let phase = (epoch % 13 + 1) as f64;
    Link {
        up_bps: 2e5 * (1.0 + tier as f64) * phase,
        down_bps: 8e5 * (1.0 + tier as f64) * phase,
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut b = if smoke {
        Bencher::with_config(BenchConfig {
            measure_time: Duration::from_millis(40),
            warmup_time: Duration::from_millis(10),
            max_samples: 200,
        })
    } else {
        Bencher::from_env()
    };
    let fleet_sizes: &[usize] = if smoke { &[10, 100] } else { &[10, 100, 1000] };

    // Correctness gate before timing: fleet decisions (which solve the
    // Theorem 2 reduced DAG by default) must be cost-equivalent — equal
    // Eq. (7) training delay — to per-tier PartitionPlanner solves (the
    // unreduced general engine) over the same link trace, and refresh
    // exactly once per dirty tier per epoch.
    {
        let devices = DeviceProfile::fleet_of(100);
        let spec = FleetSpec::from_fleet(&devices, costs);
        let num_tiers = spec.num_tiers();
        let mut reference: Vec<PartitionPlanner> = (0..num_tiers)
            .map(|t| PartitionPlanner::new(spec.tier_costs(t)))
            .collect();
        let mut fleet = FleetPlanner::new(spec);
        for epoch in 0..8u64 {
            let reqs = fleet.spec().requests(|t| epoch_link(t, epoch));
            // One reference solve per (tier, link) — all devices of a tier
            // share the epoch link, so per-request solves would only
            // re-check bit-exact cache copies at 100x the cost.
            let want: Vec<_> = (0..num_tiers)
                .map(|t| reference[t].partition(epoch_link(t, epoch)))
                .collect();
            let decisions = fleet.plan(&reqs);
            for (r, d) in reqs.iter().zip(&decisions) {
                let problem = Problem::new(fleet.spec().tier_costs(r.tier), r.link);
                assert_cut_cost_equal(&problem, &d.partition, &want[r.tier]);
            }
        }
        let s = fleet.stats();
        assert_eq!(
            s.refreshes,
            8 * fleet.spec().num_tiers() as u64,
            "expected exactly one refresh per dirty tier per epoch"
        );
        assert!(
            s.reduced_vertices < s.full_vertices,
            "googlenet must solve on a reduced DAG"
        );
    }

    let mut rows: Vec<Json> = Vec::new();
    for &n in fleet_sizes {
        let devices = DeviceProfile::fleet_of(n);
        let spec = FleetSpec::from_fleet(&devices, costs);
        let num_tiers = spec.num_tiers();

        // Dirty epoch: fresh per-tier links every iteration — the facade
        // refreshes + re-solves each tier, then fans decisions out.
        let mut planner = FleetPlanner::new(spec);
        let before = b.results().len();
        let mut epoch = 0u64;
        b.bench(&format!("fleet/{MODEL}/{n}dev/epoch-dirty"), || {
            epoch += 1;
            let reqs = planner.spec().requests(|t| epoch_link(t, epoch));
            planner.plan(&reqs)
        });
        let dirty = (b.results().len() > before).then(|| b.results()[before].summary.mean);

        // Clean epoch: identical links every iteration — after the first
        // solve the epoch is pure cache fan-out (the facade's floor).
        let before = b.results().len();
        b.bench(&format!("fleet/{MODEL}/{n}dev/epoch-clean"), || {
            let reqs = planner.spec().requests(|t| epoch_link(t, 0));
            planner.plan(&reqs)
        });
        let clean = (b.results().len() > before).then(|| b.results()[before].summary.mean);

        // σ-drift dirty epoch: per-tier links drift a few percent per
        // epoch — the fading case the incremental (flow-reusing) re-solve
        // targets — vs the same walk with the incremental path disabled
        // (the PR-1 cold-refresh engine). The planner's own counters must
        // prove the fast path actually ran.
        let mut drift_means = Vec::new();
        for (mode, options) in [
            ("incremental", FleetOptions::default()),
            (
                "cold-refresh",
                FleetOptions {
                    incremental: false,
                    ..FleetOptions::default()
                },
            ),
        ] {
            let spec = FleetSpec::from_fleet(&devices, costs);
            let mut planner = FleetPlanner::with_options(spec, options);
            let mut rng = Rng::new(0xD81F7 ^ n as u64);
            let mut tier_links: Vec<Link> = (0..num_tiers).map(|t| epoch_link(t, 0)).collect();
            let before = b.results().len();
            b.bench(&format!("fleet/{MODEL}/{n}dev/epoch-drift-{mode}"), || {
                for l in tier_links.iter_mut() {
                    *l = fading_walk(&mut rng, *l, 1, 0.96, 1.04)[0];
                }
                let reqs = planner.spec().requests(|t| tier_links[t]);
                planner.plan(&reqs)
            });
            drift_means
                .push((b.results().len() > before).then(|| b.results()[before].summary.mean));
            let ps = planner.stats();
            if mode == "incremental" && ps.flow_solves > 0 {
                assert!(
                    ps.incremental_solves > 0,
                    "σ-drift epochs must take the incremental path"
                );
            }
            if mode == "cold-refresh" {
                assert_eq!(ps.incremental_solves, 0);
            }
        }

        if let (Some(dirty), Some(clean)) = (dirty, clean) {
            println!(
                "fleet/{n}dev: dirty epoch {dirty:.3e}s ({:.3e}s/device), clean epoch {clean:.3e}s",
                dirty / n as f64
            );
            let mut row = vec![
                ("devices", Json::num(n as f64)),
                ("tiers", Json::num(num_tiers as f64)),
                ("epoch_dirty_mean_s", Json::num(dirty)),
                ("epoch_dirty_per_device_s", Json::num(dirty / n as f64)),
                ("epoch_clean_mean_s", Json::num(clean)),
            ];
            if let [Some(inc), Some(cold)] = drift_means[..] {
                println!(
                    "fleet/{n}dev: drift epoch incremental {inc:.3e}s vs cold-refresh {cold:.3e}s \
                     ({:.1}x)",
                    cold / inc.max(1e-12)
                );
                row.push(("epoch_drift_incremental_mean_s", Json::num(inc)));
                row.push(("epoch_drift_cold_refresh_mean_s", Json::num(cold)));
                row.push(("drift_speedup", Json::num(cold / inc.max(1e-12))));
            }
            rows.push(Json::obj(row));
        }
    }

    // Block-structured sweep (PR 3): the same dirty-epoch decision with the
    // fleet-level Theorem 2 reduction on (default) vs off (full general
    // DAG), on fleets of models whose blocks abstract — ResNet-18 reduces
    // to a chain (linear-scan epochs), GPT-2 likewise at transformer scale.
    let block_models: &[&str] = if smoke {
        &["resnet18"]
    } else {
        &["resnet18", "gpt2"]
    };
    let block_devices = if smoke { 10 } else { 100 };
    let mut block_rows: Vec<Json> = Vec::new();
    for &model in block_models {
        let devices = DeviceProfile::fleet_of(block_devices);
        let spec_of = || FleetSpec::from_fleet(&devices, |d| costs_for(model, d));

        // Reduced-vs-full cost-equivalence gate on a short trace (full =
        // the bit-identical PR-1 engine: no reduction, no flow reuse).
        let mut reduced = FleetPlanner::new(spec_of());
        let mut full = FleetPlanner::with_options(spec_of(), FleetOptions::bit_identical());
        for epoch in 0..4u64 {
            let reqs = reduced.spec().requests(|t| epoch_link(t, epoch));
            let red_decisions = reduced.plan(&reqs);
            let full_decisions = full.plan(&reqs);
            for ((r, da), db) in reqs.iter().zip(&red_decisions).zip(&full_decisions) {
                let problem = Problem::new(reduced.spec().tier_costs(r.tier), r.link);
                assert_cut_cost_equal(&problem, &da.partition, &db.partition);
            }
        }
        let stats = reduced.stats();
        assert!(
            stats.reduced_vertices < stats.full_vertices,
            "{model}: fleet reduction abstracted nothing"
        );

        let mut means = Vec::new();
        for (mode, reduce) in [("reduced", true), ("full", false)] {
            let mut planner = if reduce {
                FleetPlanner::new(spec_of())
            } else {
                FleetPlanner::with_options(spec_of(), FleetOptions::bit_identical())
            };
            let mut epoch = 0u64;
            let before = b.results().len();
            b.bench(
                &format!("fleet/{model}/{block_devices}dev/epoch-dirty-{mode}"),
                || {
                    epoch += 1;
                    let reqs = planner.spec().requests(|t| epoch_link(t, epoch));
                    planner.plan(&reqs)
                },
            );
            means.push((b.results().len() > before).then(|| b.results()[before].summary.mean));
        }
        if let [Some(reduced_s), Some(full_s)] = means[..] {
            println!(
                "fleet/{model}: reduced dirty epoch {reduced_s:.3e}s vs full {full_s:.3e}s \
                 ({:.1}x, solve DAG {}v/{}e vs {}v/{}e)",
                full_s / reduced_s.max(1e-12),
                stats.reduced_vertices,
                stats.reduced_edges,
                stats.full_vertices,
                stats.full_edges,
            );
            block_rows.push(Json::obj(vec![
                ("model", Json::str(model)),
                ("devices", Json::num(block_devices as f64)),
                ("blocks_abstracted", Json::num(stats.blocks_abstracted as f64)),
                ("full_vertices", Json::num(stats.full_vertices as f64)),
                ("full_edges", Json::num(stats.full_edges as f64)),
                ("reduced_vertices", Json::num(stats.reduced_vertices as f64)),
                ("reduced_edges", Json::num(stats.reduced_edges as f64)),
                ("epoch_dirty_reduced_mean_s", Json::num(reduced_s)),
                ("epoch_dirty_full_mean_s", Json::num(full_s)),
                ("speedup", Json::num(full_s / reduced_s.max(1e-12))),
            ]));
        }
    }
    b.finish();

    // Million-device scale lane (PR 8): one epoch decision for a fleet
    // where every device reports a *distinct* jittered link, planned with
    // σ-quantization collapsing the link set to log-spaced buckets. Timed
    // manually per epoch (the decision path is seconds-scale at 10^6
    // devices, so a handful of epoch samples beats a measurement window)
    // and reported as p50/p99 epoch-decision latency.
    let scale_devices: usize = if smoke { 10_000 } else { 1_000_000 };
    let scale_epochs: usize = if smoke { 4 } else { 8 };
    let buckets_per_decade: u32 = 8;
    let scale_row = {
        let devices = DeviceProfile::fleet_of(scale_devices);
        let spec = FleetSpec::from_fleet(&devices, costs);
        let num_tiers = spec.num_tiers();
        let mut planner = FleetPlanner::with_options(
            spec,
            FleetOptions {
                sigma_buckets_per_decade: buckets_per_decade,
                ..FleetOptions::default()
            },
        );
        let mut samples = Vec::with_capacity(scale_epochs);
        for epoch in 0..scale_epochs as u64 {
            // Distinct per-device links, drifting per epoch: a per-device
            // jitter spread over ±10% around the tier's epoch link, so
            // neighbours share a σ-bucket but almost no two links are
            // bit-equal (the quantizer, not the exact-match cache, does
            // the collapsing).
            let reqs: Vec<PlanRequest> = (0..planner.spec().num_devices())
                .map(|d| {
                    let tier = planner.spec().tier_of(d);
                    let base = epoch_link(tier, epoch);
                    let jitter = 0.9 + 0.2 * (d as f64 / scale_devices as f64);
                    PlanRequest {
                        device: d,
                        tier,
                        link: Link {
                            up_bps: base.up_bps * jitter,
                            down_bps: base.down_bps * jitter,
                        },
                    }
                })
                .collect();
            let t0 = Instant::now();
            let decisions = planner.plan(&reqs);
            samples.push(t0.elapsed().as_secs_f64());
            assert_eq!(decisions.len(), reqs.len());
        }
        let s = Summary::of(&samples);
        let stats = planner.stats();
        assert!(
            stats.quantized_requests > 0,
            "the jittered links must collapse into sigma buckets"
        );
        println!(
            "fleet/{MODEL}/{scale_devices}dev/epoch-quantized: mean {:.3e}s p50 {:.3e}s \
             p99 {:.3e}s ({} epochs, {} buckets/decade, {} requests quantized, {} flow solves)",
            s.mean, s.p50, s.p99, scale_epochs, buckets_per_decade, stats.quantized_requests,
            stats.flow_solves,
        );
        Json::obj(vec![
            ("devices", Json::num(scale_devices as f64)),
            ("tiers", Json::num(num_tiers as f64)),
            ("sigma_buckets_per_decade", Json::num(buckets_per_decade as f64)),
            ("epochs", Json::num(scale_epochs as f64)),
            ("epoch_mean_s", Json::num(s.mean)),
            ("epoch_p50_s", Json::num(s.p50)),
            ("epoch_p99_s", Json::num(s.p99)),
            ("quantized_requests", Json::num(stats.quantized_requests as f64)),
            ("flow_solves", Json::num(stats.flow_solves as f64)),
        ])
    };

    if smoke {
        println!("smoke mode: skipping BENCH_PR2.json / BENCH_PR3.json / BENCH_PR8.json");
        return;
    }
    let out = std::env::var("FASTSPLIT_FLEET_OUT").unwrap_or_else(|_| "BENCH_PR2.json".into());
    if out != "-" && !rows.is_empty() {
        let doc = Json::obj(vec![
            ("bench", Json::str("fleet")),
            ("measured", Json::Bool(true)),
            (
                "note",
                Json::str(
                    "FleetPlanner::plan epoch decision over 10/100/1000-device fleets \
                     (googlenet, 4 deduplicated Jetson tiers, per-tier links); dirty = fresh \
                     links each epoch (refresh+solve per tier), clean = unchanged links \
                     (cache fan-out only)",
                ),
            ),
            ("results", Json::Arr(rows)),
        ]);
        match std::fs::write(&out, doc.pretty() + "\n") {
            Ok(()) => println!("wrote {out}"),
            Err(e) => eprintln!("could not write {out}: {e}"),
        }
    }
    let out = std::env::var("FASTSPLIT_FLEET_BLOCK_OUT")
        .unwrap_or_else(|_| "BENCH_PR3.json".into());
    if out != "-" && !block_rows.is_empty() {
        let doc = Json::obj(vec![
            ("bench", Json::str("fleet-block-reduction")),
            ("measured", Json::Bool(true)),
            (
                "note",
                Json::str(
                    "Dirty fleet epochs on block-structured models (100 devices, 4 Jetson \
                     tiers): fleet-level Theorem 2 reduction on (reduced DAG / linear scan \
                     for chain-reduced models) vs off (full general DAG); decisions \
                     cost-equivalent by the assert_cut_cost_equal gate",
                ),
            ),
            ("results", Json::Arr(block_rows)),
        ]);
        match std::fs::write(&out, doc.pretty() + "\n") {
            Ok(()) => println!("wrote {out}"),
            Err(e) => eprintln!("could not write {out}: {e}"),
        }
    }
    let out = std::env::var("FASTSPLIT_FLEET_SCALE_OUT")
        .unwrap_or_else(|_| "BENCH_PR8.json".into());
    if out != "-" {
        let doc = Json::obj(vec![
            ("bench", Json::str("fleet-scale")),
            ("measured", Json::Bool(true)),
            (
                "note",
                Json::str(
                    "Million-device epoch decisions: every device reports a distinct jittered \
                     link, sigma-quantization (8 buckets/decade) collapses the link set to \
                     per-tier bucket representatives before the solve; p50/p99 are per-epoch \
                     plan() latencies over the full batch",
                ),
            ),
            ("results", Json::Arr(vec![scale_row])),
        ]);
        match std::fs::write(&out, doc.pretty() + "\n") {
            Ok(()) => println!("wrote {out}"),
            Err(e) => eprintln!("could not write {out}: {e}"),
        }
    }
}
