//! Benchmark: max-flow solvers on partition networks of increasing size
//! (ablation ablB) plus scaling on synthetic layered graphs.
//!
//! `cargo bench --bench maxflow [-- filter] [--quick]`

use fastsplit::maxflow::{dinic, push_relabel, FlowNetwork};
use fastsplit::util::bench::Bencher;
use fastsplit::util::rng::Rng;

/// Layered random DAG flow network: `layers` x `width` grid with forward
/// edges, source feeding layer 0, sink fed by the last layer.
fn layered_network(layers: usize, width: usize, seed: u64) -> (FlowNetwork, usize, usize) {
    let mut rng = Rng::new(seed);
    let n = layers * width + 2;
    let s = n - 2;
    let t = n - 1;
    let mut net = FlowNetwork::new(n);
    for w in 0..width {
        net.add_edge(s, w, rng.range(1.0, 100.0));
        net.add_edge((layers - 1) * width + w, t, rng.range(1.0, 100.0));
    }
    for l in 0..layers - 1 {
        for a in 0..width {
            for b in 0..width {
                if rng.chance(0.5) {
                    net.add_edge(l * width + a, (l + 1) * width + b, rng.range(1.0, 100.0));
                }
            }
        }
    }
    (net, s, t)
}

fn main() {
    let mut b = Bencher::from_env();
    for (layers, width) in [(8usize, 4usize), (32, 8), (64, 16), (128, 16)] {
        let id = format!("layered/{layers}x{width}");
        let (proto, s, t) = layered_network(layers, width, 99);
        let mut net = proto.clone();
        b.bench(&format!("{id}/dinic"), || {
            net.reset();
            dinic(&mut net, s, t).value
        });
        let mut net2 = proto.clone();
        b.bench(&format!("{id}/push-relabel"), || {
            net2.reset();
            push_relabel(&mut net2, s, t).value
        });
    }
    // The real partition network of the deepest zoo model.
    {
        let m = fastsplit::models::by_name("densenet121").unwrap();
        let c = fastsplit::profiles::CostGraph::build(
            &m,
            &fastsplit::profiles::DeviceProfile::jetson_tx2(),
            &fastsplit::profiles::DeviceProfile::rtx_a6000(),
            &fastsplit::profiles::TrainCfg::default(),
        );
        let n = c.len();
        let mut net = FlowNetwork::new(n + 2);
        for v in 0..n {
            net.add_edge(n, v, c.n_loc * c.xi_s[v]);
            net.add_edge(v, n + 1, c.n_loc * c.xi_d[v] + c.param_bytes[v] * 2e-6);
        }
        for e in c.dag.edges() {
            net.add_edge(e.from, e.to, c.n_loc * c.act_bytes[e.from] * 2e-6);
        }
        b.bench("densenet121/dinic", || {
            net.reset();
            dinic(&mut net, n, n + 1).value
        });
        let mut net2 = net.clone();
        b.bench("densenet121/push-relabel", || {
            net2.reset();
            push_relabel(&mut net2, n, n + 1).value
        });
    }
    b.finish();
}
