//! Benchmark: PR-10 topology planners — K-segment splits over relay paths
//! (`PathPlanner::plan` at 2/3/4 hops) and device→server assignment over
//! multi-server fleets (`MultiServerPlanner::plan` at 2/4 servers), each
//! timed as the per-epoch decision under σ-drifting links.
//!
//! ```sh
//! cargo bench --bench multihop [-- filter] [--quick] [--smoke]
//! ```
//!
//! Correctness gates before timing (both seeded from PALLAS_TEST_SEED and
//! echoing base + derived seed on failure, the harness's replay-parity
//! contract): (1) on an enumerable chain model the K-segment plan matches
//! the brute-force nested-tuple oracle at 2 and 3 hops; (2) on a 3-device
//! fleet with two servers the assignment makespan matches the brute-force
//! assignment oracle. A full run writes `BENCH_PR10.json` (override with
//! `FASTSPLIT_MULTIHOP_OUT`, disable with `FASTSPLIT_MULTIHOP_OUT=-`);
//! `--smoke` is the CI fast mode: tiny windows, no JSON.

use fastsplit::partition::{
    oracle_multi_server_makespan, oracle_path_delay, FleetSpec, Link, MultiServerPlanner,
    PathPlanner, PathSpec, PlanRequest, Problem,
};
use fastsplit::profiles::{CostGraph, DeviceProfile, TrainCfg};
use fastsplit::util::bench::{BenchConfig, Bencher};
use fastsplit::util::json::Json;
use fastsplit::util::prop::{assert_fleet_cost_equal, fading_walk};
use fastsplit::util::rng::Rng;
use std::time::Duration;

const MODEL: &str = "googlenet";

fn costs_for(model: &str, device: &DeviceProfile) -> CostGraph {
    let m = fastsplit::models::by_name(model).unwrap();
    CostGraph::build(
        &m,
        device,
        &DeviceProfile::rtx_a6000(),
        &TrainCfg::default(),
    )
}

fn spec_for(model: &str, devices: usize) -> FleetSpec {
    let fleet = DeviceProfile::fleet_of(devices);
    FleetSpec::from_fleet(&fleet, |d| costs_for(model, d))
}

fn random_link(rng: &mut Rng) -> Link {
    Link {
        up_bps: rng.range(1e5, 1e7),
        down_bps: rng.range(1e5, 1e7),
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut b = if smoke {
        Bencher::with_config(BenchConfig {
            measure_time: Duration::from_millis(40),
            warmup_time: Duration::from_millis(10),
            max_samples: 200,
        })
    } else {
        Bencher::from_env()
    };

    let base_seed = fastsplit::util::rng::test_seed();

    // Gate 1: nested-tuple oracle pin for the path planner on a chain
    // model (small lower-set lattice, so the odometer is cheap).
    {
        let gate_seed = base_seed ^ 0x70_A7;
        let mut rng = Rng::new(gate_seed);
        let costs = costs_for("lenet5", &DeviceProfile::jetson_tx2());
        for hops in [2usize, 3] {
            let mut planner = PathPlanner::new(PathSpec::relayed(&costs, hops - 1));
            for draw in 0..2 {
                let links: Vec<Link> = (0..hops).map(|_| random_link(&mut rng)).collect();
                let plan = planner.plan(&links);
                let oracle = oracle_path_delay(planner.spec(), &links);
                assert_fleet_cost_equal(
                    plan.delay,
                    oracle,
                    &format!(
                        "bench gate {hops}-hop draw {draw} (gate seed {gate_seed}, \
                         base seed {base_seed}; replay with PALLAS_TEST_SEED={base_seed})"
                    ),
                );
            }
        }
    }

    // Gate 2: assignment-oracle pin for the multi-server planner on a
    // 3-device fleet with two unequal servers (8 assignments).
    {
        let gate_seed = base_seed ^ 0xA5_16;
        let mut rng = Rng::new(gate_seed);
        let spec = spec_for("block-residual", 3);
        let capacities = vec![0.6, 1.5];
        let mut planner = MultiServerPlanner::with_capacities(spec.clone(), capacities.clone());
        let links: Vec<Link> = (0..3).map(|_| random_link(&mut rng)).collect();
        let requests: Vec<PlanRequest> = (0..3)
            .map(|d| PlanRequest {
                device: d,
                tier: spec.tier_of(d),
                link: links[d],
            })
            .collect();
        let _ = planner.plan(&requests);
        let problems: Vec<Problem> = (0..3)
            .map(|d| Problem::new(spec.tier_costs(spec.tier_of(d)), links[d]))
            .collect();
        let oracle = oracle_multi_server_makespan(&problems, &capacities);
        assert_fleet_cost_equal(
            planner.makespan().unwrap(),
            oracle,
            &format!(
                "bench gate 2-server assignment (gate seed {gate_seed}, \
                 base seed {base_seed}; replay with PALLAS_TEST_SEED={base_seed})"
            ),
        );
    }

    let mut rows: Vec<Json> = Vec::new();

    // Sweep 1: per-epoch K-segment decisions at growing path lengths,
    // every hop's link σ-drifting per epoch.
    for hops in [2usize, 3, 4] {
        let costs = costs_for(MODEL, &DeviceProfile::jetson_tx2());
        let mut planner = PathPlanner::new(PathSpec::relayed(&costs, hops - 1));
        let mut rng = Rng::new(0x70_90 ^ hops as u64);
        let mut links: Vec<Link> = (0..hops)
            .map(|_| Link::symmetric(4e5 * hops as f64))
            .collect();
        let before = b.results().len();
        b.bench(&format!("multihop/{MODEL}/{hops}hop/epoch"), || {
            for l in links.iter_mut() {
                *l = fading_walk(&mut rng, *l, 1, 0.95, 1.05)[0];
            }
            planner.plan(&links)
        });
        let mean = (b.results().len() > before).then(|| b.results()[before].summary.mean);
        let s = planner.stats();
        assert!(
            planner.solves() > 0 && s.flow_solves + s.linear_scans > 0,
            "{hops}-hop sweep never solved a stage"
        );
        if let Some(mean) = mean {
            println!(
                "multihop/{hops}hop: {mean:.3e}s/epoch, {} plans, {} dp transitions",
                planner.solves(),
                s.dp_transitions,
            );
            rows.push(Json::obj(vec![
                ("sweep", Json::str("multihop")),
                ("hops", Json::num(hops as f64)),
                ("epoch_mean_s", Json::num(mean)),
                ("plans", Json::num(planner.solves() as f64)),
                ("dp_transitions", Json::num(s.dp_transitions as f64)),
            ]));
        }
    }

    // Sweep 2: per-epoch assignment decisions at growing server counts
    // over a 6-device fleet (2 servers enumerable, 4 servers local
    // search), per-tier links σ-drifting per epoch.
    for servers in [2usize, 4] {
        let devices = 6;
        let mut planner =
            MultiServerPlanner::with_capacities(spec_for(MODEL, devices), vec![0.5; servers]);
        let num_tiers = planner.spec().num_tiers();
        let mut rng = Rng::new(0xA5_90 ^ servers as u64);
        let mut tier_links: Vec<Link> = (0..num_tiers)
            .map(|t| Link::symmetric(3e5 * (1.0 + t as f64)))
            .collect();
        let before = b.results().len();
        b.bench(&format!("assign/{MODEL}/{devices}dev/{servers}srv/epoch"), || {
            for l in tier_links.iter_mut() {
                *l = fading_walk(&mut rng, *l, 1, 0.95, 1.05)[0];
            }
            let reqs = planner.spec().requests(|t| tier_links[t]);
            planner.plan(&reqs)
        });
        let mean = (b.results().len() > before).then(|| b.results()[before].summary.mean);
        let s = planner.stats();
        assert!(
            s.inner_makespan_solves > 0,
            "{servers}-server sweep never scored an assignment"
        );
        if let Some(mean) = mean {
            let plans = s.plans.max(1);
            println!(
                "assign/{servers}srv: {mean:.3e}s/epoch, {:.1} inner solves/epoch, \
                 {} assignment moves, makespan {:.3}s",
                s.inner_makespan_solves as f64 / plans as f64,
                s.assignment_moves,
                planner.makespan().unwrap_or(0.0),
            );
            rows.push(Json::obj(vec![
                ("sweep", Json::str("assign")),
                ("devices", Json::num(devices as f64)),
                ("servers", Json::num(servers as f64)),
                ("epoch_mean_s", Json::num(mean)),
                (
                    "inner_makespan_solves_per_epoch",
                    Json::num(s.inner_makespan_solves as f64 / plans as f64),
                ),
                ("assignment_moves", Json::num(s.assignment_moves as f64)),
                ("last_makespan_s", Json::num(planner.makespan().unwrap_or(0.0))),
            ]));
        }
    }
    b.finish();

    if smoke {
        println!("smoke mode: skipping BENCH_PR10.json");
        return;
    }
    let out =
        std::env::var("FASTSPLIT_MULTIHOP_OUT").unwrap_or_else(|_| "BENCH_PR10.json".into());
    if out != "-" && !rows.is_empty() {
        let doc = Json::obj(vec![
            ("bench", Json::str("multihop")),
            ("measured", Json::Bool(true)),
            (
                "note",
                Json::str(
                    "PR-10 topology planners: PathPlanner K-segment epoch decisions over \
                     2/3/4-hop relay ladders and MultiServerPlanner device→server assignment \
                     epochs over 2/4-server 6-device googlenet fleets, both under σ-drifting \
                     links; path plans oracle-gated against the nested-tuple odometer and \
                     assignment makespans against the brute-force assignment oracle before \
                     timing, with base + derived seeds echoed on failure",
                ),
            ),
            ("results", Json::Arr(rows)),
        ]);
        match std::fs::write(&out, doc.pretty() + "\n") {
            Ok(()) => println!("wrote {out}"),
            Err(e) => eprintln!("could not write {out}: {e}"),
        }
    }
}
