//! Benchmark: partitioning-algorithm running time (Fig. 9 / Table I).
//!
//! `cargo bench --bench algo_runtime [-- filter] [--quick]`

use fastsplit::models::{BLOCK_NETS, FULL_MODELS};
use fastsplit::partition::baselines::{brute_force_partition, regression_partition};
use fastsplit::partition::{blockwise_partition, general_partition, Link, Problem};
use fastsplit::profiles::{CostGraph, DeviceProfile, TrainCfg};
use fastsplit::util::bench::Bencher;

fn costs(model: &str) -> CostGraph {
    let m = fastsplit::models::by_name(model).unwrap();
    CostGraph::build(
        &m,
        &DeviceProfile::jetson_tx2(),
        &DeviceProfile::rtx_a6000(),
        &TrainCfg::default(),
    )
}

fn main() {
    let mut b = Bencher::from_env();
    // Fig. 9(a): block networks, all methods including brute force.
    for model in BLOCK_NETS {
        let c = costs(model);
        let p = Problem::new(&c, Link::symmetric(1e6));
        b.bench(&format!("fig9a/{model}/brute-force"), || {
            brute_force_partition(&p)
        });
        b.bench(&format!("fig9a/{model}/general"), || general_partition(&p));
        b.bench(&format!("fig9a/{model}/block-wise"), || {
            blockwise_partition(&p)
        });
        b.bench(&format!("fig9a/{model}/regression"), || {
            regression_partition(&p)
        });
    }
    // Fig. 9(b) / Table I: full models.
    for model in FULL_MODELS {
        let c = costs(model);
        let p = Problem::new(&c, Link::symmetric(1e6));
        b.bench(&format!("fig9b/{model}/general"), || general_partition(&p));
        b.bench(&format!("fig9b/{model}/block-wise"), || {
            blockwise_partition(&p)
        });
        b.bench(&format!("fig9b/{model}/regression"), || {
            regression_partition(&p)
        });
    }
    // GPT-2 (Fig. 14 decision cost).
    {
        let c = costs("gpt2");
        let p = Problem::new(&c, Link::symmetric(1e7));
        b.bench("gpt2/general", || general_partition(&p));
        b.bench("gpt2/block-wise", || blockwise_partition(&p));
    }
    // Amortized planner (the coordinator's actual per-epoch hot path):
    // structure + transformed network once, warm re-solve per link state.
    // See benches/replan.rs for the dedicated cold-vs-warm comparison.
    for model in ["googlenet", "densenet121", "gpt2"] {
        let c = costs(model);
        let mut planner = fastsplit::partition::blockwise::Planner::new(&c);
        let mut rate = 1e5;
        b.bench(&format!("planner/{model}/repartition"), || {
            rate = if rate > 1e8 { 1e5 } else { rate * 1.37 };
            planner.partition(Link::symmetric(rate))
        });
    }
    b.finish();
}
