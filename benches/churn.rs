//! Benchmark: churn-tolerant planning service throughput (PR 6) — epochs
//! per second of the [`PlannerService`] epoch loop (membership deltas →
//! link reports → one `plan_epoch` call) under 0% / 1% / 10% churn, where
//! the churn rate is both the per-epoch leave probability of each active
//! device and the per-epoch stale-report probability (withheld reports
//! degrade to the last-good decision under the strict staleness bound).
//!
//! ```sh
//! cargo bench --bench churn [-- filter] [--quick] [--smoke]
//! ```
//!
//! Writes epochs/sec and degraded-decision rates to `BENCH_PR6.json`
//! (override with `FASTSPLIT_CHURN_OUT`, disable with
//! `FASTSPLIT_CHURN_OUT=-`) so the perf trajectory is tracked in-repo
//! (see PERF.md). `--smoke` is the CI fast mode: one model, no JSON.

use fastsplit::daemon::metrics::{render_prometheus, service_metrics};
use fastsplit::models;
use fastsplit::partition::{
    FleetSpec, JointOptions, Link, PlannerService, ServiceOptions, SpecDelta,
};
use fastsplit::profiles::{CostGraph, DeviceProfile, TrainCfg};
use fastsplit::util::bench::{BenchConfig, Bencher};
use fastsplit::util::json::Json;
use fastsplit::util::rng::Rng;
use std::time::Duration;

const MODELS: &[&str] = &["googlenet", "block-residual"];
const DEVICES: usize = 8;

/// (label, per-epoch leave probability == stale-report probability).
const CHURN_LEVELS: &[(&str, f64)] = &[("0pct", 0.0), ("1pct", 0.01), ("10pct", 0.10)];

fn spec(model: &str) -> FleetSpec {
    let m = models::by_name(model).unwrap();
    let server = DeviceProfile::rtx_a6000();
    let fleet = DeviceProfile::fleet_of(DEVICES);
    FleetSpec::from_fleet(&fleet, |d| {
        CostGraph::build(&m, d, &server, &TrainCfg::default())
    })
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut b = if smoke {
        Bencher::with_config(BenchConfig {
            measure_time: Duration::from_millis(40),
            warmup_time: Duration::from_millis(10),
            max_samples: 200,
        })
    } else {
        Bencher::from_env()
    };
    let mut rows: Vec<Json> = Vec::new();
    let mut last_scrape: Option<(String, String)> = None;

    let models: &[&str] = if smoke { &["googlenet"] } else { MODELS };
    for model in models {
        for (mi, &(label, p)) in CHURN_LEVELS.iter().enumerate() {
            let mut service = PlannerService::new(
                spec(model),
                ServiceOptions {
                    staleness_bound: 0,
                    solve_budget: u64::MAX,
                    joint: JointOptions::default(),
                },
            );
            let mut rng = Rng::new(0xC4A05 ^ ((mi as u64) << 16));
            // Per-device fading walk of the reported/true uplink rate.
            let mut rates: Vec<f64> = (0..DEVICES).map(|_| rng.range(1e5, 1e6)).collect();
            let mut tick: u64 = 0;
            let mut decisions: u64 = 0;

            let before = b.results().len();
            b.bench(&format!("churn/{model}/{label}"), || {
                // Membership churn: active devices leave with probability
                // p (never emptying the fleet); departed slots re-join on
                // a random tier with probability 1/2.
                let n = service.spec().num_devices();
                if tick > 0 {
                    for d in 0..n {
                        if service.spec().tier_of_opt(d).is_some() {
                            if rng.chance(p) && service.spec().active_devices() > 1 {
                                service.apply_delta(&SpecDelta::RemoveDevice { device: d });
                            }
                        } else if rng.chance(0.5) {
                            let tier = rng.index(service.spec().num_tiers());
                            service.apply_delta(&SpecDelta::AddDevice { device: d, tier });
                        }
                    }
                }
                // Link reports: each active device's rate takes a ±10%
                // fading step; the report is withheld with probability p
                // (except on a device's first decided epoch, which must
                // bootstrap).
                for d in 0..n {
                    if service.spec().tier_of_opt(d).is_none() {
                        continue;
                    }
                    rates[d] = (rates[d] * rng.range(0.9, 1.1)).clamp(1e4, 1e9);
                    let first = service.last_good(d).is_none();
                    if tick == 0 || first || !rng.chance(p) {
                        let link = Link {
                            up_bps: rates[d],
                            down_bps: rates[d] * 2.0,
                        };
                        service.report(d, link, tick);
                    }
                }
                let out = service.plan_epoch(tick).expect("bench clock is monotone");
                decisions += out.len() as u64;
                tick += 1;
                out
            });
            if b.results().len() == before {
                continue; // `-- filter` skipped this case
            }
            let mean = b.results()[before].summary.mean;
            let epochs_per_sec = 1.0 / mean.max(1e-12);
            let s = service.stats();
            let degraded_rate = s.degraded_decisions as f64 / decisions.max(1) as f64;
            println!(
                "churn/{model}/{label}: {epochs_per_sec:.0} epochs/s, \
                 degraded {:.2}% of {decisions} decisions",
                degraded_rate * 100.0
            );
            rows.push(Json::obj(vec![
                ("model", Json::str(*model)),
                ("churn", Json::num(p)),
                ("devices", Json::num(DEVICES as f64)),
                ("mean_epoch_s", Json::num(mean)),
                ("epochs_per_sec", Json::num(epochs_per_sec)),
                ("decisions", Json::num(decisions as f64)),
                ("degraded_rate", Json::num(degraded_rate)),
                ("degraded_stale", Json::num(service.degraded_stale() as f64)),
                ("degraded_budget", Json::num(service.degraded_budget() as f64)),
                ("spec_deltas", Json::num(s.spec_deltas as f64)),
            ]));
            last_scrape = Some((
                format!("churn/{model}/{label}"),
                render_prometheus(&service_metrics(&service)),
            ));
        }
    }
    b.finish();

    // The scrape a daemon metrics endpoint would serve for the last case —
    // the PERF.md recipe greps counters straight out of the bench log.
    if let Some((case, scrape)) = &last_scrape {
        println!("--- metrics scrape after {case} ---");
        print!("{scrape}");
    }

    if smoke {
        println!("smoke mode: skipping BENCH_PR6.json");
        return;
    }
    let out = std::env::var("FASTSPLIT_CHURN_OUT").unwrap_or_else(|_| "BENCH_PR6.json".into());
    if out != "-" && !rows.is_empty() {
        let doc = Json::obj(vec![
            ("bench", Json::str("churn")),
            ("measured", Json::Bool(true)),
            (
                "note",
                Json::str(
                    "PlannerService epoch loop (deltas + reports + plan_epoch) over an \
                     8-device fleet; churn level = per-epoch leave prob = stale-report \
                     prob, strict staleness bound (0), re-joins at prob 1/2",
                ),
            ),
            ("results", Json::Arr(rows)),
        ]);
        match std::fs::write(&out, doc.pretty() + "\n") {
            Ok(()) => println!("wrote {out}"),
            Err(e) => eprintln!("could not write {out}: {e}"),
        }
    }
}
