//! Benchmark: end-to-end per-epoch coordination cost — link sampling +
//! partition decision + delay accounting (everything except the model
//! execution itself), i.e. the L3 hot path the coordinator runs every
//! epoch. Also benches the simulator's epoch loop for each method.
//!
//! `cargo bench --bench e2e_partition [-- filter] [--quick]`

use fastsplit::net::{EdgeNetwork, NetConfig};
use fastsplit::partition::{blockwise_partition, Problem};
use fastsplit::profiles::{CostGraph, DeviceProfile, TrainCfg};
use fastsplit::sim::{DelayBreakdown, SimConfig, Trainer};
use fastsplit::util::bench::Bencher;

fn main() {
    let mut b = Bencher::from_env();

    // Full per-epoch decision pipeline on the heaviest model.
    for model in ["googlenet", "densenet121", "gpt2"] {
        let m = fastsplit::models::by_name(model).unwrap();
        let costs = CostGraph::build(
            &m,
            &DeviceProfile::jetson_tx2(),
            &DeviceProfile::rtx_a6000(),
            &TrainCfg::default(),
        );
        let mut net = EdgeNetwork::new(NetConfig::default());
        let mut t = 0.0;
        b.bench(&format!("epoch-decision/{model}"), || {
            t += 1.0;
            let dev = net.select_device(t);
            let link = net.sample_link(dev, t).to_link();
            let p = Problem::new(&costs, link);
            let part = blockwise_partition(&p);
            let bd = DelayBreakdown::of(&p, &part.device_set);
            (part.delay, bd.total())
        });
        // The same pipeline on the amortized planner (what the coordinator
        // actually runs per epoch): warm re-solve instead of a full
        // block-detection + network rebuild.
        let mut planner = fastsplit::partition::blockwise::Planner::new(&costs);
        let mut t = 0.0;
        b.bench(&format!("epoch-decision-warm/{model}"), || {
            t += 1.0;
            let dev = net.select_device(t);
            let link = net.sample_link(dev, t).to_link();
            let p = Problem::new(&costs, link);
            let part = planner.partition(link);
            let bd = DelayBreakdown::of(&p, &part.device_set);
            (part.delay, bd.total())
        });
    }

    // Simulator epoch throughput per method (30-epoch chunks).
    for method in ["proposed", "oss", "regression"] {
        b.bench(&format!("sim-epochs30/{method}"), || {
            let mut trainer = Trainer::new(SimConfig {
                model: "googlenet".into(),
                method: method.to_string(),
                seed: 5,
                ..SimConfig::default()
            });
            trainer.run_epochs(30).total_delay
        });
    }
    b.finish();
}
