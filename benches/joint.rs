//! Benchmark: joint fleet partitioning under shared server capacity —
//! `JointPlanner::plan` epochs over 10/100-device GoogLeNet fleets at a
//! sweep of capacities, against the dedicated-server `FleetPlanner` epoch
//! as the baseline. The congested columns pay the makespan bisection ×
//! Dinkelbach price probes on top of the λ=1 pass; every probe must ride
//! the incremental (flow-reusing) path, asserted via the planner's own
//! counters before the numbers are trusted.
//!
//! ```sh
//! cargo bench --bench joint [-- filter] [--quick] [--smoke]
//! ```
//!
//! Correctness gates before timing: (1) on an exhaustively enumerable
//! 3-device fleet the joint makespan equals the brute-force oracle over
//! all cut combinations (`assert_fleet_cost_equal`); (2) with infinite
//! capacity the joint planner is bit-identical to the fleet engine,
//! stats included. A full run writes `BENCH_PR5.json` (override with
//! `FASTSPLIT_JOINT_OUT`, disable with `FASTSPLIT_JOINT_OUT=-`);
//! `--smoke` is the CI fast mode: small fleets, tiny windows, no JSON.

use fastsplit::partition::{
    oracle_fleet_makespan, FleetPlanner, FleetSpec, JointPlanner, Link, PlanRequest, Problem,
};
use fastsplit::profiles::{CostGraph, DeviceProfile, TrainCfg};
use fastsplit::util::bench::{BenchConfig, Bencher};
use fastsplit::util::json::Json;
use fastsplit::util::prop::{assert_fleet_cost_equal, fading_walk};
use fastsplit::util::rng::Rng;
use std::time::Duration;

const MODEL: &str = "googlenet";

fn costs_for(model: &str, device: &DeviceProfile) -> CostGraph {
    let m = fastsplit::models::by_name(model).unwrap();
    CostGraph::build(
        &m,
        device,
        &DeviceProfile::rtx_a6000(),
        &TrainCfg::default(),
    )
}

fn spec_for(model: &str, devices: usize) -> FleetSpec {
    let fleet = DeviceProfile::fleet_of(devices);
    FleetSpec::from_fleet(&fleet, |d| costs_for(model, d))
}

/// Deterministic per-(tier, epoch) link, mirroring `benches/fleet.rs`.
fn epoch_link(tier: usize, epoch: u64) -> Link {
    let phase = (epoch % 13 + 1) as f64;
    Link {
        up_bps: 2e5 * (1.0 + tier as f64) * phase,
        down_bps: 8e5 * (1.0 + tier as f64) * phase,
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut b = if smoke {
        Bencher::with_config(BenchConfig {
            measure_time: Duration::from_millis(40),
            warmup_time: Duration::from_millis(10),
            max_samples: 200,
        })
    } else {
        Bencher::from_env()
    };

    // Gate 1: oracle pin on an exhaustively enumerable 3-device fleet.
    // The gate seed derives from PALLAS_TEST_SEED (so CI's seed lanes
    // exercise distinct draws) and every failure message echoes both the
    // base and the derived seed — the replay-parity contract the test
    // harness (`util::prop`) already honors.
    {
        let spec = spec_for("block-residual", 3);
        let base_seed = fastsplit::util::rng::test_seed();
        let gate_seed = base_seed ^ 0x10_1A7;
        let mut rng = Rng::new(gate_seed);
        for capacity in [0.6, 1.2, 2.0] {
            let mut joint = JointPlanner::with_capacity(spec_for("block-residual", 3), capacity);
            let links: Vec<Link> = (0..3)
                .map(|_| Link {
                    up_bps: rng.range(1e5, 1e7),
                    down_bps: rng.range(1e5, 1e7),
                })
                .collect();
            let requests: Vec<PlanRequest> = (0..3)
                .map(|d| PlanRequest {
                    device: d,
                    tier: spec.tier_of(d),
                    link: links[d],
                })
                .collect();
            let _ = joint.plan(&requests);
            let problems: Vec<Problem> = (0..3)
                .map(|d| Problem::new(spec.tier_costs(spec.tier_of(d)), links[d]))
                .collect();
            let oracle = oracle_fleet_makespan(&problems, capacity);
            assert_fleet_cost_equal(
                joint.makespan().unwrap(),
                oracle,
                &format!(
                    "bench gate capacity {capacity} (gate seed {gate_seed}, \
                     base seed {base_seed}; replay with PALLAS_TEST_SEED={base_seed})"
                ),
            );
        }
    }

    // Gate 2: ∞-capacity bit-identity against the dedicated fleet engine.
    {
        let mut fleet = FleetPlanner::new(spec_for(MODEL, 20));
        let mut joint = JointPlanner::with_capacity(spec_for(MODEL, 20), f64::INFINITY);
        for epoch in 0..3u64 {
            let reqs = fleet.spec().requests(|t| epoch_link(t, epoch));
            let want = fleet.plan(&reqs);
            let got = joint.plan(&reqs);
            for (w, g) in want.iter().zip(&got) {
                assert_eq!(g.partition.device_set, w.partition.device_set);
                assert_eq!(g.partition.delay.to_bits(), w.partition.delay.to_bits());
            }
        }
        assert_eq!(joint.stats(), fleet.stats(), "∞-capacity counters diverged");
    }

    let fleet_sizes: &[usize] = if smoke { &[10] } else { &[10, 100] };
    let mut rows: Vec<Json> = Vec::new();
    for &n in fleet_sizes {
        // Capacity sweep: dedicated baseline (∞, delegates to the fleet
        // engine), lightly congested, and heavily congested.
        let sweeps: Vec<(&str, f64)> = vec![
            ("dedicated", f64::INFINITY),
            ("loose", n as f64 * 0.5),
            ("tight", (n as f64 * 0.08).max(0.5)),
        ];
        let mut sweep_results: Vec<(String, f64, Option<f64>, u64, u64, u64, f64)> = Vec::new();
        for (label, capacity) in sweeps {
            let mut planner = JointPlanner::with_capacity(spec_for(MODEL, n), capacity);
            let num_tiers = planner.spec().num_tiers();
            // σ-drift per epoch: every tier dirty every iteration — the
            // dynamic-edge case the warm joint re-solve targets.
            let mut rng = Rng::new(0x9E11 ^ n as u64);
            let mut tier_links: Vec<Link> =
                (0..num_tiers).map(|t| epoch_link(t, 0)).collect();
            let before = b.results().len();
            b.bench(&format!("joint/{MODEL}/{n}dev/epoch-{label}"), || {
                for l in tier_links.iter_mut() {
                    *l = fading_walk(&mut rng, *l, 1, 0.95, 1.05)[0];
                }
                let reqs = planner.spec().requests(|t| tier_links[t]);
                planner.plan(&reqs)
            });
            let mean = (b.results().len() > before).then(|| b.results()[before].summary.mean);
            let s = planner.stats();
            if capacity.is_finite() {
                assert!(
                    s.price_iterations > 0 && s.joint_resolves > 0,
                    "{label}: congested sweep never ran the price loop"
                );
                assert!(
                    s.incremental_solves > 0,
                    "{label}: price probes must reuse flow"
                );
            } else {
                assert_eq!(s.joint_resolves, 0, "dedicated sweep must not price");
            }
            if let Some(mean) = mean {
                let plans = s.plans.max(1);
                println!(
                    "joint/{n}dev/{label}: {mean:.3e}s/epoch, {:.1} probes/epoch, \
                     {:.1} price iters/epoch, makespan {:.3}s",
                    s.joint_resolves as f64 / plans as f64,
                    s.price_iterations as f64 / plans as f64,
                    planner.makespan().unwrap_or(0.0),
                );
                sweep_results.push((
                    label.to_string(),
                    capacity,
                    Some(mean),
                    s.joint_resolves,
                    s.price_iterations,
                    plans,
                    planner.makespan().unwrap_or(0.0),
                ));
            }
        }
        for (label, capacity, mean, probes, iters, plans, makespan) in sweep_results {
            if let Some(mean) = mean {
                rows.push(Json::obj(vec![
                    ("devices", Json::num(n as f64)),
                    ("capacity_label", Json::str(label)),
                    (
                        "server_capacity",
                        if capacity.is_finite() {
                            Json::num(capacity)
                        } else {
                            Json::str("inf")
                        },
                    ),
                    ("epoch_mean_s", Json::num(mean)),
                    (
                        "price_iterations_per_epoch",
                        Json::num(iters as f64 / plans as f64),
                    ),
                    (
                        "joint_resolves_per_epoch",
                        Json::num(probes as f64 / plans as f64),
                    ),
                    ("last_makespan_s", Json::num(makespan)),
                ]));
            }
        }
    }
    b.finish();

    if smoke {
        println!("smoke mode: skipping BENCH_PR5.json");
        return;
    }
    let out = std::env::var("FASTSPLIT_JOINT_OUT").unwrap_or_else(|_| "BENCH_PR5.json".into());
    if out != "-" && !rows.is_empty() {
        let doc = Json::obj(vec![
            ("bench", Json::str("joint")),
            ("measured", Json::Bool(true)),
            (
                "note",
                Json::str(
                    "JointPlanner::plan epoch decisions over 10/100-device googlenet fleets \
                     under σ-drifting per-tier links, at a server-capacity sweep (dedicated ∞ \
                     baseline vs loosely/heavily congested); joint makespans oracle-gated on a \
                     3-device block-residual fleet and ∞-capacity pinned bit-identical to \
                     FleetPlanner before timing; price probes FleetStats-verified to reuse flow",
                ),
            ),
            ("results", Json::Arr(rows)),
        ]);
        match std::fs::write(&out, doc.pretty() + "\n") {
            Ok(()) => println!("wrote {out}"),
            Err(e) => eprintln!("could not write {out}: {e}"),
        }
    }
}
