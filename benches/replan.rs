//! Benchmark: amortized re-partitioning — cold network rebuild
//! (`general_partition`) vs warm capacity-refresh re-solve
//! (`PartitionPlanner`) across the model zoo, over the same cycling link
//! trace. This is the dynamic-edge hot path: the coordinator re-makes the
//! decision every epoch while only the link rates change.
//!
//! ```sh
//! cargo bench --bench replan [-- filter] [--quick]
//! ```
//!
//! Writes the cold/warm means and speedups to `BENCH_PR1.json` (override
//! with `FASTSPLIT_REPLAN_OUT`, disable with `FASTSPLIT_REPLAN_OUT=-`) so
//! the perf trajectory is tracked in-repo (see PERF.md).

use fastsplit::partition::{general_partition, Link, PartitionPlanner, Problem};
use fastsplit::profiles::{CostGraph, DeviceProfile, TrainCfg};
use fastsplit::util::bench::Bencher;
use fastsplit::util::json::Json;

const MODELS: &[&str] = &[
    "resnet18",
    "resnet50",
    "googlenet",
    "densenet121",
    "gpt2",
    "block-inception",
];

fn costs(model: &str) -> CostGraph {
    let m = fastsplit::models::by_name(model).unwrap();
    CostGraph::build(
        &m,
        &DeviceProfile::jetson_tx2(),
        &DeviceProfile::rtx_a6000(),
        &TrainCfg::default(),
    )
}

/// Deterministic fading-like link trace shared by the cold and warm runs.
fn link_trace() -> Vec<Link> {
    let mut links = Vec::with_capacity(64);
    let mut rate = 1e5_f64;
    for i in 0..64 {
        rate = if rate > 1e8 { 1e5 } else { rate * 1.31 };
        links.push(Link {
            up_bps: rate,
            down_bps: rate * (1.0 + (i % 4) as f64),
        });
    }
    links
}

fn main() {
    let mut b = Bencher::from_env();
    let links = link_trace();
    let mut rows: Vec<Json> = Vec::new();

    for model in MODELS {
        let c = costs(model);

        // Correctness gate before timing: warm must equal cold on the trace.
        let mut check = PartitionPlanner::new(&c);
        for &link in &links {
            let cold = general_partition(&Problem::new(&c, link));
            let warm = check.partition(link);
            assert_eq!(
                warm.device_set, cold.device_set,
                "{model}: warm replan diverged from cold rebuild"
            );
        }

        // Guard against `-- filter` skipping a side: only read a result row
        // if the bench call actually appended one.
        let before = b.results().len();
        let mut i = 0;
        b.bench(&format!("replan/{model}/cold-rebuild"), || {
            i = (i + 1) % links.len();
            general_partition(&Problem::new(&c, links[i]))
        });
        let cold = (b.results().len() > before).then(|| b.results()[before].summary.mean);

        let mut planner = PartitionPlanner::new(&c);
        let before = b.results().len();
        let mut i = 0;
        b.bench(&format!("replan/{model}/warm-refresh"), || {
            i = (i + 1) % links.len();
            planner.partition(links[i])
        });
        let warm = (b.results().len() > before).then(|| b.results()[before].summary.mean);

        if let (Some(cold), Some(warm)) = (cold, warm) {
            let speedup = cold / warm.max(1e-12);
            println!("replan/{model}: cold/warm speedup {speedup:.1}x");
            let (fv, fe) = planner.flow_size().unwrap_or((0, 0));
            rows.push(Json::obj(vec![
                ("model", Json::str(*model)),
                ("cold_rebuild_mean_s", Json::num(cold)),
                ("warm_refresh_mean_s", Json::num(warm)),
                ("speedup", Json::num(speedup)),
                ("flow_vertices", Json::num(fv as f64)),
                ("flow_edges", Json::num(fe as f64)),
            ]));
        }
    }
    b.finish();

    let out = std::env::var("FASTSPLIT_REPLAN_OUT").unwrap_or_else(|_| "BENCH_PR1.json".into());
    if out == "-" || rows.is_empty() {
        return;
    }
    let doc = Json::obj(vec![
        ("bench", Json::str("replan")),
        ("measured", Json::Bool(true)),
        (
            "note",
            Json::str("cold general_partition rebuild vs PartitionPlanner warm refresh, 64-link trace"),
        ),
        ("results", Json::Arr(rows)),
    ]);
    match std::fs::write(&out, doc.pretty() + "\n") {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
}
