//! Benchmark: amortized re-partitioning — cold network rebuild
//! (`general_partition`) vs warm capacity-refresh re-solve
//! (`PartitionPlanner`) across the model zoo, over the same cycling link
//! trace. This is the dynamic-edge hot path: the coordinator re-makes the
//! decision every epoch while only the link rates change.
//!
//! A second sweep (PR 4) times the **incremental** flow-reusing re-solve
//! (GGT-style repair + residual augmentation, `FleetOptions::incremental`)
//! against the warm-full re-solve and the cold rebuild, over σ-delta
//! traces of three shapes: small monotone drift, large monotone drift,
//! and hard random jumps. Decisions are cost-equivalence-gated against
//! cold solves before timing, and the planner's own counters must prove
//! every timed solve after the first actually reused flow.
//!
//! ```sh
//! cargo bench --bench replan [-- filter] [--quick] [--smoke]
//! ```
//!
//! Writes the cold/warm means and speedups to `BENCH_PR1.json` (override
//! with `FASTSPLIT_REPLAN_OUT`, disable with `FASTSPLIT_REPLAN_OUT=-`)
//! and the incremental sweep to `BENCH_PR4.json` (`FASTSPLIT_REPLAN4_OUT`
//! likewise) so the perf trajectory is tracked in-repo (see PERF.md).
//! `--smoke` is the CI fast mode: one model, short traces, no JSON.

use fastsplit::partition::{
    general_partition, FleetOptions, FleetPlanner, FleetSpec, Link, PartitionPlanner, Problem,
};
use fastsplit::profiles::{CostGraph, DeviceProfile, TrainCfg};
use fastsplit::util::bench::{BenchConfig, Bencher};
use fastsplit::util::json::Json;
use fastsplit::util::prop::{assert_cut_cost_equal, fading_walk};
use fastsplit::util::rng::Rng;
use std::time::Duration;

const MODELS: &[&str] = &[
    "resnet18",
    "resnet50",
    "googlenet",
    "densenet121",
    "gpt2",
    "block-inception",
];

/// Models of the PR-4 incremental sweep: branched full DAGs, so the
/// unreduced engine (the comparison's level ground) stays on the flow
/// path for all three columns.
const INCREMENTAL_MODELS: &[&str] = &["googlenet", "resnet18", "gpt2"];

fn costs(model: &str) -> CostGraph {
    let m = fastsplit::models::by_name(model).unwrap();
    CostGraph::build(
        &m,
        &DeviceProfile::jetson_tx2(),
        &DeviceProfile::rtx_a6000(),
        &TrainCfg::default(),
    )
}

/// Deterministic fading-like link trace shared by the cold and warm runs.
fn link_trace() -> Vec<Link> {
    let mut links = Vec::with_capacity(64);
    let mut rate = 1e5_f64;
    for i in 0..64 {
        rate = if rate > 1e8 { 1e5 } else { rate * 1.31 };
        links.push(Link {
            up_bps: rate,
            down_bps: rate * (1.0 + (i % 4) as f64),
        });
    }
    links
}

/// One σ-delta trace shape of the incremental sweep. Drift traces are
/// monotone per half (σ first grows — rates fade — then shrinks back),
/// so both the pure-augmentation and the repair direction are timed;
/// jump traces redraw the link uniformly at random every step. Starts
/// and factor ranges are chosen so drift walks stay strictly inside the
/// 1e4..1e9 B/s regime even at the factor extremes — a clamped rate
/// would repeat links and make the "incremental" column time no-op
/// refreshes (see `fading_walk`'s clamp caveat).
fn sigma_trace(kind: &str, steps: usize, seed: u64) -> Vec<Link> {
    let mut rng = Rng::new(seed);
    let half = steps / 2;
    match kind {
        "drift-small" => {
            // Worst case over 32 steps: x0.96^32 ≈ 0.27, x1.04^32 ≈ 3.5.
            let start = Link {
                up_bps: 2e6,
                down_bps: 6e6,
            };
            let mut links = fading_walk(&mut rng, start, half, 0.96, 0.995);
            let mid = *links.last().unwrap();
            links.extend(fading_walk(&mut rng, mid, steps - half, 1.005, 1.04));
            links
        }
        "drift-large" => {
            // Worst case over 32 steps: x0.8^32 ≈ 7.9e-4 of 3e7 ≈ 2.4e4
            // (above the 1e4 floor); the recovery half starts from the
            // faded midpoint (≤ 9e7·0.95^32 ≈ 1.8e7) and x1.13^32 ≈ 50
            // keeps even that below the 1e9 ceiling.
            let start = Link {
                up_bps: 3e7,
                down_bps: 9e7,
            };
            let mut links = fading_walk(&mut rng, start, half, 0.8, 0.95);
            let mid = *links.last().unwrap();
            links.extend(fading_walk(&mut rng, mid, steps - half, 1.05, 1.13));
            links
        }
        "jump" => (0..steps)
            .map(|_| Link {
                up_bps: rng.range(1e4, 1e9),
                down_bps: rng.range(1e4, 1e9),
            })
            .collect(),
        other => unreachable!("unknown trace kind {other}"),
    }
}

/// A fresh single-tier incremental planner on the unreduced DAG — the
/// same flow problem `PartitionPlanner` and `general_partition` solve,
/// so the three columns differ only in how much work they reuse.
fn incremental_planner(c: &CostGraph) -> FleetPlanner {
    FleetPlanner::with_options(
        FleetSpec::single(c.clone()),
        FleetOptions {
            block_reduction: false,
            ..FleetOptions::default()
        },
    )
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut b = if smoke {
        Bencher::with_config(BenchConfig {
            measure_time: Duration::from_millis(40),
            warmup_time: Duration::from_millis(10),
            max_samples: 200,
        })
    } else {
        Bencher::from_env()
    };
    let links = link_trace();
    let mut rows: Vec<Json> = Vec::new();

    let models: &[&str] = if smoke { &["googlenet"] } else { MODELS };
    for model in models {
        let c = costs(model);

        // Correctness gate before timing: warm must equal cold on the trace.
        let mut check = PartitionPlanner::new(&c);
        for &link in &links {
            let cold = general_partition(&Problem::new(&c, link));
            let warm = check.partition(link);
            assert_eq!(
                warm.device_set, cold.device_set,
                "{model}: warm replan diverged from cold rebuild"
            );
        }

        // Guard against `-- filter` skipping a side: only read a result row
        // if the bench call actually appended one.
        let before = b.results().len();
        let mut i = 0;
        b.bench(&format!("replan/{model}/cold-rebuild"), || {
            i = (i + 1) % links.len();
            general_partition(&Problem::new(&c, links[i]))
        });
        let cold = (b.results().len() > before).then(|| b.results()[before].summary.mean);

        let mut planner = PartitionPlanner::new(&c);
        let before = b.results().len();
        let mut i = 0;
        b.bench(&format!("replan/{model}/warm-refresh"), || {
            i = (i + 1) % links.len();
            planner.partition(links[i])
        });
        let warm = (b.results().len() > before).then(|| b.results()[before].summary.mean);

        if let (Some(cold), Some(warm)) = (cold, warm) {
            let speedup = cold / warm.max(1e-12);
            println!("replan/{model}: cold/warm speedup {speedup:.1}x");
            let (fv, fe) = planner.flow_size().unwrap_or((0, 0));
            rows.push(Json::obj(vec![
                ("model", Json::str(*model)),
                ("cold_rebuild_mean_s", Json::num(cold)),
                ("warm_refresh_mean_s", Json::num(warm)),
                ("speedup", Json::num(speedup)),
                ("flow_vertices", Json::num(fv as f64)),
                ("flow_edges", Json::num(fe as f64)),
            ]));
        }
    }

    // PR-4 sweep: incremental (flow-reusing) vs warm-full vs cold, over
    // small-drift / large-drift / jump σ traces.
    let inc_models: &[&str] = if smoke { &["googlenet"] } else { INCREMENTAL_MODELS };
    let trace_steps = if smoke { 16 } else { 64 };
    let mut inc_rows: Vec<Json> = Vec::new();
    for model in inc_models {
        let c = costs(model);
        for (ki, kind) in ["drift-small", "drift-large", "jump"].into_iter().enumerate() {
            let trace = sigma_trace(kind, trace_steps, 0x9E11_0000 + ki as u64);

            // Correctness gate: every incremental decision on the trace
            // must be cost-equivalent to a cold solve, and every solve
            // after the first must actually have reused the flow.
            let mut gate = incremental_planner(&c);
            for &link in &trace {
                let p = Problem::new(&c, link);
                let inc = gate.take_solve(0, link);
                let cold = general_partition(&p);
                assert_cut_cost_equal(&p, &inc, &cold);
            }
            let gs = gate.stats();
            assert_eq!(
                gs.incremental_solves,
                gs.flow_solves - 1,
                "{model}/{kind}: a non-first solve fell back to cold"
            );
            assert_eq!(
                gs.fallback_cold_solves, 0,
                "{model}/{kind}: the incremental repair dead-ended (fallback_cold_solves)"
            );

            let before = b.results().len();
            let mut i = 0;
            b.bench(&format!("replan4/{model}/{kind}/cold-rebuild"), || {
                i = (i + 1) % trace.len();
                general_partition(&Problem::new(&c, trace[i]))
            });
            let cold = (b.results().len() > before).then(|| b.results()[before].summary.mean);

            let mut warm_planner = PartitionPlanner::new(&c);
            let before = b.results().len();
            let mut i = 0;
            b.bench(&format!("replan4/{model}/{kind}/warm-full"), || {
                i = (i + 1) % trace.len();
                warm_planner.partition(trace[i])
            });
            let warm = (b.results().len() > before).then(|| b.results()[before].summary.mean);

            let mut inc_planner = incremental_planner(&c);
            let before = b.results().len();
            let mut i = 0;
            b.bench(&format!("replan4/{model}/{kind}/incremental"), || {
                i = (i + 1) % trace.len();
                inc_planner.take_solve(0, trace[i])
            });
            let inc = (b.results().len() > before).then(|| b.results()[before].summary.mean);

            if let (Some(cold), Some(warm), Some(inc)) = (cold, warm, inc) {
                let s = inc_planner.stats();
                assert!(
                    s.incremental_solves > 0,
                    "{model}/{kind}: timed run never took the incremental path"
                );
                let solves = s.flow_solves.max(1) as f64;
                println!(
                    "replan4/{model}/{kind}: cold {cold:.3e}s, warm-full {warm:.3e}s, \
                     incremental {inc:.3e}s ({:.1}x vs warm, {:.1}x vs cold)",
                    warm / inc.max(1e-12),
                    cold / inc.max(1e-12),
                );
                inc_rows.push(Json::obj(vec![
                    ("model", Json::str(*model)),
                    ("trace", Json::str(kind)),
                    ("steps", Json::num(trace.len() as f64)),
                    ("cold_rebuild_mean_s", Json::num(cold)),
                    ("warm_full_mean_s", Json::num(warm)),
                    ("incremental_mean_s", Json::num(inc)),
                    ("speedup_vs_cold", Json::num(cold / inc.max(1e-12))),
                    ("speedup_vs_warm_full", Json::num(warm / inc.max(1e-12))),
                    (
                        "repair_pushes_per_solve",
                        Json::num(s.repair_pushes as f64 / solves),
                    ),
                    (
                        "augment_rounds_per_solve",
                        Json::num(s.augment_rounds as f64 / solves),
                    ),
                    (
                        "fallback_cold_solves",
                        Json::num(s.fallback_cold_solves as f64),
                    ),
                ]));
            }
        }
    }
    b.finish();

    if smoke {
        println!("smoke mode: skipping BENCH_PR1.json / BENCH_PR4.json");
        return;
    }
    let out = std::env::var("FASTSPLIT_REPLAN_OUT").unwrap_or_else(|_| "BENCH_PR1.json".into());
    if out != "-" && !rows.is_empty() {
        let doc = Json::obj(vec![
            ("bench", Json::str("replan")),
            ("measured", Json::Bool(true)),
            (
                "note",
                Json::str("cold general_partition rebuild vs PartitionPlanner warm refresh, 64-link trace"),
            ),
            ("results", Json::Arr(rows)),
        ]);
        match std::fs::write(&out, doc.pretty() + "\n") {
            Ok(()) => println!("wrote {out}"),
            Err(e) => eprintln!("could not write {out}: {e}"),
        }
    }
    let out = std::env::var("FASTSPLIT_REPLAN4_OUT").unwrap_or_else(|_| "BENCH_PR4.json".into());
    if out != "-" && !inc_rows.is_empty() {
        let doc = Json::obj(vec![
            ("bench", Json::str("replan-incremental")),
            ("measured", Json::Bool(true)),
            (
                "note",
                Json::str(
                    "incremental (GGT-style flow-reusing) re-solve vs warm-full refresh \
                     (PartitionPlanner) vs cold rebuild (general_partition), unreduced DAGs, \
                     64-step σ traces (small/large monotone drift + random jumps); decisions \
                     cost-equivalence-gated and FleetStats-verified before timing",
                ),
            ),
            ("results", Json::Arr(inc_rows)),
        ]);
        match std::fs::write(&out, doc.pretty() + "\n") {
            Ok(()) => println!("wrote {out}"),
            Err(e) => eprintln!("could not write {out}: {e}"),
        }
    }
}
