//! Benchmark: planner daemon loop (PR 7) — ingest throughput of the
//! coalescing event channel and end-to-end tick latency of the
//! wheel-scheduled epoch loop (churn deltas + link reports → pump →
//! planned epoch) under 0% / 1% / 10% churn, with report leases armed.
//!
//! ```sh
//! cargo bench --bench daemon [-- filter] [--quick] [--smoke]
//! ```
//!
//! Writes ticks/sec, ingest events/sec and degraded-decision rates to
//! `BENCH_PR7.json` (override with `FASTSPLIT_DAEMON_OUT`, disable with
//! `FASTSPLIT_DAEMON_OUT=-`) so the perf trajectory is tracked in-repo
//! (see PERF.md). `--smoke` is the CI fast mode: one model, no JSON.

use fastsplit::daemon::{DaemonConfig, DaemonEvent, PlannerDaemon, SimClock};
use fastsplit::models;
use fastsplit::partition::{
    DecisionProvenance, FleetSpec, JointOptions, Link, ServiceOptions, SpecDelta,
};
use fastsplit::profiles::{CostGraph, DeviceProfile, TrainCfg};
use fastsplit::util::bench::{BenchConfig, Bencher};
use fastsplit::util::json::Json;
use fastsplit::util::rng::Rng;
use std::sync::Arc;
use std::time::Duration;

const MODELS: &[&str] = &["googlenet", "block-residual"];
const DEVICES: usize = 8;

/// (label, per-tick leave probability == withheld-report probability).
const CHURN_LEVELS: &[(&str, f64)] = &[("0pct", 0.0), ("1pct", 0.01), ("10pct", 0.10)];

/// Reports handed to the ingest channel per iteration of the ingest bench.
const INGEST_BATCH: usize = 64;

fn spec(model: &str) -> FleetSpec {
    let m = models::by_name(model).unwrap();
    let server = DeviceProfile::rtx_a6000();
    let fleet = DeviceProfile::fleet_of(DEVICES);
    FleetSpec::from_fleet(&fleet, |d| {
        CostGraph::build(&m, d, &server, &TrainCfg::default())
    })
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut b = if smoke {
        Bencher::with_config(BenchConfig {
            measure_time: Duration::from_millis(40),
            warmup_time: Duration::from_millis(10),
            max_samples: 200,
        })
    } else {
        Bencher::from_env()
    };
    let mut rows: Vec<Json> = Vec::new();

    let models: &[&str] = if smoke { &["googlenet"] } else { MODELS };

    // Ingest throughput: a batch of reports down the channel, synced by a
    // pump round-trip (the wheel is idle — nothing fires — so the reply
    // bounds exactly channel + coalescing work).
    for model in models {
        let clock = SimClock::new(0);
        let daemon = PlannerDaemon::spawn(
            spec(model),
            DaemonConfig {
                replan_every: 1 << 40, // never fires during the bench
                ..DaemonConfig::default()
            },
            Arc::new(clock.clone()),
        );
        let sender = daemon.sender();
        let mut rng = Rng::new(0xDAE7 ^ 1);
        let mut rates: Vec<f64> = (0..DEVICES).map(|_| rng.range(1e5, 1e6)).collect();

        let before = b.results().len();
        b.bench(&format!("daemon/ingest/{model}"), || {
            for i in 0..INGEST_BATCH {
                let d = i % DEVICES;
                rates[d] = (rates[d] * rng.range(0.9, 1.1)).clamp(1e4, 1e9);
                let _ = sender.send(DaemonEvent::Report {
                    device: d,
                    link: Link {
                        up_bps: rates[d],
                        down_bps: rates[d] * 2.0,
                    },
                    tick: 0,
                });
            }
            daemon.pump()
        });
        if b.results().len() > before {
            let mean = b.results()[before].summary.mean;
            let events_per_sec = INGEST_BATCH as f64 / mean.max(1e-12);
            println!("daemon/ingest/{model}: {events_per_sec:.0} events/s");
            rows.push(Json::obj(vec![
                ("case", Json::str("ingest")),
                ("model", Json::str(*model)),
                ("devices", Json::num(DEVICES as f64)),
                ("batch", Json::num(INGEST_BATCH as f64)),
                ("mean_batch_s", Json::num(mean)),
                ("events_per_sec", Json::num(events_per_sec)),
            ]));
        }
        daemon.shutdown();
    }

    // Tick latency: one full daemon tick — churn deltas + reports down
    // the channel, the clock advances, a pump fires the scheduled re-plan
    // (and any lease expiries) and plans the epoch.
    for model in models {
        for (mi, &(label, p)) in CHURN_LEVELS.iter().enumerate() {
            let clock = SimClock::new(0);
            let daemon = PlannerDaemon::spawn(
                spec(model),
                DaemonConfig {
                    replan_every: 1,
                    lease_ttl: Some(4),
                    service: ServiceOptions {
                        staleness_bound: 0,
                        solve_budget: u64::MAX,
                        joint: JointOptions::default(),
                    },
                    ..DaemonConfig::default()
                },
                Arc::new(clock.clone()),
            );
            let sender = daemon.sender();
            let mut rng = Rng::new(0xDAE7 ^ ((mi as u64) << 16));
            let mut rates: Vec<f64> = (0..DEVICES).map(|_| rng.range(1e5, 1e6)).collect();
            // Local membership mirror so generated deltas stay valid
            // without a spec round-trip per event.
            let mut active = vec![true; DEVICES];
            let mut bootstrapped = vec![false; DEVICES];
            let mut tick: u64 = 0;
            let mut decisions: u64 = 0;
            let mut degraded: u64 = 0;

            let before = b.results().len();
            b.bench(&format!("daemon/tick/{model}/{label}"), || {
                tick += 1;
                clock.set(tick);
                // Membership churn: active devices leave with probability
                // p (never emptying the fleet); departed slots re-join on
                // a random tier with probability 1/2.
                for d in 0..DEVICES {
                    if active[d] {
                        if rng.chance(p) && active.iter().filter(|&&a| a).count() > 1 {
                            let _ = sender.send(DaemonEvent::Delta(SpecDelta::RemoveDevice {
                                device: d,
                            }));
                            active[d] = false;
                            bootstrapped[d] = false;
                        }
                    } else if rng.chance(0.5) {
                        let tier = rng.index(4);
                        let _ = sender.send(DaemonEvent::Delta(SpecDelta::AddDevice {
                            device: d,
                            tier,
                        }));
                        active[d] = true;
                    }
                }
                // Link reports: ±10% fading walk, withheld with
                // probability p (except a device's bootstrap epoch).
                for d in 0..DEVICES {
                    if !active[d] {
                        continue;
                    }
                    rates[d] = (rates[d] * rng.range(0.9, 1.1)).clamp(1e4, 1e9);
                    if !bootstrapped[d] || !rng.chance(p) {
                        let _ = sender.send(DaemonEvent::Report {
                            device: d,
                            link: Link {
                                up_bps: rates[d],
                                down_bps: rates[d] * 2.0,
                            },
                            tick,
                        });
                        bootstrapped[d] = true;
                    }
                }
                let report = daemon.pump();
                for epoch in &report.epochs {
                    decisions += epoch.decisions.len() as u64;
                    degraded += epoch
                        .decisions
                        .iter()
                        .filter(|d| matches!(d.provenance, DecisionProvenance::Degraded(_)))
                        .count() as u64;
                }
                report
            });
            if b.results().len() == before {
                daemon.shutdown();
                continue; // `-- filter` skipped this case
            }
            let mean = b.results()[before].summary.mean;
            let ticks_per_sec = 1.0 / mean.max(1e-12);
            let counters = daemon.counters();
            let degraded_rate = degraded as f64 / decisions.max(1) as f64;
            println!(
                "daemon/tick/{model}/{label}: {ticks_per_sec:.0} ticks/s, \
                 degraded {:.2}% of {decisions} decisions, \
                 {} lease expiries",
                degraded_rate * 100.0,
                counters.lease_expiries,
            );
            rows.push(Json::obj(vec![
                ("case", Json::str("tick")),
                ("model", Json::str(*model)),
                ("churn", Json::num(p)),
                ("devices", Json::num(DEVICES as f64)),
                ("mean_tick_s", Json::num(mean)),
                ("ticks_per_sec", Json::num(ticks_per_sec)),
                ("decisions", Json::num(decisions as f64)),
                ("degraded_rate", Json::num(degraded_rate)),
                ("events_ingested", Json::num(counters.events_ingested as f64)),
                ("coalesced_deltas", Json::num(counters.coalesced_deltas as f64)),
                ("lease_expiries", Json::num(counters.lease_expiries as f64)),
            ]));
            daemon.shutdown();
        }
    }

    // PR 9: journal overhead + crash-recovery latency. The overhead pair
    // runs an identical reports-only tick loop with durability off and
    // on (default snapshot cadence, so rotation cost is amortized in);
    // the recovery case replays a 32-tick journaled run left dirty by a
    // simulated crash.
    let mut rows9: Vec<Json> = Vec::new();
    for model in models {
        for journal in [false, true] {
            let dir = std::env::temp_dir().join(format!(
                "fastsplit-bench-journal-{}-{model}-{}",
                std::process::id(),
                if journal { "on" } else { "off" },
            ));
            let _ = std::fs::remove_dir_all(&dir);
            let clock = SimClock::new(0);
            let daemon = PlannerDaemon::spawn(
                spec(model),
                DaemonConfig {
                    replan_every: 1,
                    lease_ttl: Some(4),
                    journal_dir: journal.then(|| dir.clone()),
                    ..DaemonConfig::default()
                },
                Arc::new(clock.clone()),
            );
            let sender = daemon.sender();
            let mut rng = Rng::new(0xDAE7 ^ 9);
            let mut rates: Vec<f64> = (0..DEVICES).map(|_| rng.range(1e5, 1e6)).collect();
            let mut tick: u64 = 0;
            let label = if journal { "on" } else { "off" };
            let before = b.results().len();
            b.bench(&format!("daemon/journal-{label}/{model}"), || {
                tick += 1;
                clock.set(tick);
                for d in 0..DEVICES {
                    rates[d] = (rates[d] * rng.range(0.9, 1.1)).clamp(1e4, 1e9);
                    let _ = sender.send(DaemonEvent::Report {
                        device: d,
                        link: Link {
                            up_bps: rates[d],
                            down_bps: rates[d] * 2.0,
                        },
                        tick,
                    });
                }
                daemon.pump()
            });
            if b.results().len() > before {
                let mean = b.results()[before].summary.mean;
                let ticks_per_sec = 1.0 / mean.max(1e-12);
                println!("daemon/journal-{label}/{model}: {ticks_per_sec:.0} ticks/s");
                rows9.push(Json::obj(vec![
                    ("case", Json::str("tick")),
                    ("model", Json::str(*model)),
                    ("journal", Json::Bool(journal)),
                    ("devices", Json::num(DEVICES as f64)),
                    ("mean_tick_s", Json::num(mean)),
                    ("ticks_per_sec", Json::num(ticks_per_sec)),
                ]));
            }
            daemon.shutdown();
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
    for model in models {
        const RECOVERY_TICKS: u64 = 32;
        let dir = std::env::temp_dir().join(format!(
            "fastsplit-bench-recover-{}-{model}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let clock = SimClock::new(0);
        let daemon = PlannerDaemon::spawn(
            spec(model),
            DaemonConfig {
                replan_every: 1,
                lease_ttl: Some(4),
                journal_dir: Some(dir.clone()),
                snapshot_every: u64::MAX, // the whole run replays from one file
                ..DaemonConfig::default()
            },
            Arc::new(clock.clone()),
        );
        let sender = daemon.sender();
        let mut rng = Rng::new(0xDAE7 ^ 10);
        let mut rates: Vec<f64> = (0..DEVICES).map(|_| rng.range(1e5, 1e6)).collect();
        for tick in 1..=RECOVERY_TICKS {
            clock.set(tick);
            for d in 0..DEVICES {
                rates[d] = (rates[d] * rng.range(0.9, 1.1)).clamp(1e4, 1e9);
                let _ = sender.send(DaemonEvent::Report {
                    device: d,
                    link: Link {
                        up_bps: rates[d],
                        down_bps: rates[d] * 2.0,
                    },
                    tick,
                });
            }
            daemon.pump();
        }
        daemon.abandon(); // a crash: no drain frame, recovery replays everything
        let mut replayed: u64 = 0;
        let before = b.results().len();
        b.bench(&format!("daemon/recover/{model}"), || {
            let (handle, report) =
                PlannerDaemon::recover(&dir, Arc::new(SimClock::new(RECOVERY_TICKS)))
                    .expect("the journal recovers");
            replayed = report.replayed_frames;
            // abandon() writes nothing back, keeping the journal
            // byte-stable across iterations.
            handle.abandon();
            replayed
        });
        if b.results().len() > before {
            let mean = b.results()[before].summary.mean;
            println!(
                "daemon/recover/{model}: {} per recovery ({replayed} frames replayed)",
                fastsplit::util::fmt_secs(mean),
            );
            rows9.push(Json::obj(vec![
                ("case", Json::str("recover")),
                ("model", Json::str(*model)),
                ("devices", Json::num(DEVICES as f64)),
                ("ticks", Json::num(RECOVERY_TICKS as f64)),
                ("replayed_frames", Json::num(replayed as f64)),
                ("mean_recover_s", Json::num(mean)),
            ]));
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
    b.finish();

    if smoke {
        println!("smoke mode: skipping BENCH_PR7.json / BENCH_PR9.json");
        return;
    }
    let out9 =
        std::env::var("FASTSPLIT_DAEMON_PR9_OUT").unwrap_or_else(|_| "BENCH_PR9.json".into());
    if out9 != "-" && !rows9.is_empty() {
        let doc = Json::obj(vec![
            ("bench", Json::str("daemon-journal")),
            ("measured", Json::Bool(true)),
            (
                "note",
                Json::str(
                    "PR 9 durability costs over an 8-device fleet: tick = reports-only daemon \
                     ticks/sec with the write-ahead journal off vs on (default snapshot \
                     cadence, rotation amortized in); recover = full crash recovery (read + \
                     snapshot restore + 32-tick tail replay) from a dirty journal",
                ),
            ),
            ("results", Json::Arr(rows9)),
        ]);
        match std::fs::write(&out9, doc.pretty() + "\n") {
            Ok(()) => println!("wrote {out9}"),
            Err(e) => eprintln!("could not write {out9}: {e}"),
        }
    }
    let out = std::env::var("FASTSPLIT_DAEMON_OUT").unwrap_or_else(|_| "BENCH_PR7.json".into());
    if out != "-" && !rows.is_empty() {
        let doc = Json::obj(vec![
            ("bench", Json::str("daemon")),
            ("measured", Json::Bool(true)),
            (
                "note",
                Json::str(
                    "planner daemon over an 8-device fleet: ingest = reports/sec through \
                     the coalescing channel (pump round-trip as the sync barrier); tick = \
                     full daemon ticks/sec (churn deltas + reports + wheel-fired plan) with \
                     replan_every=1, lease_ttl=4, strict staleness bound (0)",
                ),
            ),
            ("results", Json::Arr(rows)),
        ]);
        match std::fs::write(&out, doc.pretty() + "\n") {
            Ok(()) => println!("wrote {out}"),
            Err(e) => eprintln!("could not write {out}: {e}"),
        }
    }
}
