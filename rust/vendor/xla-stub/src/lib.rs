//! Offline stub of the `xla` PJRT bindings.
//!
//! The real crate links against `libxla_extension`, which is not present in
//! the reproduction containers. This stub keeps the whole workspace
//! compiling and testable offline:
//!
//! * [`Literal`] is a real host-side tensor (enough for the engine's
//!   literal construction/round-trip unit tests to run for real);
//! * [`PjRtClient::cpu`] returns an error, so every PJRT-dependent path
//!   (`SplitTrainer`, the `train` CLI command, runtime integration tests)
//!   degrades to its existing "artifacts unavailable" skip behavior.
//!
//! Swap this path dependency for the real `xla` crate to run split
//! training end-to-end; no call-site changes are needed.

use std::fmt;

/// Stub error type; satisfies `std::error::Error` so `?` converts into
/// `anyhow::Error` at call sites.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what} requires the real PJRT runtime (this build uses the offline xla stub)"
    )))
}

/// Element types a [`Literal`] can hold. Public only because the
/// [`NativeType`] trait mentions it; not part of the stable surface.
#[doc(hidden)]
#[derive(Clone, Debug, PartialEq)]
pub enum Storage {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Storage {
    fn len(&self) -> usize {
        match self {
            Storage::F32(v) => v.len(),
            Storage::I32(v) => v.len(),
        }
    }

    fn elem_bytes(&self) -> usize {
        4
    }
}

/// Conversion between native slices and [`Storage`].
pub trait NativeType: Sized {
    fn store(data: &[Self]) -> Storage;
    fn load(storage: &Storage) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn store(data: &[f32]) -> Storage {
        Storage::F32(data.to_vec())
    }

    fn load(storage: &Storage) -> Result<Vec<f32>> {
        match storage {
            Storage::F32(v) => Ok(v.clone()),
            Storage::I32(_) => unavailable("f32 view of an i32 literal"),
        }
    }
}

impl NativeType for i32 {
    fn store(data: &[i32]) -> Storage {
        Storage::I32(data.to_vec())
    }

    fn load(storage: &Storage) -> Result<Vec<i32>> {
        match storage {
            Storage::I32(v) => Ok(v.clone()),
            Storage::F32(_) => unavailable("i32 view of an f32 literal"),
        }
    }
}

/// A host tensor: flat storage + dimensions. Functional in the stub.
#[derive(Clone, Debug)]
pub struct Literal {
    storage: Storage,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a native slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
            storage: T::store(data),
        }
    }

    /// Reshape (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let numel: i64 = dims.iter().product();
        if numel as usize != self.storage.len() {
            return Err(Error(format!(
                "reshape to {:?} ({} elements) from {} elements",
                dims,
                numel,
                self.storage.len()
            )));
        }
        Ok(Literal {
            storage: self.storage.clone(),
            dims: dims.to_vec(),
        })
    }

    /// Flat row-major copy of the elements.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::load(&self.storage)
    }

    /// Flatten a tuple literal into its elements (real XLA only).
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("tuple literals")
    }

    /// Total payload size in bytes.
    pub fn size_bytes(&self) -> usize {
        self.storage.len() * self.storage.elem_bytes()
    }

    /// Dimensions.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

impl From<f32> for Literal {
    fn from(v: f32) -> Literal {
        Literal {
            storage: Storage::F32(vec![v]),
            dims: Vec::new(),
        }
    }
}

/// Parsed HLO module (opaque in the stub).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HLO text parsing")
    }
}

/// XLA computation handle (opaque in the stub).
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device-side buffer handle returned by an execution.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("buffer readback")
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("execution")
    }
}

/// PJRT client. `cpu()` always errors in the stub, which is the single
/// gate that keeps every runtime path in "unavailable, skip" mode.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("the PJRT CPU client")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("compilation")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_reshape_and_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.dims(), &[2, 2]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.size_bytes(), 16);
    }

    #[test]
    fn reshape_mismatch_rejected() {
        assert!(Literal::vec1(&[1i32, 2]).reshape(&[3]).is_err());
    }

    #[test]
    fn client_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
    }
}
