//! Minimal offline shim of the `anyhow` crate.
//!
//! The reproduction containers have no crates.io access, so the subset of
//! `anyhow` this repo uses — [`Error`], [`Result`], the [`Context`] trait,
//! and the `anyhow!` / `bail!` / `ensure!` macros — is reimplemented here
//! with the same call-site API. Error chains are stored as a vector of
//! messages (outermost context first); `{:#}` formatting joins the chain
//! with `": "` exactly like upstream.

use std::fmt;

/// A dynamically typed error with a chain of context messages.
pub struct Error {
    /// Outermost (most recent context) first.
    chain: Vec<String>,
}

/// `anyhow::Result<T>`: a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Push an outer context message onto the chain.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context/cause messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the full chain, "outer: inner: root".
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Internal unification of "things that convert into [`Error`]" so
/// [`Context`] works both on `Result<T, E: std::error::Error>` and on
/// `Result<T, anyhow::Error>` (mirrors upstream's `ext::StdError`).
pub trait IntoChainError: Sized {
    fn into_chain_error(self) -> Error;
}

impl<E> IntoChainError for E
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn into_chain_error(self) -> Error {
        Error::from(self)
    }
}

impl IntoChainError for Error {
    fn into_chain_error(self) -> Error {
        self
    }
}

/// Attach context to errors, upstream-style.
pub trait Context<T, E> {
    /// Wrap the error with a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    /// Wrap the error with a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: IntoChainError> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into_chain_error().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into_chain_error().context(f()))
    }
}

impl<T> Context<T, core::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn context_chains_and_alternate_display() {
        let r: Result<()> = Err(io_err()).context("reading manifest");
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: missing file");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("empty").unwrap_err();
        assert_eq!(format!("{e}"), "empty");
    }

    #[test]
    fn context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("inner {}", 3));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner 3");
    }

    #[test]
    fn macros_compile() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x == 1 {
                bail!("one is not allowed");
            }
            Ok(x)
        }
        assert!(f(2).is_ok());
        assert!(f(-1).is_err());
        assert!(f(1).is_err());
    }
}
