//! Minimal offline stand-in for the `rayon` crate.
//!
//! The reproduction containers have no crates.io access, so the real
//! rayon cannot be vendored wholesale; this shim implements exactly the
//! surface `fastsplit`'s `parallel` feature uses —
//! `slice.par_iter_mut().for_each(op)` — by splitting the slice into one
//! contiguous chunk per available core and running each chunk on a
//! `std::thread::scope` thread. Call sites are written against rayon's
//! prelude idiom, so swapping this path dependency for the real `rayon`
//! on a networked machine compiles unchanged.
//!
//! Semantics match rayon where it matters for determinism: `op` runs
//! exactly once per element, elements of one chunk run in slice order on
//! one thread, and `for_each` returns only after every element has been
//! processed (scoped threads join on scope exit). Panics in `op`
//! propagate to the caller like rayon's.

pub mod prelude {
    pub use crate::IntoParallelRefMutIterator;
}

/// Rayon's `par_iter_mut` entry-point trait, reduced to mutable slices.
pub trait IntoParallelRefMutIterator<'data> {
    type Item: Send + 'data;
    fn par_iter_mut(&'data mut self) -> ParIterMut<'data, Self::Item>;
}

impl<'data, T: Send + 'data> IntoParallelRefMutIterator<'data> for [T] {
    type Item = T;
    fn par_iter_mut(&'data mut self) -> ParIterMut<'data, T> {
        ParIterMut { slice: self }
    }
}

impl<'data, T: Send + 'data> IntoParallelRefMutIterator<'data> for Vec<T> {
    type Item = T;
    fn par_iter_mut(&'data mut self) -> ParIterMut<'data, T> {
        ParIterMut {
            slice: self.as_mut_slice(),
        }
    }
}

/// Parallel mutable iterator over a slice (the shim's only shape).
pub struct ParIterMut<'data, T: Send> {
    slice: &'data mut [T],
}

impl<'data, T: Send> ParIterMut<'data, T> {
    /// Run `op` once per element, chunked across `available_parallelism`
    /// scoped threads. Single-element (or single-core) inputs run inline.
    pub fn for_each<F>(self, op: F)
    where
        F: Fn(&mut T) + Send + Sync,
    {
        let len = self.slice.len();
        if len == 0 {
            return;
        }
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(len);
        if threads <= 1 {
            for item in self.slice {
                op(item);
            }
            return;
        }
        let chunk = len.div_ceil(threads);
        std::thread::scope(|scope| {
            for part in self.slice.chunks_mut(chunk) {
                let op = &op;
                scope.spawn(move || {
                    for item in part {
                        op(item);
                    }
                });
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::prelude::*;

    #[test]
    fn visits_every_element_exactly_once() {
        let mut v: Vec<u64> = (0..1000).collect();
        v.par_iter_mut().for_each(|x| *x += 1);
        assert!(v.iter().enumerate().all(|(i, &x)| x == i as u64 + 1));
    }

    #[test]
    fn empty_and_single_inputs() {
        let mut empty: Vec<u64> = Vec::new();
        empty.par_iter_mut().for_each(|_| unreachable!());
        let mut one = [7u64];
        one[..].par_iter_mut().for_each(|x| *x *= 2);
        assert_eq!(one[0], 14);
    }

    #[test]
    fn runs_on_slices_too() {
        let mut v = [1u32, 2, 3, 4, 5];
        v.as_mut_slice().par_iter_mut().for_each(|x| *x *= 10);
        assert_eq!(v, [10, 20, 30, 40, 50]);
    }
}
