//! Simulator + experiment-harness integration: the scenario battery of
//! Sec. VII-B runs end to end and exhibits the paper's qualitative shape
//! (who wins, and roughly by how much).

use fastsplit::net::{Band, ChannelCondition, NetConfig};
use fastsplit::sim::{Dataset, SimConfig, Trainer};

fn cfg(model: &str, method: &str, seed: u64) -> SimConfig {
    SimConfig {
        model: model.into(),
        net: NetConfig {
            band: Band::n257(),
            condition: ChannelCondition::Normal,
            ..NetConfig::default()
        },
        method: method.into(),
        seed,
        ..SimConfig::default()
    }
}

#[test]
fn paper_shape_proposed_beats_all_sl_baselines_on_googlenet() {
    // Fig. 13-style check with reduced epochs: mean epoch delay of the
    // proposed method beats OSS / device-only / regression, and the margin
    // against the best baseline is in a plausible band (the paper reports
    // 8-39% across scenarios; we accept >2% to stay robust to seeds).
    let mean = |method: &str| {
        let mut t = Trainer::new(cfg("googlenet", method, 7));
        t.run_epochs(60).mean_epoch_delay
    };
    let proposed = mean("proposed");
    let oss = mean("oss");
    let dev = mean("device-only");
    let reg = mean("regression");
    for (name, d) in [("oss", oss), ("device-only", dev), ("regression", reg)] {
        assert!(
            proposed < d,
            "proposed {proposed} not better than {name} {d}"
        );
    }
    let best = oss.min(dev).min(reg);
    assert!(
        proposed < best * 0.98,
        "margin too small: proposed {proposed} vs best baseline {best}"
    );
}

#[test]
fn mmwave_beats_sub6_for_proposed() {
    // 10x bandwidth should reduce the transmission-bound epochs.
    let mean = |band: Band| {
        let mut c = cfg("googlenet", "proposed", 9);
        c.net.band = band;
        let mut t = Trainer::new(c);
        t.run_epochs(40).mean_epoch_delay
    };
    assert!(mean(Band::n257()) < mean(Band::n1()));
}

#[test]
fn non_iid_needs_more_total_delay() {
    let total = |iid: bool| {
        let mut t = Trainer::new(cfg("resnet18", "proposed", 11));
        let (res, _) = t.run_to_accuracy(Dataset::Cifar10, iid, 5000);
        res.total_delay
    };
    assert!(total(false) > total(true));
}

#[test]
fn larger_fleet_does_not_break_the_loop() {
    for devices in [10usize, 40] {
        let mut c = cfg("resnet18", "proposed", 13);
        c.net.num_devices = devices;
        let mut t = Trainer::new(c);
        let res = t.run_epochs(devices + 5);
        // All devices participated at least once (round-robin fairness).
        let seen: std::collections::HashSet<usize> =
            res.records.iter().map(|r| r.device).collect();
        assert_eq!(seen.len(), devices, "{devices} devices");
    }
}

#[test]
fn quick_experiment_harnesses_produce_reports() {
    for id in ["fig7a", "fig8", "fig16", "ablB"] {
        let out = fastsplit::experiments::run(id, true).unwrap();
        assert!(out.len() > 100, "{id} output too small:\n{out}");
    }
    assert!(fastsplit::experiments::run("nope", true).is_none());
}

#[test]
fn gpt2_scenario_runs() {
    let mut t = Trainer::new(cfg("gpt2", "proposed", 17));
    let res = t.run_epochs(10);
    assert!(res.total_delay > 0.0);
    assert!(res.mean_decision_time < 0.5);
}
