//! Failure-injection tests: corrupted artifacts, malformed manifests, and
//! hostile inputs must produce errors, never panics or wrong results.

use fastsplit::runtime::{Engine, Manifest};
use std::io::Write;

fn tmpdir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("fastsplit-failtest-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn missing_manifest_is_an_error() {
    let dir = tmpdir("missing");
    let err = Manifest::load(dir.to_str().unwrap()).unwrap_err();
    assert!(format!("{err:#}").contains("manifest.json"), "{err:#}");
}

#[test]
fn malformed_manifest_json_is_an_error() {
    let dir = tmpdir("badjson");
    std::fs::write(dir.join("manifest.json"), b"{ not json !").unwrap();
    assert!(Manifest::load(dir.to_str().unwrap()).is_err());
}

#[test]
fn manifest_missing_required_fields_is_an_error() {
    let dir = tmpdir("nofields");
    std::fs::write(dir.join("manifest.json"), br#"{"batch": 32}"#).unwrap();
    let err = Manifest::load(dir.to_str().unwrap()).unwrap_err();
    assert!(format!("{err:#}").contains("missing"), "{err:#}");
}

#[test]
fn manifest_referencing_absent_files_is_an_error() {
    let dir = tmpdir("nofiles");
    let manifest = r#"{
        "batch": 32, "img": 16, "channels": 3, "num_classes": 10,
        "stages": 4, "cuts": [1],
        "param_shapes": [[3]],
        "artifacts": {
            "dev_fwd_cut1": {"file": "missing.hlo.txt", "inputs": []},
            "srv_step_cut1": {"file": "missing.hlo.txt", "inputs": []},
            "dev_bwd_cut1": {"file": "missing.hlo.txt", "inputs": []},
            "full_step": {"file": "missing.hlo.txt", "inputs": []},
            "predict": {"file": "missing.hlo.txt", "inputs": []}
        }
    }"#;
    std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    let err = Manifest::load(dir.to_str().unwrap()).unwrap_err();
    assert!(format!("{err:#}").contains("missing"), "{err:#}");
}

#[test]
fn garbage_hlo_text_fails_to_compile() {
    let dir = tmpdir("badhlo");
    let path = dir.join("garbage.hlo.txt");
    let mut f = std::fs::File::create(&path).unwrap();
    f.write_all(b"HloModule nonsense\nENTRY { this is not hlo }\n")
        .unwrap();
    drop(f);
    let mut engine = Engine::cpu().unwrap();
    assert!(engine.load("garbage", &path).is_err());
    // The failed load must not poison the engine.
    assert_eq!(engine.cached(), 0);
}

#[test]
fn running_unloaded_executable_is_an_error() {
    let mut engine = Engine::cpu().unwrap();
    let err = match engine.run("never-loaded", &[]) {
        Ok(_) => panic!("run of an unloaded executable succeeded"),
        Err(e) => e,
    };
    assert!(format!("{err:#}").contains("not loaded"));
}

#[test]
fn init_params_shape_mismatch_is_an_error() {
    // A manifest whose declared shape disagrees with the shipped values.
    let dir = tmpdir("badparams");
    if !fastsplit::runtime::artifacts_available(fastsplit::runtime::DEFAULT_ARTIFACTS_DIR) {
        eprintln!("skipping: needs real artifacts to copy");
        return;
    }
    // Copy the real artifacts, then corrupt init_params.json.
    for entry in std::fs::read_dir(fastsplit::runtime::DEFAULT_ARTIFACTS_DIR).unwrap() {
        let entry = entry.unwrap();
        std::fs::copy(entry.path(), dir.join(entry.file_name())).unwrap();
    }
    std::fs::write(dir.join("init_params.json"), b"[[1.0, 2.0]]").unwrap();
    let m = Manifest::load(dir.to_str().unwrap()).unwrap();
    assert!(m.load_init_params().is_err());
}
