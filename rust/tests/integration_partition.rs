//! Cross-module integration: zoo models x device tiers x link regimes,
//! exercising the full partition stack (model -> cost graph -> Alg. 1-4 ->
//! Eq. (7)) and the baseline battery together.

use fastsplit::models;
use fastsplit::partition::baselines::{partition_by_method, BASELINE_NAMES};
use fastsplit::partition::blockwise::blockwise_partition_instrumented;
use fastsplit::partition::general::general_partition_instrumented;
use fastsplit::partition::{Link, Problem};
use fastsplit::profiles::{CostGraph, DeviceProfile, TrainCfg};

fn tiers() -> Vec<DeviceProfile> {
    vec![
        DeviceProfile::jetson_tx1(),
        DeviceProfile::jetson_tx2(),
        DeviceProfile::jetson_orin_nano(),
        DeviceProfile::jetson_agx_orin(),
    ]
}

#[test]
fn every_model_partitions_under_every_tier_and_rate() {
    for model_name in models::MODEL_NAMES {
        let model = models::by_name(model_name).unwrap();
        for device in tiers() {
            let costs = CostGraph::build(
                &model,
                &device,
                &DeviceProfile::rtx_a6000(),
                &TrainCfg::default(),
            );
            assert!(costs.satisfies_assumption1(), "{model_name}/{}", device.name);
            for rate in [1e4, 1e6, 1e8] {
                let p = Problem::new(&costs, Link::symmetric(rate));
                let gen = general_partition_instrumented(&p);
                let bw = blockwise_partition_instrumented(&p);
                assert!(
                    p.is_feasible(&gen.partition.device_set),
                    "{model_name}/{}/{rate}: general infeasible",
                    device.name
                );
                assert!(
                    p.is_feasible(&bw.partition.device_set),
                    "{model_name}/{}/{rate}: blockwise infeasible",
                    device.name
                );
                let tol = 1e-9 * (1.0 + gen.partition.delay);
                assert!(
                    (gen.partition.delay - bw.partition.delay).abs() <= tol,
                    "{model_name}/{}/{rate}: general {} != blockwise {}",
                    device.name,
                    gen.partition.delay,
                    bw.partition.delay
                );
            }
        }
    }
}

#[test]
fn all_baselines_run_on_all_models() {
    for model_name in models::MODEL_NAMES {
        let model = models::by_name(model_name).unwrap();
        let costs = CostGraph::build(
            &model,
            &DeviceProfile::jetson_tx2(),
            &DeviceProfile::rtx_a6000(),
            &TrainCfg::default(),
        );
        let link = Link::symmetric(1e6);
        let p = Problem::new(&costs, link);
        let proposed = partition_by_method("proposed", &p, link);
        for method in BASELINE_NAMES {
            let part = partition_by_method(method, &p, link);
            assert!(part.delay > 0.0, "{model_name}/{method}");
            if *method != "central" {
                assert!(
                    proposed.delay <= part.delay + 1e-9 * part.delay,
                    "{model_name}: proposed {} beaten by {method} {}",
                    proposed.delay,
                    part.delay
                );
            }
        }
    }
}

#[test]
fn rate_monotonicity_of_optimal_delay() {
    // A strictly better link can never make the optimal delay worse.
    let model = models::by_name("googlenet").unwrap();
    let costs = CostGraph::build(
        &model,
        &DeviceProfile::jetson_tx2(),
        &DeviceProfile::rtx_a6000(),
        &TrainCfg::default(),
    );
    let mut prev = f64::INFINITY;
    for rate in [1e4, 3e4, 1e5, 1e6, 1e7, 1e8, 1e9] {
        let p = Problem::new(&costs, Link::symmetric(rate));
        let d = partition_by_method("proposed", &p, p.link).delay;
        assert!(
            d <= prev * (1.0 + 1e-9),
            "optimal delay rose with rate: {prev} -> {d} at {rate}"
        );
        prev = d;
    }
}

#[test]
fn stronger_device_never_hurts() {
    let model = models::by_name("resnet18").unwrap();
    let mut prev = f64::INFINITY;
    for device in tiers() {
        let costs = CostGraph::build(
            &model,
            &device,
            &DeviceProfile::rtx_a6000(),
            &TrainCfg::default(),
        );
        let p = Problem::new(&costs, Link::symmetric(1e6));
        let d = partition_by_method("proposed", &p, p.link).delay;
        assert!(
            d <= prev * (1.0 + 1e-9),
            "optimal delay rose with a faster device tier: {prev} -> {d}"
        );
        prev = d;
    }
}

#[test]
fn n_loc_scales_iteration_terms_only() {
    let model = models::by_name("lenet5").unwrap();
    let build = |n_loc: u32| {
        CostGraph::build(
            &model,
            &DeviceProfile::jetson_tx2(),
            &DeviceProfile::rtx_a6000(),
            &TrainCfg {
                batch: 32,
                n_loc,
                bwd_ratio: 2.0,
            },
        )
    };
    let c1 = build(1);
    let c10 = build(10);
    let link = Link::symmetric(1e6);
    // Evaluate the same device set under both: delay difference must be
    // exactly 9x the per-iteration part.
    let mask: Vec<bool> = (0..c1.len()).map(|v| v < 4).collect();
    let p1 = Problem::new(&c1, link);
    let p10 = Problem::new(&c10, link);
    let d1 = p1.delay(&mask);
    let d10 = p10.delay(&mask);
    let model_bytes: f64 = (0..4).map(|v| c1.param_bytes[v]).sum();
    let model_xfer = model_bytes * 2.0 / 1e6;
    let per_iter = d1 - model_xfer;
    assert!(
        (d10 - (10.0 * per_iter + model_xfer)).abs() < 1e-9 * d10,
        "d1={d1} d10={d10}"
    );
}

/// Cross-module joint planning: for every zoo model, a 4-tier fleet under a
/// shrinking shared server stays within the provable makespan envelope —
/// at least the slowest dedicated optimum, at most the worst all-on-device
/// delay — grows monotonically as capacity shrinks, and produces feasible,
/// within-makespan decisions throughout.
#[test]
fn every_model_plans_jointly_under_shared_capacity() {
    use fastsplit::partition::{FleetSpec, JointPlanner};

    for model_name in models::MODEL_NAMES {
        let model = models::by_name(model_name).unwrap();
        let server = DeviceProfile::rtx_a6000();
        let all = tiers();
        let link_of = |t: usize| Link::symmetric(8e5 * (1.0 + t as f64));
        let mut prev = 0.0f64;
        for capacity in [f64::INFINITY, 2.0, 0.8] {
            let spec = FleetSpec::from_fleet(&all, |d| {
                CostGraph::build(&model, d, &server, &TrainCfg::default())
            });
            let mut joint = JointPlanner::with_capacity(spec, capacity);
            let reqs = joint.spec().requests(link_of);
            let decisions = joint.plan(&reqs);
            let makespan = joint.makespan().expect("non-empty epoch");
            // Envelope: every device can always fall back to all-on-device.
            let worst_device_only = reqs
                .iter()
                .map(|r| {
                    let costs = joint.spec().tier_costs(r.tier);
                    let p = Problem::new(costs, r.link);
                    p.device_only().delay
                })
                .fold(0.0, f64::max);
            assert!(
                makespan <= worst_device_only * (1.0 + 1e-9),
                "{model_name} capacity {capacity}: makespan {makespan} above the \
                 all-on-device envelope {worst_device_only}"
            );
            assert!(
                makespan >= prev * (1.0 - 1e-9),
                "{model_name}: makespan fell as capacity shrank to {capacity}"
            );
            prev = makespan;
            for (r, d) in reqs.iter().zip(&decisions) {
                let p = Problem::new(joint.spec().tier_costs(r.tier), r.link);
                assert!(
                    p.is_feasible(&d.partition.device_set),
                    "{model_name} capacity {capacity}: infeasible joint cut"
                );
                assert!(
                    d.partition.delay <= makespan * (1.0 + 1e-9),
                    "{model_name} capacity {capacity}: decision above the makespan"
                );
            }
        }
    }
}
