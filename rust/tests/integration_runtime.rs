//! PJRT runtime integration: requires `make artifacts` to have run (tests
//! self-skip otherwise so `cargo test` stays green pre-build).

use fastsplit::runtime::data::Synthetic;
use fastsplit::runtime::{artifacts_available, Manifest, SplitTrainer, DEFAULT_ARTIFACTS_DIR};

fn skip() -> bool {
    if !artifacts_available(DEFAULT_ARTIFACTS_DIR) {
        eprintln!("skipping runtime integration: run `make artifacts`");
        return true;
    }
    false
}

fn data(m: &Manifest, seed: u64) -> Synthetic {
    Synthetic::new(m.img, m.channels, m.num_classes, m.batch, seed)
}

#[test]
fn every_cut_trains_and_reduces_loss() {
    if skip() {
        return;
    }
    let mut trainer = SplitTrainer::new(DEFAULT_ARTIFACTS_DIR).unwrap();
    let mut gen = data(trainer.manifest(), 1);
    // Alternate through all cuts, including device-only (4): parameters are
    // shared, so training progress must survive cut switches — the SL
    // invariant the coordinator depends on.
    let cuts = [0usize, 1, 2, 3, 4];
    let mut first = None;
    let mut losses = Vec::new();
    for step in 0..30 {
        let batch = gen.next_batch();
        let out = trainer.step(cuts[step % cuts.len()], &batch, 0.1).unwrap();
        assert!(out.loss.is_finite(), "step {step} loss not finite");
        first.get_or_insert(out.loss);
        losses.push(out.loss as f64);
    }
    let head: f64 = losses[..5].iter().sum::<f64>() / 5.0;
    let tail: f64 = losses[losses.len() - 5..].iter().sum::<f64>() / 5.0;
    assert!(
        tail < head,
        "loss did not decrease across cut switches: {head} -> {tail}"
    );
}

#[test]
fn split_step_matches_full_step_numerics() {
    if skip() {
        return;
    }
    // Two trainers from identical initial params; one runs the monolithic
    // full step, the other the 3-artifact split pipeline. Losses must match
    // step for step (the rust-side counterpart of the python
    // test_split_equals_full_step).
    let mut full = SplitTrainer::new(DEFAULT_ARTIFACTS_DIR).unwrap();
    let mut split = SplitTrainer::new(DEFAULT_ARTIFACTS_DIR).unwrap();
    let mut gen_a = data(full.manifest(), 2);
    let mut gen_b = data(split.manifest(), 2);
    for cut in [1usize, 2, 3] {
        let ba = gen_a.next_batch();
        let bb = gen_b.next_batch();
        assert_eq!(ba.labels, bb.labels);
        let lf = full.step(0, &ba, 0.05).unwrap().loss;
        let ls = split.step(cut, &bb, 0.05).unwrap().loss;
        assert!(
            (lf - ls).abs() < 1e-4 * (1.0 + lf.abs()),
            "cut {cut}: full {lf} vs split {ls}"
        );
    }
}

#[test]
fn wire_bytes_match_manifest_shapes() {
    if skip() {
        return;
    }
    let mut trainer = SplitTrainer::new(DEFAULT_ARTIFACTS_DIR).unwrap();
    let m = trainer.manifest().clone();
    let mut gen = data(&m, 3);
    for cut in m.cuts.clone() {
        let batch = gen.next_batch();
        let out = trainer.step(cut, &batch, 0.05).unwrap();
        let smashed_elems: usize = m.artifacts[&format!("srv_step_cut{cut}")].inputs[0].numel();
        // smashed up + gradient down, fp32.
        assert_eq!(out.wire_bytes, (2 * smashed_elems * 4) as u64, "cut {cut}");
    }
}

#[test]
fn accuracy_improves_with_training() {
    if skip() {
        return;
    }
    let mut trainer = SplitTrainer::new(DEFAULT_ARTIFACTS_DIR).unwrap();
    let mut gen = data(trainer.manifest(), 4);
    let evals: Vec<_> = (0..4).map(|_| gen.next_batch()).collect();
    let acc_mean = |t: &mut SplitTrainer, evals: &[fastsplit::runtime::data::Batch]| {
        evals.iter().map(|b| t.accuracy(b).unwrap()).sum::<f64>() / evals.len() as f64
    };
    let acc0 = acc_mean(&mut trainer, &evals);
    let mut losses = Vec::new();
    for _ in 0..120 {
        let batch = gen.next_batch();
        losses.push(trainer.step(2, &batch, 0.05).unwrap().loss as f64);
    }
    let acc1 = acc_mean(&mut trainer, &evals);
    let head: f64 = losses[..10].iter().sum::<f64>() / 10.0;
    let tail: f64 = losses[losses.len() - 10..].iter().sum::<f64>() / 10.0;
    assert!(tail < head, "loss did not decrease: {head} -> {tail}");
    // Accuracy on a 128-sample eval set is noisy; allow slack but require
    // no collapse.
    assert!(
        acc1 >= acc0 - 0.05,
        "accuracy collapsed after training: {acc0} -> {acc1}"
    );
}

#[test]
fn invalid_cut_is_rejected() {
    if skip() {
        return;
    }
    let mut trainer = SplitTrainer::new(DEFAULT_ARTIFACTS_DIR).unwrap();
    let mut gen = data(trainer.manifest(), 5);
    let batch = gen.next_batch();
    // Cut 7 is beyond stages and maps to device-only (full step) — allowed.
    assert!(trainer.step(7, &batch, 0.05).is_ok());
    // Wrong batch size is rejected.
    let mut small = Synthetic::new(
        trainer.manifest().img,
        trainer.manifest().channels,
        trainer.manifest().num_classes,
        8,
        6,
    );
    assert!(trainer.step(1, &small.next_batch(), 0.05).is_err());
}
