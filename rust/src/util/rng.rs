//! Deterministic pseudo-random number generation and distribution samplers.
//!
//! Implements xoshiro256++ (Blackman & Vigna) seeded via splitmix64, plus
//! the samplers the edge-network simulator needs: uniform, normal
//! (Box-Muller), exponential (inverse CDF), and Dirichlet (via Gamma with
//! Marsaglia-Tsang). All simulation runs are reproducible from a `u64` seed.

/// Default base seed of the randomized test suites. Kept equal to the
/// historical `util::prop::for_all` base so default runs replay the exact
/// case streams earlier PRs were validated against.
pub const DEFAULT_TEST_SEED: u64 = 0xF057_5EED;

/// Base seed for every randomized/property test: the `PALLAS_TEST_SEED`
/// environment variable when set (decimal, or hex with an `0x` prefix),
/// else [`DEFAULT_TEST_SEED`]. Property drivers fold this base into their
/// per-case seeds and print it on failure, so any failing run is replayable
/// with `PALLAS_TEST_SEED=<seed> cargo test ...` (recipe in PERF.md).
pub fn test_seed() -> u64 {
    match std::env::var("PALLAS_TEST_SEED") {
        Ok(s) => parse_seed(&s).unwrap_or_else(|| {
            panic!("PALLAS_TEST_SEED must be a u64 (decimal or 0x-hex): {s:?}")
        }),
        Err(_) => DEFAULT_TEST_SEED,
    }
}

fn parse_seed(s: &str) -> Option<u64> {
    let s = s.trim();
    match s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        Some(hex) => u64::from_str_radix(hex, 16).ok(),
        None => s.parse().ok(),
    }
}

/// xoshiro256++ PRNG. Deterministic, fast, good statistical quality.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of Box-Muller.
    gauss_spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent stream for a sub-component (e.g. per device).
    pub fn fork(&mut self, stream: u64) -> Rng {
        let base = self.next_u64();
        Rng::new(base ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Uses Lemire's multiply-shift rejection.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize index in [0, n).
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (with spare caching).
    pub fn gauss(&mut self) -> f64 {
        if let Some(v) = self.gauss_spare.take() {
            return v;
        }
        // Avoid u == 0 for the log.
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let v = self.f64();
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * v;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with the given mean and standard deviation.
    pub fn normal(&mut self, mean: f64, std_dev: f64) -> f64 {
        mean + std_dev * self.gauss()
    }

    /// Exponential with unit mean (inverse CDF).
    pub fn exponential(&mut self) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln()
    }

    /// Gamma(shape, scale=1) via Marsaglia-Tsang squeeze (shape >= 0).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        assert!(shape > 0.0);
        if shape < 1.0 {
            // Boosting: Gamma(a) = Gamma(a+1) * U^(1/a)
            let g = self.gamma(shape + 1.0);
            let u = self.f64().max(1e-300);
            return g * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.gauss();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v = v * v * v;
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4) {
                return d * v;
            }
            if u > 0.0 && u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Dirichlet(gamma * p) sample — used for non-IID label splits (Sec. VII-B.3).
    pub fn dirichlet(&mut self, alpha: &[f64]) -> Vec<f64> {
        let draws: Vec<f64> = alpha.iter().map(|&a| self.gamma(a.max(1e-9))).collect();
        let sum: f64 = draws.iter().sum();
        if sum <= 0.0 {
            let n = alpha.len() as f64;
            return alpha.iter().map(|_| 1.0 / n).collect();
        }
        draws.into_iter().map(|d| d / sum).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seed_parsing_accepts_decimal_and_hex() {
        assert_eq!(parse_seed("42"), Some(42));
        assert_eq!(parse_seed(" 42 "), Some(42));
        assert_eq!(parse_seed("0xC0FFEE"), Some(0xC0FFEE));
        assert_eq!(parse_seed("0XdeadBEEF"), Some(0xDEAD_BEEF));
        assert_eq!(parse_seed("nope"), None);
        assert_eq!(parse_seed("0x"), None);
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.gauss();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn exponential_unit_mean() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.exponential()).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::new(11);
        for &shape in &[0.5, 1.0, 2.5, 7.0] {
            let n = 100_000;
            let mean: f64 = (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!(
                (mean - shape).abs() < 0.06 * shape.max(1.0),
                "shape={shape} mean={mean}"
            );
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(13);
        let alpha = vec![0.5; 10];
        for _ in 0..100 {
            let d = r.dirichlet(&alpha);
            let s: f64 = d.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(d.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(99);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }
}
