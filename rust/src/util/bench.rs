//! Criterion-style micro-benchmark harness (criterion is unavailable
//! offline). Each `[[bench]]` target with `harness = false` builds a plain
//! binary that drives this runner: warmup, timed iterations, and a summary
//! line with mean / p50 / p95 per benchmark id.

use super::stats::Summary;
use std::hint::black_box;
use std::time::{Duration, Instant};

/// Benchmark runner configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    /// Target wall time spent measuring each benchmark.
    pub measure_time: Duration,
    /// Warmup wall time before measuring.
    pub warmup_time: Duration,
    /// Upper bound on recorded samples.
    pub max_samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            measure_time: Duration::from_millis(800),
            warmup_time: Duration::from_millis(150),
            max_samples: 10_000,
        }
    }
}

/// Result row for one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub id: String,
    pub summary: Summary,
}

/// Bench harness; accumulates results and prints a report.
pub struct Bencher {
    cfg: BenchConfig,
    results: Vec<BenchResult>,
    filter: Option<String>,
}

impl Bencher {
    /// Construct from CLI args (`cargo bench -- <filter>` and `--quick`).
    pub fn from_env() -> Bencher {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        let quick = argv.iter().any(|a| a == "--quick");
        // cargo passes --bench; ignore it and any other --flags for filtering
        let filter = argv.into_iter().find(|a| !a.starts_with("--"));
        let mut cfg = BenchConfig::default();
        if quick {
            cfg.measure_time = Duration::from_millis(120);
            cfg.warmup_time = Duration::from_millis(30);
        }
        Bencher {
            cfg,
            results: Vec::new(),
            filter,
        }
    }

    pub fn with_config(cfg: BenchConfig) -> Bencher {
        Bencher {
            cfg,
            results: Vec::new(),
            filter: None,
        }
    }

    /// Time `f`, which should produce a value consumed by `black_box`.
    pub fn bench<T, F: FnMut() -> T>(&mut self, id: &str, mut f: F) {
        if let Some(filter) = &self.filter {
            if !id.contains(filter.as_str()) {
                return;
            }
        }
        // Warmup and per-iteration cost estimate.
        let warm_start = Instant::now();
        let mut warm_iters: u64 = 0;
        while warm_start.elapsed() < self.cfg.warmup_time {
            black_box(f());
            warm_iters += 1;
        }
        let est = warm_start.elapsed().as_secs_f64() / warm_iters.max(1) as f64;

        // Choose batch size so each sample is at least ~20 µs.
        let batch = ((20e-6 / est.max(1e-12)).ceil() as u64).max(1);
        let mut samples = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.cfg.measure_time && samples.len() < self.cfg.max_samples {
            let t0 = Instant::now();
            for _ in 0..batch {
                black_box(f());
            }
            samples.push(t0.elapsed().as_secs_f64() / batch as f64);
        }
        let summary = Summary::of(&samples);
        println!(
            "bench {id:<52} mean {:>12}  p50 {:>12}  p95 {:>12}  ({} samples x {} iters)",
            crate::util::fmt_secs(summary.mean),
            crate::util::fmt_secs(summary.p50),
            crate::util::fmt_secs(summary.p95),
            summary.n,
            batch,
        );
        self.results.push(BenchResult {
            id: id.to_string(),
            summary,
        });
    }

    /// All recorded results.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Final single-line footer (keeps `cargo bench` output greppable).
    pub fn finish(&self) {
        println!("bench-suite-complete: {} benchmarks", self.results.len());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_records() {
        let mut b = Bencher::with_config(BenchConfig {
            measure_time: Duration::from_millis(20),
            warmup_time: Duration::from_millis(5),
            max_samples: 100,
        });
        b.bench("noop_sum", || (0..100u64).sum::<u64>());
        assert_eq!(b.results().len(), 1);
        assert!(b.results()[0].summary.mean > 0.0);
    }
}
