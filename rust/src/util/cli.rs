//! Tiny declarative command-line parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! and per-subcommand help rendering. The binary's `main.rs` defines one
//! [`Args`] per subcommand.

use std::collections::BTreeMap;

/// Parsed arguments: options (`--key value`) and positionals, in order.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub opts: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse raw arguments. `flag_names` lists boolean options taking no value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, flag_names: &[&str]) -> Args {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&rest) {
                    args.flags.push(rest.to_string());
                } else if let Some(val) = iter.peek() {
                    if val.starts_with("--") {
                        args.flags.push(rest.to_string());
                    } else {
                        let v = iter.next().unwrap();
                        args.opts.insert(rest.to_string(), v);
                    }
                } else {
                    args.flags.push(rest.to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed_forms() {
        let a = Args::parse(
            sv(&["pos1", "--k", "v", "--x=3", "--verbose", "pos2"]),
            &["verbose"],
        );
        assert_eq!(a.positional, sv(&["pos1", "pos2"]));
        assert_eq!(a.get("k"), Some("v"));
        assert_eq!(a.get_f64("x", 0.0), 3.0);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = Args::parse(sv(&["--dry-run"]), &[]);
        assert!(a.flag("dry-run"));
    }

    #[test]
    fn flag_followed_by_option() {
        let a = Args::parse(sv(&["--fast", "--n", "10"]), &[]);
        assert!(a.flag("fast"));
        assert_eq!(a.get_usize("n", 0), 10);
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(sv(&[]), &[]);
        assert_eq!(a.get_or("model", "resnet18"), "resnet18");
        assert_eq!(a.get_u64("seed", 7), 7);
    }
}
