//! Lightweight property-testing driver (proptest is unavailable offline)
//! plus the shared generators and the cut-cost equivalence harness of the
//! partition property suites.
//!
//! [`for_all`] runs a property over `cases` seeded generations; on failure
//! it retries with the same seed to confirm determinism and reports the
//! failing seed so the case can be replayed with `FASTSPLIT_PROP_SEED`.
//! [`zoo_matrix`] is the shared generator matrix of the partition suites:
//! every zoo model × every Jetson device tier, with a deterministic
//! per-cell RNG for drawing random links. Both drivers derive their base
//! seed from [`crate::util::rng::test_seed`], so `PALLAS_TEST_SEED`
//! reseeds every suite at once and failures print the seed to replay with
//! (recipe in PERF.md).

use super::rng::Rng;
use crate::models;
use crate::partition::fleet::SpecDelta;
use crate::partition::general::general_partition;
use crate::partition::types::{Link, Partition, Problem};
use crate::profiles::{CostGraph, DeviceProfile, TrainCfg};

/// Number of cases to run per property (override with FASTSPLIT_PROP_CASES).
pub fn default_cases() -> u64 {
    std::env::var("FASTSPLIT_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Run `prop(rng)` for `cases` different deterministic seeds. Panics with
/// the failing seed embedded in the message on the first failure.
pub fn for_all<F: FnMut(&mut Rng)>(name: &str, cases: u64, mut prop: F) {
    // Allow pinning a single seed for replay.
    if let Ok(seed) = std::env::var("FASTSPLIT_PROP_SEED") {
        if let Ok(seed) = seed.parse::<u64>() {
            let mut rng = Rng::new(seed);
            prop(&mut rng);
            return;
        }
    }
    let base = crate::util::rng::test_seed();
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::new(seed);
            prop(&mut rng);
        }));
        if let Err(payload) = result {
            let msg = panic_message(payload.as_ref());
            panic!(
                "property '{name}' failed on case {case} (seed {seed}, base seed {base}):\n{msg}\n\
                 replay this case with FASTSPLIT_PROP_SEED={seed}, or the whole \
                 suite with PALLAS_TEST_SEED={base}"
            );
        }
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    payload
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_else(|| "<non-string panic>".into())
}

/// Generate a random connected DAG as an edge list over `n` vertices where
/// every edge goes from a lower to a higher index (guaranteeing acyclicity)
/// and every vertex (except 0) has at least one parent — shaped like layer
/// graphs: a chain backbone with extra skip/branch edges.
pub fn random_layer_dag(rng: &mut Rng, n: usize, extra_edge_prob: f64) -> Vec<(usize, usize)> {
    assert!(n >= 2);
    let mut edges = Vec::new();
    for v in 1..n {
        // Backbone parent: usually the previous vertex (chain-like models),
        // occasionally an earlier one (branching).
        let parent = if v == 1 || rng.chance(0.8) {
            v - 1
        } else {
            rng.index(v)
        };
        edges.push((parent, v));
    }
    // Extra forward edges: skip connections / parallel branches.
    for u in 0..n {
        for v in (u + 1)..n.min(u + 6) {
            if rng.chance(extra_edge_prob) && !edges.contains(&(u, v)) {
                edges.push((u, v));
            }
        }
    }
    edges.sort();
    edges.dedup();
    edges
}

/// A random link spanning the suites' 1e4..1e9 bytes/s rate regime.
pub fn random_link(rng: &mut Rng) -> Link {
    Link {
        up_bps: rng.range(1e4, 1e9),
        down_bps: rng.range(1e4, 1e9),
    }
}

/// A σ-drift link trajectory: `steps` links starting from (but not
/// including) `start`, each multiplying both rates independently by a
/// factor drawn from `[factor_lo, factor_hi)` and clamping to the suites'
/// 1e4..1e9 B/s regime. Factors below 1 model fading (σ = 1/R_up +
/// 1/R_down grows, transformed-network capacities grow), factors above 1
/// model recovery (capacities shrink — the repair case of the
/// incremental re-solver). With 1.0 outside the factor range, consecutive
/// links differ **as long as the clamp does not engage** — a rate pinned
/// at a regime bound repeats while its factors keep pushing outward, so
/// callers that rely on every step being dirty (the σ-drift regressions
/// and `benches/replan.rs` do) must pick `start`/`steps`/factors whose
/// walk stays inside 1e4..1e9. Shared by the σ-drift regression suites
/// and `benches/replan.rs`.
pub fn fading_walk(
    rng: &mut Rng,
    start: Link,
    steps: usize,
    factor_lo: f64,
    factor_hi: f64,
) -> Vec<Link> {
    let mut links = Vec::with_capacity(steps);
    let (mut up, mut down) = (start.up_bps, start.down_bps);
    for _ in 0..steps {
        up = (up * rng.range(factor_lo, factor_hi)).clamp(1e4, 1e9);
        down = (down * rng.range(factor_lo, factor_hi)).clamp(1e4, 1e9);
        links.push(Link {
            up_bps: up,
            down_bps: down,
        });
    }
    links
}

/// Relative tolerance of [`assert_cut_cost_equal`], in units of
/// `f64::EPSILON` at the delay's magnitude (i.e. ULPs): 2^16. Two
/// co-optimal cuts have mathematically equal T(cut), but evaluating Eq. (7)
/// over *different* device sets sums different terms in different orders,
/// so the computed delays may differ by accumulation rounding — a few
/// hundred ULPs at zoo-model sizes, bounded comfortably by 2^16 ULPs
/// (≈1.5e-11 relative) while staying orders of magnitude below any genuine
/// cost gap between distinct cut values.
pub const CUT_COST_ULPS: f64 = 65536.0;

/// Assert two partitions of the same problem are **cost-equivalent**: both
/// feasible, and with equal total training delay T(cut) under the paper's
/// Eq. (7) cost model, to within the ULP-scale tolerance [`CUT_COST_ULPS`].
///
/// This is the property that licenses the fleet-level block reduction:
/// Theorem 2 preserves the optimal *value*, not the argmin, so reduced-DAG
/// and full-DAG solves may tie-break among co-optimal cuts differently and
/// bit-identity of device sets cannot be demanded. Both delays are
/// re-evaluated here through the same [`Problem::delay`] path, so a stored
/// delay's provenance (reduced vs full evaluation) cannot skew the
/// comparison.
pub fn assert_cut_cost_equal(problem: &Problem, a: &Partition, b: &Partition) {
    assert_cut_cost_within(problem, a, b, 0.0);
}

/// Generalization of [`assert_cut_cost_equal`] with an explicit additive
/// slack `eps` (in seconds) on top of the ULP-scale rounding allowance:
/// both cuts must be feasible and their re-evaluated Eq. (7) delays must
/// satisfy `|T(a) − T(b)| ≤ eps + tol`. `eps = 0` is exactly the old
/// ULP-equality harness (and [`assert_cut_cost_equal`] delegates here);
/// positive `eps` is the σ-quantization harness — a quantized decision is
/// only cost-equal to the unquantized one up to the analytic per-bucket
/// bound `(B_a + B_b)·Δσ` (delay is affine in σ for a fixed cut; see
/// PERF.md "PR 8" for the derivation), so the caller computes that bound
/// and passes it as `eps`.
pub fn assert_cut_cost_within(problem: &Problem, a: &Partition, b: &Partition, eps: f64) {
    assert!(
        eps >= 0.0 && eps.is_finite(),
        "cost slack must be finite and non-negative, got {eps}"
    );
    assert!(
        problem.is_feasible(&a.device_set),
        "first cut is infeasible: {:?}",
        a.device_set
    );
    assert!(
        problem.is_feasible(&b.device_set),
        "second cut is infeasible: {:?}",
        b.device_set
    );
    let ta = problem.delay(&a.device_set);
    let tb = problem.delay(&b.device_set);
    let tol = CUT_COST_ULPS * f64::EPSILON * (1.0 + ta.abs().max(tb.abs()));
    assert!(
        (ta - tb).abs() <= eps + tol,
        "cut costs differ: {ta} vs {tb} (|delta| = {:.3e}, eps = {eps:.3e}, tol = {tol:.3e}, \
         device layers {} vs {})",
        (ta - tb).abs(),
        a.device_layers(),
        b.device_layers(),
    );
}

/// Assert two fleet makespans of the same joint problem are equal within
/// the [`CUT_COST_ULPS`] tolerance — the fleet-level sibling of
/// [`assert_cut_cost_equal`], used to pin `partition::joint::JointPlanner`
/// to the brute-force oracle's optimum and warm joint re-solves to cold
/// ones. Co-optimal fleet plans may pick different cut combinations (and
/// the two sides bisect their makespans independently), so the pinned
/// property is the optimal *value*, converged to ULP scale on both sides.
pub fn assert_fleet_cost_equal(a: f64, b: f64, context: &str) {
    assert!(
        a.is_finite() && b.is_finite(),
        "non-finite fleet makespan ({context}): {a} vs {b}"
    );
    let tol = CUT_COST_ULPS * f64::EPSILON * (1.0 + a.abs().max(b.abs()));
    assert!(
        (a - b).abs() <= tol,
        "fleet makespans differ ({context}): {a} vs {b} \
         (|delta| = {:.3e}, tol = {tol:.3e})",
        (a - b).abs(),
    );
}

/// The joint sibling of [`fading_walk`]: drift a link's rates **and** a
/// shared server capacity together. Each step multiplies both rates by
/// factors from `[factor_lo, factor_hi)` exactly as [`fading_walk`] does,
/// then multiplies the capacity by its own factor from the same range,
/// clamped to `[0.05, 64.0]` device-equivalents — low enough to congest
/// small fleets, high enough to de-congest them, so a two-sided walk
/// exercises both joint regimes and the transitions between them. Shared
/// by the joint σ/capacity fuzz lane and `benches/joint.rs`.
pub fn joint_fading_walk(
    rng: &mut Rng,
    start: Link,
    start_capacity: f64,
    steps: usize,
    factor_lo: f64,
    factor_hi: f64,
) -> Vec<(Link, f64)> {
    let links = fading_walk(rng, start, steps, factor_lo, factor_hi);
    let mut capacity = start_capacity;
    links
        .into_iter()
        .map(|link| {
            capacity = (capacity * rng.range(factor_lo, factor_hi)).clamp(0.05, 64.0);
            (link, capacity)
        })
        .collect()
}

/// One (model, device-tier) cell of the shared generator matrix.
pub struct ZooCase {
    pub model: &'static str,
    pub tier: &'static str,
    pub costs: CostGraph,
}

/// The shared generator matrix of the partition property suites: every zoo
/// model × every Jetson device tier, each cell receiving its own
/// deterministic RNG for drawing random links (suites draw ≥13 links per
/// cell, so every model sees ≥52 random (tier, link) pairs — the ISSUE's
/// ≥50-draw floor). The base seed comes from
/// [`crate::util::rng::test_seed`]; on failure the cell and the base seed
/// are reported so the whole matrix replays with `PALLAS_TEST_SEED`.
pub fn zoo_matrix<F: FnMut(&ZooCase, &mut Rng)>(name: &str, mut prop: F) {
    let base = crate::util::rng::test_seed();
    let server = DeviceProfile::rtx_a6000();
    let tiers = [
        DeviceProfile::jetson_tx1(),
        DeviceProfile::jetson_tx2(),
        DeviceProfile::jetson_orin_nano(),
        DeviceProfile::jetson_agx_orin(),
    ];
    for &model in models::MODEL_NAMES {
        let m = models::by_name(model).expect("zoo model");
        for (t, device) in tiers.iter().enumerate() {
            let case = ZooCase {
                model,
                tier: device.name,
                costs: CostGraph::build(&m, device, &server, &TrainCfg::default()),
            };
            let seed = mix(mix(base, fnv(model)), t as u64 + 1);
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let mut rng = Rng::new(seed);
                prop(&case, &mut rng);
            }));
            if let Err(payload) = result {
                let msg = panic_message(payload.as_ref());
                panic!(
                    "matrix property '{name}' failed on {model}/{} (cell seed {seed}, \
                     base seed {base}):\n{msg}\n\
                     replay the suite with PALLAS_TEST_SEED={base}",
                    device.name
                );
            }
        }
    }
}

/// Run a **single** seeded case with replay-parity failure reporting —
/// the one-case sibling of [`for_all`]/[`zoo_matrix`] for oracle gates and
/// walk tests that draw randomness once instead of iterating a case
/// matrix. The case RNG is seeded with `test_seed() ^ salt` (`salt`
/// decorrelates different gates under the same base seed), so
/// `PALLAS_TEST_SEED` reseeds the gate along with every other suite; on
/// failure the message carries the base seed *and* the derived case seed
/// plus the replay recipe. Before PR 10 several oracle gates seeded
/// `Rng::new` directly and asserted bare, so a fuzz failure under a CI
/// seed printed neither — unreplayable by construction (the ISSUE-10
/// bugfix).
pub fn seeded_case<F: FnOnce(&mut Rng)>(name: &str, salt: u64, f: F) {
    let base = crate::util::rng::test_seed();
    let seed = base ^ salt;
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let mut rng = Rng::new(seed);
        f(&mut rng);
    }));
    if let Err(payload) = result {
        let msg = panic_message(payload.as_ref());
        panic!(
            "seeded case '{name}' failed (case seed {seed}, base seed {base}):\n{msg}\n\
             replay with PALLAS_TEST_SEED={base}"
        );
    }
}

/// A random relay path of `hops` independent links, each drawn from the
/// suites' 1e4..1e9 B/s regime — the multi-hop sibling of
/// [`random_link`]. Hop `k` connects path host `k` to host `k+1` (host 0
/// is the device, the last host the final server), so a K-segment
/// multi-hop problem draws `hops = K` links.
pub fn random_path(rng: &mut Rng, hops: usize) -> Vec<Link> {
    (0..hops).map(|_| random_link(rng)).collect()
}

/// One churn fault a [`ChurnScript`] injects into a planning epoch — the
/// device-membership subset of [`SpecDelta`] (tier add/retire are
/// rarer operator actions, covered by direct unit tests instead of the
/// random walk).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ChurnEvent {
    /// A device (re-)joins the fleet on an active tier.
    Join { device: usize, tier: usize },
    /// A device drops out of the fleet.
    Leave { device: usize },
    /// A device moves to a different tier (hardware swap / re-profile).
    Migrate { device: usize, tier: usize },
}

impl ChurnEvent {
    /// The [`SpecDelta`] this event patches the fleet with.
    pub fn to_delta(&self) -> SpecDelta {
        match *self {
            ChurnEvent::Join { device, tier } => SpecDelta::AddDevice { device, tier },
            ChurnEvent::Leave { device } => SpecDelta::RemoveDevice { device },
            ChurnEvent::Migrate { device, tier } => SpecDelta::MigrateDevice { device, tier },
        }
    }
}

/// One tick of a [`ChurnScript`]: the churn events to apply *before* the
/// tick's reports, the link reports that actually arrive (withheld
/// reports model the stale/drop faults — a joined device that has not yet
/// reported is the drop case), and the per-slot ground-truth links for
/// feasibility/envelope checks.
#[derive(Clone, Debug)]
pub struct ChurnTick {
    pub events: Vec<ChurnEvent>,
    /// `(device, link)` reports delivered this tick; always truthful
    /// (staleness comes from *withholding* later reports, not lying).
    pub reports: Vec<(usize, Link)>,
    /// Ground-truth link per device slot at this tick (length
    /// `max_devices`; departed slots keep drifting, ready for a re-join).
    pub true_links: Vec<Link>,
}

/// A replayable fault-injection script for the churn-tolerant planning
/// service: seeded membership churn + report withholding over a per-device
/// fading walk. Deterministic for a fixed RNG, so `PALLAS_TEST_SEED`
/// replays the whole scenario (the PR-6 harness contract, RESILIENCE.md).
#[derive(Clone, Debug)]
pub struct ChurnScript {
    pub ticks: Vec<ChurnTick>,
}

/// Generate a seeded [`ChurnScript`]: `max_devices` slots (all active at
/// start, slot `d` on tier `d % num_tiers`), each tick drifting every
/// slot's link by ±10% (clamped to the suites' 1e4..1e9 B/s regime), then
/// churning each slot with probability `churn_prob` (active slots leave or
/// migrate, departed slots re-join on a random tier — the fleet never
/// empties) and withholding each active slot's report with probability
/// `stale_prob`.
pub fn churn_script(
    rng: &mut Rng,
    num_tiers: usize,
    max_devices: usize,
    ticks: usize,
    churn_prob: f64,
    stale_prob: f64,
) -> ChurnScript {
    assert!(num_tiers >= 1 && max_devices >= 1);
    let mut tier_of: Vec<Option<usize>> = (0..max_devices).map(|d| Some(d % num_tiers)).collect();
    let mut links: Vec<Link> = (0..max_devices)
        .map(|_| Link {
            up_bps: rng.range(1e5, 1e6),
            down_bps: rng.range(1e5, 1e6),
        })
        .collect();
    let mut out = Vec::with_capacity(ticks);
    for _ in 0..ticks {
        for l in &mut links {
            l.up_bps = (l.up_bps * rng.range(0.9, 1.1)).clamp(1e4, 1e9);
            l.down_bps = (l.down_bps * rng.range(0.9, 1.1)).clamp(1e4, 1e9);
        }
        let mut events = Vec::new();
        for d in 0..max_devices {
            if !rng.chance(churn_prob) {
                continue;
            }
            match tier_of[d] {
                Some(cur) => {
                    let active = tier_of.iter().filter(|t| t.is_some()).count();
                    if rng.chance(0.5) && active > 1 {
                        events.push(ChurnEvent::Leave { device: d });
                        tier_of[d] = None;
                    } else if num_tiers > 1 {
                        let tier = (cur + 1 + rng.index(num_tiers - 1)) % num_tiers;
                        events.push(ChurnEvent::Migrate { device: d, tier });
                        tier_of[d] = Some(tier);
                    }
                }
                None => {
                    let tier = rng.index(num_tiers);
                    events.push(ChurnEvent::Join { device: d, tier });
                    tier_of[d] = Some(tier);
                }
            }
        }
        let mut reports = Vec::new();
        for d in 0..max_devices {
            if tier_of[d].is_some() && !rng.chance(stale_prob) {
                reports.push((d, links[d]));
            }
        }
        out.push(ChurnTick {
            events,
            reports,
            true_links: links.clone(),
        });
    }
    ChurnScript { ticks: out }
}

/// PR 9's crash-injection view of a [`ChurnScript`]: the same seeded
/// scenario plus the resume arithmetic the recovery harness needs. The
/// daemon journals every ingested event, so "how far did the crashed run
/// get" is an event count; [`CrashScript::resume_position`] maps that
/// count back to the first undelivered event under the canonical
/// delivery order (each tick's churn deltas first, then its reports).
#[derive(Clone, Debug)]
pub struct CrashScript {
    pub script: ChurnScript,
}

impl CrashScript {
    pub fn new(script: ChurnScript) -> CrashScript {
        CrashScript { script }
    }

    /// Events delivered per tick under the canonical order (all churn
    /// deltas, then all reports).
    pub fn events_per_tick(&self) -> Vec<usize> {
        self.script
            .ticks
            .iter()
            .map(|t| t.events.len() + t.reports.len())
            .collect()
    }

    /// Total events the full script delivers.
    pub fn total_events(&self) -> u64 {
        self.events_per_tick().iter().map(|&n| n as u64).sum()
    }

    /// Where a run that consumed `consumed` events stopped: the
    /// `(tick, within-tick index)` of the first undelivered event.
    /// Zero-event ticks are skipped (there is nothing to deliver in
    /// them); consuming the whole script yields `(ticks.len(), 0)`.
    /// Callers resuming a crashed run must still re-pump the ticks
    /// before the returned position — the event count alone cannot say
    /// how far the crashed run's *pumping* got, only its delivery.
    pub fn resume_position(&self, consumed: u64) -> (usize, usize) {
        let mut remaining = consumed;
        for (tick, &n) in self.events_per_tick().iter().enumerate() {
            if remaining < n as u64 {
                return (tick, remaining as usize);
            }
            remaining -= n as u64;
        }
        (self.script.ticks.len(), 0)
    }
}

/// Assert the stale-σ envelope of a degraded decision (the PR-6 cost
/// contract; derivation in PERF.md "PR 6"): for a fixed cut `x`, Eq. (7)
/// delay is affine in σ = 1/R_up + 1/R_down — `T(x, σ) = C(x) + B(x)·σ`
/// with `B(x) ≥ 0` the cut's transmitted bytes. If `served` was optimal at
/// `stale_link` (it was the planner's answer there), then under the true
/// link
///
/// ```text
/// T(served, σ_true) ≤ T(opt, σ_true) + (B_served + B_opt)·|σ_true − σ_stale|
/// ```
///
/// where `opt` is the true-link optimum. Both `B·|Δσ|` swings are
/// evaluated directly on the link pair (no slope division), and the
/// comparison carries the usual [`CUT_COST_ULPS`] rounding allowance.
pub fn assert_stale_sigma_envelope(
    costs: &CostGraph,
    pin_inputs: bool,
    true_link: Link,
    stale_link: Link,
    served: &[bool],
) {
    let fresh = Problem::with_pin(costs, true_link, pin_inputs);
    let stale = Problem::with_pin(costs, stale_link, pin_inputs);
    assert!(
        fresh.is_feasible(served),
        "served cut infeasible under the true link: {served:?}"
    );
    let opt = general_partition(&fresh);
    let served_true = fresh.delay(served);
    let swing_served = (served_true - stale.delay(served)).abs();
    let swing_opt = (fresh.delay(&opt.device_set) - stale.delay(&opt.device_set)).abs();
    let bound = opt.delay + swing_served + swing_opt;
    let tol = CUT_COST_ULPS * f64::EPSILON * (1.0 + served_true.abs().max(bound.abs()));
    assert!(
        served_true <= bound + tol,
        "stale-σ envelope violated: served T = {served_true}, optimal T = {}, \
         bound = {bound} (σ_true = {:.3e}, σ_stale = {:.3e})",
        opt.delay,
        true_link.sigma(),
        stale_link.sigma(),
    );
}

fn fnv(s: &str) -> u64 {
    s.bytes()
        .fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
            (h ^ b as u64).wrapping_mul(0x100_0000_01b3)
        })
}

fn mix(a: u64, b: u64) -> u64 {
    (a ^ b).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn for_all_runs_all_cases() {
        let mut count = 0;
        for_all("counter", 16, |_rng| {
            count += 1;
        });
        assert_eq!(count, 16);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn for_all_reports_failure() {
        for_all("fails", 8, |rng| {
            assert!(rng.f64() < 2.0); // always true
            panic!("boom");
        });
    }

    #[test]
    fn random_dag_is_acyclic_and_connected() {
        for_all("dag-shape", 32, |rng| {
            let n = 2 + rng.index(20);
            let edges = random_layer_dag(rng, n, 0.2);
            let mut has_parent = vec![false; n];
            for &(u, v) in &edges {
                assert!(u < v, "forward edges only");
                assert!(v < n);
                has_parent[v] = true;
            }
            for v in 1..n {
                assert!(has_parent[v], "vertex {v} orphaned");
            }
        });
    }

    #[test]
    fn fading_walk_stays_in_regime_and_always_moves() {
        for_all("fading-walk", 16, |rng| {
            let start = Link {
                up_bps: 1e6,
                down_bps: 4e6,
            };
            let links = fading_walk(rng, start, 20, 1.02, 1.3);
            assert_eq!(links.len(), 20);
            let mut prev = start;
            for l in links {
                assert!(l.up_bps >= 1e4 && l.up_bps <= 1e9);
                assert!(l.down_bps >= 1e4 && l.down_bps <= 1e9);
                assert!(
                    l.up_bps != prev.up_bps && l.down_bps != prev.down_bps,
                    "consecutive links must differ"
                );
                prev = l;
            }
        });
    }

    #[test]
    fn fleet_cost_equal_accepts_ulp_noise_and_rejects_gaps() {
        assert_fleet_cost_equal(1.0, 1.0 + 1e-13, "ulp-scale noise");
        let gap = std::panic::catch_unwind(|| assert_fleet_cost_equal(1.0, 1.01, "gap"));
        assert!(gap.is_err(), "a 1% makespan gap must not compare equal");
        let inf = std::panic::catch_unwind(|| {
            assert_fleet_cost_equal(f64::INFINITY, f64::INFINITY, "inf")
        });
        assert!(inf.is_err(), "non-finite makespans must be rejected");
    }

    #[test]
    fn joint_fading_walk_drifts_both_axes_within_bounds() {
        for_all("joint-walk", 8, |rng| {
            let start = Link {
                up_bps: 1e6,
                down_bps: 2e6,
            };
            let walk = joint_fading_walk(rng, start, 1.0, 24, 0.85, 1.2);
            assert_eq!(walk.len(), 24);
            for (l, c) in walk {
                assert!((0.05..=64.0).contains(&c), "capacity {c} out of bounds");
                assert!(l.up_bps >= 1e4 && l.up_bps <= 1e9);
                assert!(l.down_bps >= 1e4 && l.down_bps <= 1e9);
            }
        });
    }

    #[test]
    fn zoo_matrix_covers_every_model_tier_cell() {
        let mut cells: Vec<(String, String)> = Vec::new();
        zoo_matrix("coverage", |case, rng| {
            assert_eq!(case.costs.len(), models::by_name(case.model).unwrap().len());
            let l = random_link(rng);
            assert!(l.up_bps >= 1e4 && l.up_bps < 1e9);
            cells.push((case.model.to_string(), case.tier.to_string()));
        });
        assert_eq!(cells.len(), models::MODEL_NAMES.len() * 4);
        // Deterministic order and no duplicate cells.
        let mut dedup = cells.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), cells.len());
    }

    #[test]
    #[should_panic(expected = "matrix property 'zoo-fails'")]
    fn zoo_matrix_reports_cell_and_seed() {
        zoo_matrix("zoo-fails", |_case, _rng| panic!("boom"));
    }

    #[test]
    fn seeded_case_is_deterministic_and_salt_decorrelated() {
        let mut first = Vec::new();
        seeded_case("draws", 0x5EED, |rng| {
            first = vec![rng.f64(), rng.f64(), rng.f64()];
        });
        let mut again = Vec::new();
        seeded_case("draws", 0x5EED, |rng| {
            again = vec![rng.f64(), rng.f64(), rng.f64()];
        });
        assert_eq!(first, again, "same salt must replay the same stream");
        let mut other = Vec::new();
        seeded_case("draws", 0x5EED + 1, |rng| {
            other = vec![rng.f64(), rng.f64(), rng.f64()];
        });
        assert_ne!(first, other, "different salts must decorrelate");
    }

    #[test]
    fn seeded_case_failure_echoes_both_seeds() {
        let payload = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            seeded_case("gate-fails", 0xBAD, |_rng| panic!("boom"));
        }))
        .expect_err("the case must fail");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .expect("string panic payload");
        let base = crate::util::rng::test_seed();
        let seed = base ^ 0xBAD;
        assert!(msg.contains("seeded case 'gate-fails' failed"), "{msg}");
        assert!(msg.contains(&format!("case seed {seed}")), "{msg}");
        assert!(msg.contains(&format!("base seed {base}")), "{msg}");
        assert!(msg.contains(&format!("PALLAS_TEST_SEED={base}")), "{msg}");
    }

    #[test]
    fn random_path_draws_hops_independent_valid_links() {
        for_all("random-path", 8, |rng| {
            let path = random_path(rng, 4);
            assert_eq!(path.len(), 4);
            for l in &path {
                assert!(l.is_valid());
                assert!(l.up_bps >= 1e4 && l.up_bps < 1e9);
                assert!(l.down_bps >= 1e4 && l.down_bps < 1e9);
            }
            assert!(
                path.windows(2).all(|w| w[0] != w[1]),
                "consecutive hops must differ"
            );
        });
    }

    #[test]
    fn cost_equal_accepts_coptimal_and_rejects_gaps() {
        let m = models::by_name("lenet5").unwrap();
        let costs = CostGraph::build(
            &m,
            &DeviceProfile::jetson_tx2(),
            &DeviceProfile::rtx_a6000(),
            &TrainCfg::default(),
        );
        let p = Problem::new(&costs, Link::symmetric(1e6));
        let all = p.device_only();
        assert_cut_cost_equal(&p, &all, &all);
        let mut prefix = vec![false; costs.len()];
        prefix[0] = true;
        let one = p.partition(prefix);
        let gap = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            assert_cut_cost_equal(&p, &all, &one);
        }));
        assert!(gap.is_err(), "distinct cut costs must not compare equal");
    }

    /// `assert_cut_cost_within` is the ULP harness plus an additive slack:
    /// eps = 0 matches `assert_cut_cost_equal` exactly, a gap inside eps
    /// passes, a gap outside it still fails, and negative / non-finite
    /// slacks are rejected outright.
    #[test]
    fn cut_cost_within_honors_the_additive_slack() {
        let m = models::by_name("lenet5").unwrap();
        let costs = CostGraph::build(
            &m,
            &DeviceProfile::jetson_tx2(),
            &DeviceProfile::rtx_a6000(),
            &TrainCfg::default(),
        );
        let p = Problem::new(&costs, Link::symmetric(1e6));
        let all = p.device_only();
        let mut prefix = vec![false; costs.len()];
        prefix[0] = true;
        let one = p.partition(prefix);
        let gap = (p.delay(&all.device_set) - p.delay(&one.device_set)).abs();
        assert!(gap > 0.0, "test needs two cuts with distinct costs");
        // Slack covering the gap passes; half the gap does not.
        assert_cut_cost_within(&p, &all, &one, gap * 1.01);
        let tight = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            assert_cut_cost_within(&p, &all, &one, gap * 0.5);
        }));
        assert!(tight.is_err(), "half-gap slack must still fail");
        for bad in [-1.0, f64::NAN, f64::INFINITY] {
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                assert_cut_cost_within(&p, &all, &all, bad);
            }));
            assert!(r.is_err(), "slack {bad} must be rejected");
        }
    }

    #[test]
    fn churn_script_respects_membership_invariants() {
        for_all("churn-script-shape", 16, |rng| {
            let num_tiers = 1 + rng.index(4);
            let max_devices = 1 + rng.index(8);
            let script = churn_script(rng, num_tiers, max_devices, 12, 0.5, 0.4);
            assert_eq!(script.ticks.len(), 12);
            let mut tier_of: Vec<Option<usize>> =
                (0..max_devices).map(|d| Some(d % num_tiers)).collect();
            for step in &script.ticks {
                assert_eq!(step.true_links.len(), max_devices);
                for l in &step.true_links {
                    assert!(l.up_bps >= 1e4 && l.up_bps <= 1e9);
                    assert!(l.down_bps >= 1e4 && l.down_bps <= 1e9);
                }
                for ev in &step.events {
                    // Events are valid against the tracked membership —
                    // join only on empty slots, leave/migrate only on
                    // occupied ones, tiers in range.
                    match *ev {
                        ChurnEvent::Join { device, tier } => {
                            assert!(tier_of[device].is_none(), "join on an occupied slot");
                            assert!(tier < num_tiers);
                            tier_of[device] = Some(tier);
                        }
                        ChurnEvent::Leave { device } => {
                            assert!(tier_of[device].is_some(), "leave from an empty slot");
                            tier_of[device] = None;
                        }
                        ChurnEvent::Migrate { device, tier } => {
                            assert!(tier < num_tiers);
                            let cur = tier_of[device].expect("migrate from an empty slot");
                            assert_ne!(cur, tier, "migrate must change tiers");
                            tier_of[device] = Some(tier);
                        }
                    }
                }
                assert!(
                    tier_of.iter().any(|t| t.is_some()),
                    "the fleet must never empty"
                );
                for &(d, link) in &step.reports {
                    assert!(tier_of[d].is_some(), "departed devices must not report");
                    assert_eq!(link, step.true_links[d], "reports are truthful");
                }
            }
        });
    }

    /// Every prefix length of the event stream maps to the position of
    /// the first undelivered event, and the full stream maps past the
    /// last tick — the arithmetic the PR 9 crash-recovery harness
    /// resumes runs with.
    #[test]
    fn crash_script_resume_positions_partition_the_event_stream() {
        for_all("crash-script-resume", 8, |rng| {
            let script = CrashScript::new(churn_script(rng, 3, 5, 8, 0.5, 0.4));
            let per_tick = script.events_per_tick();
            assert_eq!(per_tick.len(), 8);
            let mut consumed = 0u64;
            for (tick, &n) in per_tick.iter().enumerate() {
                for within in 0..n {
                    assert_eq!(script.resume_position(consumed), (tick, within));
                    consumed += 1;
                }
            }
            assert_eq!(consumed, script.total_events());
            assert_eq!(script.resume_position(consumed), (8, 0));
            assert_eq!(script.resume_position(consumed + 5), (8, 0));
        });
    }

    #[test]
    fn stale_sigma_envelope_holds_for_stale_optimal_cuts() {
        let m = models::by_name("googlenet").unwrap();
        let costs = CostGraph::build(
            &m,
            &DeviceProfile::jetson_tx2(),
            &DeviceProfile::rtx_a6000(),
            &TrainCfg::default(),
        );
        for_all("stale-sigma-envelope", 24, |rng| {
            let true_link = random_link(rng);
            let stale_link = random_link(rng);
            // Any cut optimal at the stale link satisfies the envelope at
            // the true link — including the degenerate stale == true case.
            let served = general_partition(&Problem::new(&costs, stale_link));
            assert_stale_sigma_envelope(&costs, true, true_link, stale_link, &served.device_set);
            assert_stale_sigma_envelope(&costs, true, true_link, true_link, &served.device_set);
        });
    }
}
