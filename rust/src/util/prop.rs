//! Lightweight property-testing driver (proptest is unavailable offline).
//!
//! [`for_all`] runs a property over `cases` seeded generations; on failure
//! it retries with the same seed to confirm determinism and reports the
//! failing seed so the case can be replayed with `FASTSPLIT_PROP_SEED`.

use super::rng::Rng;

/// Number of cases to run per property (override with FASTSPLIT_PROP_CASES).
pub fn default_cases() -> u64 {
    std::env::var("FASTSPLIT_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Run `prop(rng)` for `cases` different deterministic seeds. Panics with
/// the failing seed embedded in the message on the first failure.
pub fn for_all<F: FnMut(&mut Rng)>(name: &str, cases: u64, mut prop: F) {
    // Allow pinning a single seed for replay.
    if let Ok(seed) = std::env::var("FASTSPLIT_PROP_SEED") {
        if let Ok(seed) = seed.parse::<u64>() {
            let mut rng = Rng::new(seed);
            prop(&mut rng);
            return;
        }
    }
    let base = 0xF057_5EEDu64;
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::new(seed);
            prop(&mut rng);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed on case {case} (seed {seed}):\n{msg}\n\
                 replay with FASTSPLIT_PROP_SEED={seed}"
            );
        }
    }
}

/// Generate a random connected DAG as an edge list over `n` vertices where
/// every edge goes from a lower to a higher index (guaranteeing acyclicity)
/// and every vertex (except 0) has at least one parent — shaped like layer
/// graphs: a chain backbone with extra skip/branch edges.
pub fn random_layer_dag(rng: &mut Rng, n: usize, extra_edge_prob: f64) -> Vec<(usize, usize)> {
    assert!(n >= 2);
    let mut edges = Vec::new();
    for v in 1..n {
        // Backbone parent: usually the previous vertex (chain-like models),
        // occasionally an earlier one (branching).
        let parent = if v == 1 || rng.chance(0.8) {
            v - 1
        } else {
            rng.index(v)
        };
        edges.push((parent, v));
    }
    // Extra forward edges: skip connections / parallel branches.
    for u in 0..n {
        for v in (u + 1)..n.min(u + 6) {
            if rng.chance(extra_edge_prob) && !edges.contains(&(u, v)) {
                edges.push((u, v));
            }
        }
    }
    edges.sort();
    edges.dedup();
    edges
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn for_all_runs_all_cases() {
        let mut count = 0;
        for_all("counter", 16, |_rng| {
            count += 1;
        });
        assert_eq!(count, 16);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn for_all_reports_failure() {
        for_all("fails", 8, |rng| {
            assert!(rng.f64() < 2.0); // always true
            panic!("boom");
        });
    }

    #[test]
    fn random_dag_is_acyclic_and_connected() {
        for_all("dag-shape", 32, |rng| {
            let n = 2 + rng.index(20);
            let edges = random_layer_dag(rng, n, 0.2);
            let mut has_parent = vec![false; n];
            for &(u, v) in &edges {
                assert!(u < v, "forward edges only");
                assert!(v < n);
                has_parent[v] = true;
            }
            for v in 1..n {
                assert!(has_parent[v], "vertex {v} orphaned");
            }
        });
    }
}
