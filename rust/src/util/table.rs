//! Plain-text table rendering for experiment harnesses — every paper
//! figure/table harness prints its rows through this so EXPERIMENTS.md can
//! quote outputs verbatim.

/// A simple column-aligned text table.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn row_strs(&mut self, cells: &[&str]) {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    }

    /// Render with column alignment and a separator under the header.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut width = vec![0usize; ncols];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.chars().count();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(c);
                for _ in c.chars().count()..width[i] {
                    line.push(' ');
                }
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &width));
        out.push('\n');
        out.push_str(&"-".repeat(width.iter().sum::<usize>() + 2 * (ncols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &width));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["model", "time"]);
        t.row_strs(&["resnet18", "1.2ms"]);
        t.row_strs(&["googlenet-wide", "0.3ms"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("model"));
        assert!(lines[2].starts_with("resnet18"));
        // aligned: "time" column starts at same offset in all rows
        let off = lines[0].find("time").unwrap();
        assert_eq!(&lines[3][off..off + 5], "0.3ms");
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn rejects_bad_row() {
        let mut t = Table::new(&["a", "b"]);
        t.row_strs(&["only-one"]);
    }
}
