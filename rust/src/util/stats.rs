//! Summary statistics for experiment harnesses and the bench runner.

/// Summary of a sample: mean, standard deviation, percentiles, extrema.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std_dev: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute a summary. Returns a zeroed summary for an empty sample.
    pub fn of(samples: &[f64]) -> Summary {
        if samples.is_empty() {
            return Summary {
                n: 0,
                mean: 0.0,
                std_dev: 0.0,
                min: 0.0,
                max: 0.0,
                p50: 0.0,
                p95: 0.0,
                p99: 0.0,
            };
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std_dev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 0.50),
            p95: percentile_sorted(&sorted, 0.95),
            p99: percentile_sorted(&sorted, 0.99),
        }
    }
}

/// Linear-interpolated percentile over an already-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Ordinary least squares fit of a degree-`deg` polynomial, returning
/// coefficients lowest-order first. Used by the regression baseline ([21]).
pub fn polyfit(xs: &[f64], ys: &[f64], deg: usize) -> Vec<f64> {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() > deg, "need more points than coefficients");
    let m = deg + 1;
    // Normal equations: (A^T A) c = A^T y with A[i][j] = x_i^j.
    let mut ata = vec![vec![0.0f64; m]; m];
    let mut aty = vec![0.0f64; m];
    for (&x, &y) in xs.iter().zip(ys) {
        let mut powers = vec![1.0f64; m];
        for j in 1..m {
            powers[j] = powers[j - 1] * x;
        }
        for i in 0..m {
            aty[i] += powers[i] * y;
            for j in 0..m {
                ata[i][j] += powers[i] * powers[j];
            }
        }
    }
    solve_linear(ata, aty)
}

/// Gaussian elimination with partial pivoting.
pub fn solve_linear(mut a: Vec<Vec<f64>>, mut b: Vec<f64>) -> Vec<f64> {
    let n = b.len();
    for col in 0..n {
        // Pivot.
        let mut piv = col;
        for r in col + 1..n {
            if a[r][col].abs() > a[piv][col].abs() {
                piv = r;
            }
        }
        a.swap(col, piv);
        b.swap(col, piv);
        let d = a[col][col];
        if d.abs() < 1e-12 {
            continue; // singular direction; leave as-is (coefficient -> 0)
        }
        for r in 0..n {
            if r == col {
                continue;
            }
            let f = a[r][col] / d;
            for c in col..n {
                a[r][c] -= f * a[col][c];
            }
            b[r] -= f * b[col];
        }
    }
    (0..n)
        .map(|i| {
            if a[i][i].abs() < 1e-12 {
                0.0
            } else {
                b[i] / a[i][i]
            }
        })
        .collect()
}

/// Evaluate a polynomial given coefficients lowest-order first.
pub fn polyval(coeffs: &[f64], x: f64) -> f64 {
    coeffs.iter().rev().fold(0.0, |acc, &c| acc * x + c)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.std_dev - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile_sorted(&sorted, 0.5) - 5.0).abs() < 1e-12);
        assert!((percentile_sorted(&sorted, 0.95) - 9.5).abs() < 1e-12);
    }

    #[test]
    fn polyfit_recovers_quadratic() {
        let xs: Vec<f64> = (0..20).map(|i| i as f64 * 0.5).collect();
        let ys: Vec<f64> = xs.iter().map(|&x| 2.0 - 3.0 * x + 0.5 * x * x).collect();
        let c = polyfit(&xs, &ys, 2);
        assert!((c[0] - 2.0).abs() < 1e-8, "{c:?}");
        assert!((c[1] + 3.0).abs() < 1e-8);
        assert!((c[2] - 0.5).abs() < 1e-8);
        assert!((polyval(&c, 3.0) - (2.0 - 9.0 + 4.5)).abs() < 1e-8);
    }

    #[test]
    fn solve_linear_identity() {
        let a = vec![vec![1.0, 0.0], vec![0.0, 2.0]];
        let x = solve_linear(a, vec![3.0, 8.0]);
        assert!((x[0] - 3.0).abs() < 1e-12);
        assert!((x[1] - 4.0).abs() < 1e-12);
    }
}
