//! Minimal JSON value model, parser, and pretty-printer.
//!
//! Used for the artifact manifest (`artifacts/manifest.json`), experiment
//! result rows, and configuration files. Supports the full JSON grammar
//! (objects, arrays, strings with escapes, numbers, booleans, null).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` so emission is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn str<S: Into<String>>(s: S) -> Json {
        Json::Str(s.into())
    }

    pub fn num<N: Into<f64>>(n: N) -> Json {
        Json::Num(n.into())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Compact single-line rendering.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Indented rendering (2-space).
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Error with byte offset context.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while let Some(b) = self.peek() {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let start = self.pos;
                    let rest = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| self.err("invalid UTF-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b) if b.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b) if b.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b) if b.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "42", "-3.5", "1e3", "\"hi\\nthere\""] {
            let v = Json::parse(src).unwrap();
            let re = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, re, "src={src}");
        }
    }

    #[test]
    fn nested_structure() {
        let src = r#"{"a": [1, 2, {"b": "x"}], "c": {"d": null}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
        let re = Json::parse(&v.pretty()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::Arr(vec![]).to_string(), "[]");
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.5).to_string(), "5.5");
    }
}
