//! Self-contained substrates that would normally come from external crates.
//!
//! The offline build only ships the `xla` crate's dependency tree, so the
//! deterministic PRNG + samplers ([`rng`]), a JSON emitter/parser ([`json`]),
//! a CLI argument parser ([`cli`]), summary statistics ([`stats`]), a
//! criterion-style micro-benchmark harness ([`bench`]), and a lightweight
//! property-testing driver ([`prop`]) are implemented here from scratch.

pub mod rng;
pub mod json;
pub mod cli;
pub mod stats;
pub mod bench;
pub mod prop;
pub mod table;

/// Format a duration in seconds with an adaptive unit (s / ms / µs / ns).
pub fn fmt_secs(s: f64) -> String {
    if !s.is_finite() {
        return format!("{s}");
    }
    let a = s.abs();
    if a >= 1.0 {
        format!("{s:.3} s")
    } else if a >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if a >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Format a byte count with an adaptive unit.
pub fn fmt_bytes(b: f64) -> String {
    let a = b.abs();
    if a >= 1024.0 * 1024.0 * 1024.0 {
        format!("{:.2} GiB", b / (1024.0 * 1024.0 * 1024.0))
    } else if a >= 1024.0 * 1024.0 {
        format!("{:.2} MiB", b / (1024.0 * 1024.0))
    } else if a >= 1024.0 {
        format!("{:.2} KiB", b / 1024.0)
    } else {
        format!("{b:.0} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_secs_units() {
        assert_eq!(fmt_secs(1.5), "1.500 s");
        assert_eq!(fmt_secs(0.0015), "1.500 ms");
        assert_eq!(fmt_secs(0.0000015), "1.500 µs");
        assert_eq!(fmt_secs(1.5e-9), "1.5 ns");
    }

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(512.0), "512 B");
        assert_eq!(fmt_bytes(2048.0), "2.00 KiB");
        assert_eq!(fmt_bytes(3.0 * 1024.0 * 1024.0), "3.00 MiB");
    }
}
