//! Artifact manifest: shapes and file names of every AOT-compiled function,
//! parsed from `artifacts/manifest.json` (written by python/compile/aot.py).

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Declared input tensor of an artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT artifact entry.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub batch: usize,
    pub img: usize,
    pub channels: usize,
    pub num_classes: usize,
    pub stages: usize,
    pub cuts: Vec<usize>,
    pub param_shapes: Vec<Vec<usize>>,
    pub artifacts: BTreeMap<String, ArtifactInfo>,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: &str) -> Result<Manifest> {
        let dir = Path::new(dir).to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let json = Json::parse(&text).map_err(|e| anyhow!("{e}"))?;

        let usize_field = |k: &str| -> Result<usize> {
            json.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("manifest missing '{k}'"))
        };
        let batch = usize_field("batch")?;
        let img = usize_field("img")?;
        let channels = usize_field("channels")?;
        let num_classes = usize_field("num_classes")?;
        let stages = usize_field("stages")?;
        let cuts: Vec<usize> = json
            .get("cuts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing 'cuts'"))?
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        let param_shapes: Vec<Vec<usize>> = json
            .get("param_shapes")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing 'param_shapes'"))?
            .iter()
            .map(|s| {
                s.as_arr()
                    .map(|a| a.iter().filter_map(Json::as_usize).collect())
                    .unwrap_or_default()
            })
            .collect();

        let mut artifacts = BTreeMap::new();
        let arts = json
            .get("artifacts")
            .ok_or_else(|| anyhow!("manifest missing 'artifacts'"))?;
        if let Json::Obj(map) = arts {
            for (name, info) in map {
                let file = info
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("artifact '{name}' missing file"))?;
                let inputs = info
                    .get("inputs")
                    .and_then(Json::as_arr)
                    .unwrap_or(&[])
                    .iter()
                    .map(|i| TensorSpec {
                        shape: i
                            .get("shape")
                            .and_then(Json::as_arr)
                            .map(|a| a.iter().filter_map(Json::as_usize).collect())
                            .unwrap_or_default(),
                        dtype: i
                            .get("dtype")
                            .and_then(Json::as_str)
                            .unwrap_or("float32")
                            .to_string(),
                    })
                    .collect();
                artifacts.insert(
                    name.clone(),
                    ArtifactInfo {
                        file: dir.join(file),
                        inputs,
                    },
                );
            }
        }

        let m = Manifest {
            dir,
            batch,
            img,
            channels,
            num_classes,
            stages,
            cuts,
            param_shapes,
            artifacts,
        };
        m.validate()?;
        Ok(m)
    }

    fn validate(&self) -> Result<()> {
        for cut in &self.cuts {
            for prefix in ["dev_fwd", "srv_step", "dev_bwd"] {
                let name = format!("{prefix}_cut{cut}");
                let info = self
                    .artifacts
                    .get(&name)
                    .ok_or_else(|| anyhow!("manifest missing artifact '{name}'"))?;
                if !info.file.exists() {
                    return Err(anyhow!("artifact file missing: {}", info.file.display()));
                }
            }
        }
        for name in ["full_step", "predict"] {
            if !self.artifacts.contains_key(name) {
                return Err(anyhow!("manifest missing artifact '{name}'"));
            }
        }
        Ok(())
    }

    /// Load the initial parameter values exported by aot.py.
    pub fn load_init_params(&self) -> Result<Vec<Vec<f32>>> {
        let path = self.dir.join("init_params.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let json = Json::parse(&text).map_err(|e| anyhow!("{e}"))?;
        let arr = json.as_arr().ok_or_else(|| anyhow!("params not an array"))?;
        let mut out = Vec::with_capacity(arr.len());
        for (i, p) in arr.iter().enumerate() {
            let mut flat = Vec::new();
            flatten_into(p, &mut flat);
            let expect: usize = self.param_shapes[i].iter().product();
            if flat.len() != expect {
                return Err(anyhow!(
                    "param {i}: {} values, expected {expect}",
                    flat.len()
                ));
            }
            out.push(flat);
        }
        Ok(out)
    }
}

fn flatten_into(v: &Json, out: &mut Vec<f32>) {
    match v {
        Json::Num(n) => out.push(*n as f32),
        Json::Arr(items) => {
            for i in items {
                flatten_into(i, out);
            }
        }
        _ => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        crate::runtime::artifacts_available(crate::runtime::DEFAULT_ARTIFACTS_DIR)
    }

    #[test]
    fn loads_real_manifest_when_present() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(crate::runtime::DEFAULT_ARTIFACTS_DIR).unwrap();
        assert_eq!(m.batch, 32);
        assert_eq!(m.cuts, vec![1, 2, 3]);
        assert_eq!(m.param_shapes.len(), 8);
        assert!(m.artifacts.len() >= 11);
        let params = m.load_init_params().unwrap();
        assert_eq!(params.len(), 8);
        assert_eq!(params[0].len(), 3 * 3 * 3 * 16);
    }

    #[test]
    fn rejects_missing_dir() {
        assert!(Manifest::load("/nonexistent-dir").is_err());
    }
}
