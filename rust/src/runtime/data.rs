//! Synthetic dataset for the end-to-end split-training driver.
//!
//! A learnable classification task: labels are the argmax of a fixed random
//! linear projection of the flattened image (same construction the L2
//! python tests use), optionally skewed non-IID per device via a Dirichlet
//! split (Sec. VII-B.3).

use crate::util::rng::Rng;

/// A batch of images + labels, laid out row-major NHWC f32 / i32.
#[derive(Clone, Debug)]
pub struct Batch {
    pub x: Vec<f32>,
    pub labels: Vec<i32>,
    pub batch: usize,
}

/// Synthetic dataset generator.
pub struct Synthetic {
    img: usize,
    channels: usize,
    classes: usize,
    batch: usize,
    projection: Vec<f32>,
    rng: Rng,
}

impl Synthetic {
    pub fn new(img: usize, channels: usize, classes: usize, batch: usize, seed: u64) -> Synthetic {
        let mut rng = Rng::new(seed);
        let dim = img * img * channels;
        let projection: Vec<f32> = (0..dim * classes).map(|_| rng.gauss() as f32).collect();
        Synthetic {
            img,
            channels,
            classes,
            batch,
            projection,
            rng,
        }
    }

    /// Generate the next training batch.
    pub fn next_batch(&mut self) -> Batch {
        let dim = self.img * self.img * self.channels;
        let mut x = Vec::with_capacity(self.batch * dim);
        let mut labels = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            let sample: Vec<f32> = (0..dim).map(|_| self.rng.range(-1.0, 1.0) as f32).collect();
            labels.push(self.label_of(&sample));
            x.extend_from_slice(&sample);
        }
        Batch {
            x,
            labels,
            batch: self.batch,
        }
    }

    /// Ground-truth label: argmax of the fixed projection.
    pub fn label_of(&self, sample: &[f32]) -> i32 {
        let dim = sample.len();
        let mut best = 0usize;
        let mut best_v = f64::NEG_INFINITY;
        for c in 0..self.classes {
            let mut v = 0.0f64;
            for (i, &s) in sample.iter().enumerate() {
                v += s as f64 * self.projection[i * self.classes + c] as f64;
            }
            if v > best_v {
                best_v = v;
                best = c;
            }
            let _ = dim;
        }
        best as i32
    }

    pub fn classes(&self) -> usize {
        self.classes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_have_declared_geometry() {
        let mut d = Synthetic::new(16, 3, 10, 32, 1);
        let b = d.next_batch();
        assert_eq!(b.x.len(), 32 * 16 * 16 * 3);
        assert_eq!(b.labels.len(), 32);
        assert!(b.labels.iter().all(|&l| (0..10).contains(&l)));
    }

    #[test]
    fn labels_are_balanced_enough() {
        let mut d = Synthetic::new(8, 1, 4, 64, 2);
        let mut counts = [0usize; 4];
        for _ in 0..20 {
            for &l in &d.next_batch().labels {
                counts[l as usize] += 1;
            }
        }
        // Each class should appear a reasonable number of times.
        for (c, &n) in counts.iter().enumerate() {
            assert!(n > 100, "class {c} has only {n} samples");
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let mut a = Synthetic::new(8, 1, 4, 16, 3);
        let mut b = Synthetic::new(8, 1, 4, 16, 3);
        assert_eq!(a.next_batch().labels, b.next_batch().labels);
    }
}
