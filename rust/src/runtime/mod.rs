//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//! Python never runs on this path — the rust binary is self-contained once
//! `make artifacts` has run.

pub mod manifest;
pub mod engine;
pub mod split_exec;
pub mod data;

pub use engine::Engine;
pub use manifest::Manifest;
pub use split_exec::SplitTrainer;

/// Default artifacts directory (relative to the repo root).
pub const DEFAULT_ARTIFACTS_DIR: &str = "artifacts";

/// True if the artifacts directory looks complete (manifest present).
pub fn artifacts_available(dir: &str) -> bool {
    std::path::Path::new(dir).join("manifest.json").exists()
}
