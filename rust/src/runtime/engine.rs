//! PJRT engine: CPU client + HLO-text compilation cache.
//!
//! Follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file` (the
//! text parser reassigns instruction ids, which is why text — not the
//! serialized proto — is the interchange format with jax >= 0.5).

use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// A PJRT CPU execution engine with a compiled-executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    cache: BTreeMap<String, xla::PjRtLoadedExecutable>,
}

impl Engine {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            cache: BTreeMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the HLO text at `path` under `key`.
    pub fn load(&mut self, key: &str, path: &Path) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(key) {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            self.cache.insert(key.to_string(), exe);
        }
        Ok(self.cache.get(key).unwrap())
    }

    /// Execute a cached executable on literal inputs; returns the flattened
    /// tuple elements (aot.py lowers with return_tuple=True).
    pub fn run(&mut self, key: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self
            .cache
            .get(key)
            .with_context(|| format!("executable '{key}' not loaded"))?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing '{key}'"))?;
        let literal = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        literal.to_tuple().context("untupling result")
    }

    /// Number of compiled executables held.
    pub fn cached(&self) -> usize {
        self.cache.len()
    }
}

/// Build an f32 literal of the given shape from a flat row-major slice.
pub fn literal_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let numel: usize = shape.iter().product();
    anyhow::ensure!(
        data.len() == numel,
        "literal data {} != shape numel {numel}",
        data.len()
    );
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// Build an i32 literal of the given shape.
pub fn literal_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let numel: usize = shape.iter().product();
    anyhow::ensure!(data.len() == numel, "literal data mismatch");
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// Scalar f32 literal.
pub fn literal_scalar(v: f32) -> xla::Literal {
    xla::Literal::from(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = literal_f32(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]).unwrap();
        let back = l.to_vec::<f32>().unwrap();
        assert_eq!(back, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn literal_shape_mismatch_rejected() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
    }

    // Engine tests requiring the PJRT client live in rust/tests/ (they link
    // against libxla_extension and need the artifacts built).
}
