//! Split executor: drives real split-training steps through the PJRT
//! engine — dev_fwd on the "device", srv_step on the "server", dev_bwd back
//! on the device — with parameters held as XLA literals across steps.
//!
//! Placement is an accounting concept (both sides execute on the local CPU
//! client); the coordinator charges the simulated link/compute delays. The
//! numerics are the real L2 model compiled by aot.py.

use super::data::Batch;
use super::engine::{literal_f32, literal_i32, literal_scalar, Engine};
use super::manifest::Manifest;
use anyhow::{ensure, Context, Result};

/// Outcome of one training step.
#[derive(Clone, Copy, Debug)]
pub struct StepOutcome {
    pub loss: f32,
    /// Bytes that crossed the simulated wire (smashed data + gradient).
    pub wire_bytes: u64,
    /// Cut used (0 = central/full-step on the server, stages = device-only).
    pub cut: usize,
}

/// The split trainer: owns parameters and compiled executables.
pub struct SplitTrainer {
    engine: Engine,
    manifest: Manifest,
    /// Current parameter literals, one per model.PARAM_SHAPES entry.
    params: Vec<xla::Literal>,
}

impl SplitTrainer {
    /// Load artifacts + initial parameters and precompile every cut.
    pub fn new(artifacts_dir: &str) -> Result<SplitTrainer> {
        let manifest = Manifest::load(artifacts_dir)?;
        let mut engine = Engine::cpu()?;
        for (name, info) in &manifest.artifacts {
            engine.load(name, &info.file)?;
        }
        let init = manifest.load_init_params()?;
        let params = init
            .iter()
            .zip(&manifest.param_shapes)
            .map(|(flat, shape)| literal_f32(flat, shape))
            .collect::<Result<Vec<_>>>()?;
        Ok(SplitTrainer {
            engine,
            manifest,
            params,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Valid cut choices: 0 (central) plus the compiled split cuts.
    pub fn available_cuts(&self) -> Vec<usize> {
        let mut cuts = vec![0];
        cuts.extend(self.manifest.cuts.iter().copied());
        cuts
    }

    fn batch_literals(&self, batch: &Batch) -> Result<(xla::Literal, xla::Literal)> {
        let m = &self.manifest;
        ensure!(batch.batch == m.batch, "batch size mismatch");
        let x = literal_f32(&batch.x, &[m.batch, m.img, m.img, m.channels])?;
        let labels = literal_i32(&batch.labels, &[m.batch])?;
        Ok((x, labels))
    }

    /// Run one training step at the given cut (0 = central full step).
    /// `cut == stages` is device-only: the same full step, accounted on the
    /// device by the coordinator.
    pub fn step(&mut self, cut: usize, batch: &Batch, lr: f32) -> Result<StepOutcome> {
        let (x, labels) = self.batch_literals(batch)?;
        if cut == 0 || cut >= self.manifest.stages {
            return self.full_step(x, labels, lr, cut);
        }
        ensure!(
            self.manifest.cuts.contains(&cut),
            "cut {cut} not compiled (available: {:?})",
            self.available_cuts()
        );
        let n_dev = 2 * cut;

        // Device forward -> smashed activation.
        let mut fwd_inputs = vec![x];
        for p in &self.params[..n_dev] {
            fwd_inputs.push(p.clone());
        }
        let x_again = fwd_inputs[0].clone();
        let mut fwd_out = self
            .engine
            .run(&format!("dev_fwd_cut{cut}"), &fwd_inputs)
            .context("dev_fwd")?;
        let smashed = fwd_out.remove(0);
        let smashed_bytes = smashed.size_bytes() as u64;

        // Server step -> loss, gradient of smashed, updated server params.
        let mut srv_inputs = vec![smashed, labels, literal_scalar(lr)];
        for p in &self.params[n_dev..] {
            srv_inputs.push(p.clone());
        }
        let mut srv_out = self
            .engine
            .run(&format!("srv_step_cut{cut}"), &srv_inputs)
            .context("srv_step")?;
        let loss = srv_out.remove(0).to_vec::<f32>()?[0];
        let d_smashed = srv_out.remove(0);
        let grad_bytes = d_smashed.size_bytes() as u64;
        for (i, new_p) in srv_out.into_iter().enumerate() {
            self.params[n_dev + i] = new_p;
        }

        // Device backward -> updated device params.
        let mut bwd_inputs = vec![x_again, d_smashed, literal_scalar(lr)];
        for p in &self.params[..n_dev] {
            bwd_inputs.push(p.clone());
        }
        let bwd_out = self
            .engine
            .run(&format!("dev_bwd_cut{cut}"), &bwd_inputs)
            .context("dev_bwd")?;
        ensure!(bwd_out.len() == n_dev, "dev_bwd arity");
        for (i, new_p) in bwd_out.into_iter().enumerate() {
            self.params[i] = new_p;
        }

        Ok(StepOutcome {
            loss,
            wire_bytes: smashed_bytes + grad_bytes,
            cut,
        })
    }

    fn full_step(
        &mut self,
        x: xla::Literal,
        labels: xla::Literal,
        lr: f32,
        cut: usize,
    ) -> Result<StepOutcome> {
        // cut 0 = the whole model on the server: the raw batch crosses the
        // wire each iteration; cut >= stages = device-only: nothing crosses.
        let wire_bytes = if cut == 0 { x.size_bytes() as u64 } else { 0 };
        let mut inputs = vec![x, labels, literal_scalar(lr)];
        for p in &self.params {
            inputs.push(p.clone());
        }
        let mut out = self.engine.run("full_step", &inputs).context("full_step")?;
        let loss = out.remove(0).to_vec::<f32>()?[0];
        for (i, new_p) in out.into_iter().enumerate() {
            self.params[i] = new_p;
        }
        Ok(StepOutcome {
            loss,
            wire_bytes,
            cut,
        })
    }

    /// Evaluate accuracy on a batch with the current parameters.
    pub fn accuracy(&mut self, batch: &Batch) -> Result<f64> {
        let (x, _) = self.batch_literals(batch)?;
        let mut inputs = vec![x];
        for p in &self.params {
            inputs.push(p.clone());
        }
        let out = self.engine.run("predict", &inputs).context("predict")?;
        let logits = out[0].to_vec::<f32>()?;
        let classes = self.manifest.num_classes;
        let mut correct = 0usize;
        for (i, &label) in batch.labels.iter().enumerate() {
            let row = &logits[i * classes..(i + 1) * classes];
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if pred == label as usize {
                correct += 1;
            }
        }
        Ok(correct as f64 / batch.labels.len() as f64)
    }
}
