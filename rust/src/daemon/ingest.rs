//! Event ingestion and inter-tick coalescing.
//!
//! Concurrent producers feed the daemon raw [`DaemonEvent`]s; between two
//! plan ticks the [`Coalescer`] folds them into the *smallest equivalent
//! batch*: an add+remove of the same device cancels outright, a delta
//! chain per device collapses to at most two deltas, and link reports are
//! last-writer-wins per device. The contract (RESILIENCE.md "Daemon
//! contracts") is **replay equivalence**: applying the coalesced batch to
//! a `PlannerService` leaves it in a state indistinguishable — decisions,
//! caches, feasibility — from applying the raw stream, while
//! `spec_deltas` counts at most (usually far fewer than) the raw events.
//!
//! To make that equivalence exact the coalescer *validates at the door*,
//! against a pending-state mirror of the fleet spec: an event that the
//! raw stream would reject (typed [`SpecError`]) or that can only produce
//! divergent state (a report for a departed slot, which the service would
//! hold for a future incarnation) is refused with an [`IngestError`] and
//! counted by the daemon, never enqueued. Everything the coalescer
//! accepts therefore replays cleanly.
//!
//! Emission order is canonical and deterministic: device deltas in slot
//! order, reports after deltas in slot order; tier events are barriers
//! (they flush pending device lanes first) because detaching a tier
//! reorders around device deltas in ways coalescing must not hide.

use std::collections::BTreeMap;

use crate::partition::fleet::{FleetSpec, SpecDelta, SpecError};
use crate::partition::types::Link;

/// One raw event a producer hands the daemon.
#[derive(Clone, Debug)]
pub enum DaemonEvent {
    /// A churn event against the fleet spec.
    Delta(SpecDelta),
    /// A device's link report at caller tick `tick`.
    Report {
        device: usize,
        link: Link,
        tick: u64,
    },
}

/// One entry of a flushed coalesced batch, in canonical order.
#[derive(Clone, Debug)]
pub enum CoalescedItem {
    /// A (possibly fused) churn event to apply.
    Delta(SpecDelta),
    /// The newest surviving report for a device.
    Report {
        device: usize,
        link: Link,
        tick: u64,
    },
}

/// Why the coalescer refused an event at the door.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IngestError {
    /// The delta is malformed against the pending fleet state.
    Spec(SpecError),
    /// A report named a slot that is departed (or out of range) in the
    /// pending state — holding it for a future incarnation would diverge
    /// from raw replay, so it is refused instead.
    ReportForInactiveDevice { device: usize },
    /// A report carried a non-positive rate (the service would panic).
    NonPositiveRate { device: usize },
}

impl std::fmt::Display for IngestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            IngestError::Spec(e) => write!(f, "{e}"),
            IngestError::ReportForInactiveDevice { device } => {
                write!(f, "report for inactive device slot {device}")
            }
            IngestError::NonPositiveRate { device } => {
                write!(f, "non-positive link rate reported for device {device}")
            }
        }
    }
}

impl std::error::Error for IngestError {}

impl From<SpecError> for IngestError {
    fn from(e: SpecError) -> IngestError {
        IngestError::Spec(e)
    }
}

/// Per-device pending state between barriers.
struct DeviceLane {
    /// The device's tier when the lane opened (pending state *before*
    /// this batch touched it).
    initial: Option<usize>,
    /// A `RemoveDevice` happened in this batch.
    removed: bool,
    /// A `MigrateDevice` happened in this batch (without a removal).
    migrated: bool,
    /// Newest surviving report: last-writer-wins by tick, cleared by a
    /// removal (the raw service clears its inbox on departure too).
    report: Option<(Link, u64)>,
}

/// The inter-tick event folder. See the module docs for the contract.
pub struct Coalescer {
    /// Pending-state mirror: each slot's tier after every accepted event.
    membership: Vec<Option<usize>>,
    /// Pending retired flag per tier slot.
    retired: Vec<bool>,
    /// Open device lanes, keyed by slot (BTreeMap = canonical order).
    lanes: BTreeMap<usize, DeviceLane>,
    /// Flushed-but-unconsumed items (tier barriers emit into here).
    items: Vec<CoalescedItem>,
}

impl Coalescer {
    /// A coalescer whose pending-state mirror starts at `spec`.
    pub fn new(spec: &FleetSpec) -> Coalescer {
        Coalescer {
            membership: (0..spec.num_devices()).map(|d| spec.tier_of_opt(d)).collect(),
            retired: (0..spec.num_tiers()).map(|t| spec.tier_retired(t)).collect(),
            lanes: BTreeMap::new(),
            items: Vec::new(),
        }
    }

    fn tier_ok(&self, tier: usize) -> Result<(), SpecError> {
        if tier >= self.retired.len() {
            Err(SpecError::UnknownTier { tier })
        } else if self.retired[tier] {
            Err(SpecError::RetiredTier { tier })
        } else {
            Ok(())
        }
    }

    fn slot(&self, device: usize) -> Option<usize> {
        self.membership.get(device).copied().flatten()
    }

    fn lane(&mut self, device: usize) -> &mut DeviceLane {
        let initial = self.slot(device);
        self.lanes.entry(device).or_insert(DeviceLane {
            initial,
            removed: false,
            migrated: false,
            report: None,
        })
    }

    /// Accept one raw event into the pending batch, or refuse it with a
    /// typed error (mirroring exactly what raw replay would reject).
    pub fn push(&mut self, event: DaemonEvent) -> Result<(), IngestError> {
        match event {
            DaemonEvent::Delta(delta) => self.push_delta(delta).map_err(IngestError::from),
            DaemonEvent::Report { device, link, tick } => {
                if !link.is_valid() {
                    return Err(IngestError::NonPositiveRate { device });
                }
                if self.slot(device).is_none() {
                    return Err(IngestError::ReportForInactiveDevice { device });
                }
                let lane = self.lane(device);
                match lane.report {
                    Some((_, have)) if tick < have => {} // older: dropped
                    _ => lane.report = Some((link, tick)),
                }
                Ok(())
            }
        }
    }

    fn push_delta(&mut self, delta: SpecDelta) -> Result<(), SpecError> {
        match delta {
            SpecDelta::AddTier { .. } => {
                // Tier events are barriers: device-lane coalescing must
                // not move a delta across a tier-set change.
                self.barrier();
                self.retired.push(false);
                self.items.push(CoalescedItem::Delta(delta));
            }
            SpecDelta::RetireTier { tier } => {
                if tier >= self.retired.len() {
                    return Err(SpecError::UnknownTier { tier });
                }
                if self.retired[tier] {
                    return Err(SpecError::AlreadyRetired { tier });
                }
                self.barrier();
                self.retired[tier] = true;
                for slot in &mut self.membership {
                    if *slot == Some(tier) {
                        *slot = None;
                    }
                }
                self.items.push(CoalescedItem::Delta(delta));
            }
            SpecDelta::AddDevice { device, tier } => {
                self.tier_ok(tier)?;
                if self.slot(device).is_some() {
                    return Err(SpecError::DeviceAlreadyPresent { device });
                }
                self.lane(device);
                if device >= self.membership.len() {
                    self.membership.resize(device + 1, None);
                }
                self.membership[device] = Some(tier);
            }
            SpecDelta::RemoveDevice { device } => {
                if self.slot(device).is_none() {
                    return Err(SpecError::UnknownDevice { device });
                }
                let lane = self.lane(device);
                lane.removed = true;
                lane.report = None;
                self.membership[device] = None;
            }
            SpecDelta::MigrateDevice { device, tier } => {
                self.tier_ok(tier)?;
                if self.slot(device).is_none() {
                    return Err(SpecError::UnknownDevice { device });
                }
                self.lane(device).migrated = true;
                self.membership[device] = Some(tier);
            }
        }
        Ok(())
    }

    /// Fold every open device lane into canonical items: deltas in slot
    /// order (at most two per device), then surviving reports in slot
    /// order.
    fn barrier(&mut self) {
        let lanes = std::mem::take(&mut self.lanes);
        let mut reports: Vec<(usize, Link, u64)> = Vec::new();
        for (device, lane) in lanes {
            let current = self.slot(device);
            match (lane.initial, current) {
                // Add + remove within one batch: cancels outright.
                (None, None) => {}
                (None, Some(tier)) => {
                    self.items
                        .push(CoalescedItem::Delta(SpecDelta::AddDevice { device, tier }));
                }
                (Some(_), None) => {
                    debug_assert!(lane.removed, "only a removal departs a lane");
                    self.items
                        .push(CoalescedItem::Delta(SpecDelta::RemoveDevice { device }));
                }
                (Some(t0), Some(tier)) => {
                    if lane.removed {
                        // Remove then re-add: must NOT fuse to a migrate —
                        // a re-join drops the old incarnation's caches, a
                        // migrate keeps the report. Emit both.
                        self.items
                            .push(CoalescedItem::Delta(SpecDelta::RemoveDevice { device }));
                        self.items
                            .push(CoalescedItem::Delta(SpecDelta::AddDevice { device, tier }));
                    } else if lane.migrated {
                        // Emitted even when tier == t0: a migrate clears
                        // the device's last-good cache, and a round-trip
                        // A→B→A must still clear it under raw replay.
                        self.items
                            .push(CoalescedItem::Delta(SpecDelta::MigrateDevice {
                                device,
                                tier,
                            }));
                    } else {
                        debug_assert_eq!(t0, tier, "an untouched lane cannot move tiers");
                    }
                }
            }
            if let Some((link, tick)) = lane.report {
                debug_assert!(current.is_some(), "reports for departed slots are refused");
                reports.push((device, link, tick));
            }
        }
        for (device, link, tick) in reports {
            self.items
                .push(CoalescedItem::Report { device, link, tick });
        }
    }

    /// Close the batch: fold the open lanes and hand back every pending
    /// item in canonical order. The mirror keeps its state — the next
    /// batch continues from here.
    pub fn flush(&mut self) -> Vec<CoalescedItem> {
        self.barrier();
        std::mem::take(&mut self.items)
    }

    /// Raw events currently folded into the pending batch (open lanes
    /// plus already-barriered items) — `0` means flush would be empty.
    pub fn is_pending(&self) -> bool {
        !self.lanes.is_empty() || !self.items.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::profiles::{CostGraph, DeviceProfile, TrainCfg};
    use crate::util::rng::Rng;

    fn spec_for(model: &str, devices: usize) -> FleetSpec {
        let m = models::by_name(model).unwrap();
        FleetSpec::from_fleet(&DeviceProfile::fleet_of(devices), |d| {
            CostGraph::build(&m, d, &DeviceProfile::rtx_a6000(), &TrainCfg::default())
        })
    }

    fn deltas(items: &[CoalescedItem]) -> Vec<String> {
        items
            .iter()
            .filter_map(|i| match i {
                CoalescedItem::Delta(d) => Some(format!("{d:?}")),
                CoalescedItem::Report { .. } => None,
            })
            .collect()
    }

    #[test]
    fn add_then_remove_cancels_outright() {
        let spec = spec_for("block-residual", 4);
        let mut c = Coalescer::new(&spec);
        c.push(DaemonEvent::Delta(SpecDelta::AddDevice { device: 9, tier: 0 }))
            .unwrap();
        c.push(DaemonEvent::Delta(SpecDelta::RemoveDevice { device: 9 }))
            .unwrap();
        assert!(c.flush().is_empty(), "add+remove is a no-op batch");
        // And the inverse does NOT cancel: remove + re-add emits both
        // (a re-join must not inherit the old incarnation's caches).
        c.push(DaemonEvent::Delta(SpecDelta::RemoveDevice { device: 1 }))
            .unwrap();
        c.push(DaemonEvent::Delta(SpecDelta::AddDevice { device: 1, tier: 2 }))
            .unwrap();
        let out = deltas(&c.flush());
        assert_eq!(out.len(), 2);
        assert!(out[0].contains("RemoveDevice"));
        assert!(out[1].contains("AddDevice"));
    }

    #[test]
    fn migrate_chains_collapse_but_round_trips_still_emit() {
        let spec = spec_for("block-residual", 4);
        let mut c = Coalescer::new(&spec);
        // Device 0 lives on tier 0: a chain 0→1→2→3 collapses to one
        // migrate to the final tier.
        for tier in [1usize, 2, 3] {
            c.push(DaemonEvent::Delta(SpecDelta::MigrateDevice { device: 0, tier }))
                .unwrap();
        }
        let out = deltas(&c.flush());
        assert_eq!(out, vec!["MigrateDevice { device: 0, tier: 3 }"]);
        // A round trip 3→1→3 still emits one migrate (the raw stream
        // cleared the device's last-good cache; the batch must too).
        c.push(DaemonEvent::Delta(SpecDelta::MigrateDevice { device: 0, tier: 1 }))
            .unwrap();
        c.push(DaemonEvent::Delta(SpecDelta::MigrateDevice { device: 0, tier: 3 }))
            .unwrap();
        let out = deltas(&c.flush());
        assert_eq!(out, vec!["MigrateDevice { device: 0, tier: 3 }"]);
    }

    #[test]
    fn reports_are_last_writer_wins_and_ordered_after_deltas() {
        let spec = spec_for("block-residual", 4);
        let mut c = Coalescer::new(&spec);
        c.push(DaemonEvent::Report {
            device: 2,
            link: Link::symmetric(1e5),
            tick: 4,
        })
        .unwrap();
        c.push(DaemonEvent::Delta(SpecDelta::MigrateDevice { device: 2, tier: 0 }))
            .unwrap();
        c.push(DaemonEvent::Report {
            device: 2,
            link: Link::symmetric(3e5),
            tick: 6,
        })
        .unwrap();
        // An out-of-order older report is dropped, like the service inbox.
        c.push(DaemonEvent::Report {
            device: 2,
            link: Link::symmetric(9e5),
            tick: 5,
        })
        .unwrap();
        let items = c.flush();
        assert_eq!(items.len(), 2);
        assert!(matches!(
            items[0],
            CoalescedItem::Delta(SpecDelta::MigrateDevice { device: 2, tier: 0 })
        ));
        match items[1] {
            CoalescedItem::Report { device, link, tick } => {
                assert_eq!(device, 2);
                assert_eq!(tick, 6);
                assert_eq!(link.up_bps, 3e5);
            }
            _ => panic!("report must follow the deltas"),
        }
    }

    #[test]
    fn removal_clears_the_pending_report() {
        let spec = spec_for("block-residual", 4);
        let mut c = Coalescer::new(&spec);
        c.push(DaemonEvent::Report {
            device: 1,
            link: Link::symmetric(2e5),
            tick: 1,
        })
        .unwrap();
        c.push(DaemonEvent::Delta(SpecDelta::RemoveDevice { device: 1 }))
            .unwrap();
        let items = c.flush();
        assert_eq!(items.len(), 1, "only the removal survives");
        assert!(matches!(
            items[0],
            CoalescedItem::Delta(SpecDelta::RemoveDevice { device: 1 })
        ));
    }

    #[test]
    fn door_validation_mirrors_raw_replay() {
        let spec = spec_for("block-residual", 4);
        let mut c = Coalescer::new(&spec);
        // Raw-invalid deltas are refused with the same typed errors.
        assert_eq!(
            c.push(DaemonEvent::Delta(SpecDelta::MigrateDevice { device: 9, tier: 0 })),
            Err(IngestError::Spec(SpecError::UnknownDevice { device: 9 }))
        );
        assert_eq!(
            c.push(DaemonEvent::Delta(SpecDelta::AddDevice { device: 1, tier: 0 })),
            Err(IngestError::Spec(SpecError::DeviceAlreadyPresent { device: 1 }))
        );
        // Validation is against the *pending* state: remove 1, then the
        // same add is acceptable; a second remove is not.
        c.push(DaemonEvent::Delta(SpecDelta::RemoveDevice { device: 1 }))
            .unwrap();
        assert_eq!(
            c.push(DaemonEvent::Delta(SpecDelta::RemoveDevice { device: 1 })),
            Err(IngestError::Spec(SpecError::UnknownDevice { device: 1 }))
        );
        assert_eq!(
            c.push(DaemonEvent::Report {
                device: 1,
                link: Link::symmetric(1e5),
                tick: 0,
            }),
            Err(IngestError::ReportForInactiveDevice { device: 1 })
        );
        c.push(DaemonEvent::Delta(SpecDelta::AddDevice { device: 1, tier: 0 }))
            .unwrap();
        // Bad rates are refused at the door, not panicked on later.
        assert_eq!(
            c.push(DaemonEvent::Report {
                device: 1,
                link: Link {
                    up_bps: 0.0,
                    down_bps: 1e5,
                },
                tick: 0,
            }),
            Err(IngestError::NonPositiveRate { device: 1 })
        );
        // Non-finite rates too: NaN and infinity must not reach the
        // planner's SoA refresh through the daemon door (PR 8).
        assert_eq!(
            c.push(DaemonEvent::Report {
                device: 1,
                link: Link {
                    up_bps: f64::NAN,
                    down_bps: 1e5,
                },
                tick: 0,
            }),
            Err(IngestError::NonPositiveRate { device: 1 })
        );
        assert_eq!(
            c.push(DaemonEvent::Report {
                device: 1,
                link: Link {
                    up_bps: 1e5,
                    down_bps: f64::INFINITY,
                },
                tick: 0,
            }),
            Err(IngestError::NonPositiveRate { device: 1 })
        );
    }

    #[test]
    fn tier_events_are_barriers() {
        let spec = spec_for("block-residual", 6);
        let mut c = Coalescer::new(&spec);
        // Device 0 migrates, then its tier retires: the migrate must be
        // emitted before the retire (the retire detaches the device).
        c.push(DaemonEvent::Delta(SpecDelta::MigrateDevice { device: 0, tier: 3 }))
            .unwrap();
        c.push(DaemonEvent::Delta(SpecDelta::RetireTier { tier: 3 }))
            .unwrap();
        let out = deltas(&c.flush());
        assert_eq!(
            out,
            vec![
                "MigrateDevice { device: 0, tier: 3 }".to_string(),
                "RetireTier { tier: 3 }".to_string(),
            ]
        );
        // And the mirror noticed the detachment: device 0 is gone, tier
        // 3 rejects newcomers.
        assert_eq!(
            c.push(DaemonEvent::Delta(SpecDelta::MigrateDevice { device: 0, tier: 0 })),
            Err(IngestError::Spec(SpecError::UnknownDevice { device: 0 }))
        );
        assert_eq!(
            c.push(DaemonEvent::Delta(SpecDelta::AddDevice { device: 0, tier: 3 })),
            Err(IngestError::Spec(SpecError::RetiredTier { tier: 3 }))
        );
    }

    /// Seeded batch equivalence on the spec level: a random valid event
    /// stream applied raw and applied coalesced end at the same
    /// membership, with the coalesced delta count never exceeding (and
    /// for this workload strictly under) the raw count.
    #[test]
    fn seeded_coalesced_batches_replay_to_the_raw_spec() {
        let mut rng = Rng::new(crate::util::rng::test_seed() ^ 0xC0A1);
        let spec = spec_for("block-residual", 6);
        let mut raw = spec.clone();
        let mut c = Coalescer::new(&spec);
        let mut coalesced = spec.clone();
        let mut raw_deltas = 0u64;
        let mut batched_deltas = 0u64;
        for _ in 0..40 {
            // One inter-tick window of random-but-valid device churn.
            for _ in 0..rng.below(8) {
                let device = rng.below(8) as usize;
                let delta = match raw.tier_of_opt(device) {
                    None => SpecDelta::AddDevice {
                        device,
                        tier: rng.below(raw.num_tiers() as u64) as usize,
                    },
                    Some(_) if rng.chance(0.5) => SpecDelta::RemoveDevice { device },
                    Some(_) => SpecDelta::MigrateDevice {
                        device,
                        tier: rng.below(raw.num_tiers() as u64) as usize,
                    },
                };
                if raw.validate(&delta).is_err() {
                    continue; // e.g. a retired target tier
                }
                raw.apply(&delta);
                raw_deltas += 1;
                c.push(DaemonEvent::Delta(delta)).unwrap();
            }
            for item in c.flush() {
                if let CoalescedItem::Delta(d) = item {
                    coalesced.apply(&d);
                    batched_deltas += 1;
                }
            }
            let same: Vec<Option<usize>> = (0..raw.num_devices())
                .map(|d| raw.tier_of_opt(d))
                .collect();
            let got: Vec<Option<usize>> = (0..coalesced.num_devices())
                .map(|d| coalesced.tier_of_opt(d))
                .collect();
            assert_eq!(got, same, "coalesced replay diverged from raw");
        }
        assert!(batched_deltas <= raw_deltas);
        assert!(
            batched_deltas < raw_deltas,
            "this workload must make coalescing fire ({batched_deltas} vs {raw_deltas})"
        );
    }
}
