//! Pull-based metrics: snapshot the planning stack's counters into
//! Prometheus text format (the kumomta `kumo-prometheus` shape, without
//! the HTTP server — rendering is the daemon's job, transport is the
//! embedder's).
//!
//! The scrape surface is a pure function of [`FleetStats`] and the
//! service counters: no background aggregation, no atomics, no drift
//! between what the planner counted and what the scrape says. The
//! rendered text is **byte-stable** for a fixed state — metric order is
//! struct-field order, names and HELP/TYPE lines are pinned by a golden
//! test below so the format cannot drift silently under a scraper.

use crate::partition::fleet::FleetStats;
use crate::partition::service::PlannerService;
use crate::partition::sharded::ShardedFleetPlanner;

/// Prometheus metric families this module emits.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricKind {
    /// Monotone counter (`_total` names).
    Counter,
    /// Point-in-time value.
    Gauge,
}

/// One rendered metric: a name, its HELP line, kind and current value.
#[derive(Clone, Copy, Debug)]
pub struct Metric {
    /// Prometheus metric name (`fastsplit_*`).
    pub name: &'static str,
    /// The `# HELP` line body.
    pub help: &'static str,
    /// Counter or gauge.
    pub kind: MetricKind,
    /// Current value (all the stack's counters are integral).
    pub value: u64,
}

/// Render metrics in Prometheus text exposition format: per metric a
/// `# HELP`, a `# TYPE` and one sample line. Deterministic: the output
/// is a pure function of the input slice.
pub fn render_prometheus(metrics: &[Metric]) -> String {
    let mut out = String::new();
    for m in metrics {
        let kind = match m.kind {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
        };
        out.push_str(&format!(
            "# HELP {name} {help}\n# TYPE {name} {kind}\n{name} {value}\n",
            name = m.name,
            help = m.help,
            kind = kind,
            value = m.value,
        ));
    }
    out
}

/// Snapshot a [`FleetStats`] into its metric family, in struct-field
/// order (the golden test pins names and order).
pub fn fleet_metrics(stats: &FleetStats) -> Vec<Metric> {
    let counter = |name, help, value| Metric {
        name,
        help,
        kind: MetricKind::Counter,
        value,
    };
    let gauge = |name, help, value| Metric {
        name,
        help,
        kind: MetricKind::Gauge,
        value,
    };
    vec![
        counter(
            "fastsplit_plans_total",
            "Batched plan calls served",
            stats.plans,
        ),
        counter(
            "fastsplit_requests_total",
            "Plan requests across all plan calls",
            stats.requests,
        ),
        counter(
            "fastsplit_refreshes_total",
            "O(E) capacity-refresh passes",
            stats.refreshes,
        ),
        counter("fastsplit_flow_solves_total", "Dinic runs", stats.flow_solves),
        counter(
            "fastsplit_linear_scans_total",
            "Linear-scan solves on chain solve DAGs",
            stats.linear_scans,
        ),
        counter(
            "fastsplit_incremental_solves_total",
            "Flow solves that reused the previous flow",
            stats.incremental_solves,
        ),
        counter(
            "fastsplit_repair_pushes_total",
            "Arc cancellations by incremental repair",
            stats.repair_pushes,
        ),
        counter(
            "fastsplit_augment_rounds_total",
            "BFS phases of incremental augmentation",
            stats.augment_rounds,
        ),
        gauge(
            "fastsplit_full_dag_vertices",
            "Vertices of the full model DAG",
            stats.full_vertices as u64,
        ),
        gauge(
            "fastsplit_full_dag_edges",
            "Edges of the full model DAG",
            stats.full_edges as u64,
        ),
        gauge(
            "fastsplit_solve_dag_vertices",
            "Vertices of the DAG the engine solves on",
            stats.reduced_vertices as u64,
        ),
        gauge(
            "fastsplit_solve_dag_edges",
            "Edges of the DAG the engine solves on",
            stats.reduced_edges as u64,
        ),
        gauge(
            "fastsplit_blocks_detected",
            "Blocks found by Alg. 3 detection",
            stats.blocks_detected as u64,
        ),
        gauge(
            "fastsplit_blocks_abstracted",
            "Blocks abstracted under Theorem 2",
            stats.blocks_abstracted as u64,
        ),
        counter(
            "fastsplit_price_iterations_total",
            "Joint-planner congestion price probes",
            stats.price_iterations,
        ),
        counter(
            "fastsplit_joint_resolves_total",
            "Priced per-tier re-solves of the joint loop",
            stats.joint_resolves,
        ),
        counter(
            "fastsplit_fallback_cold_solves_total",
            "Incremental repairs that fell back cold",
            stats.fallback_cold_solves,
        ),
        counter(
            "fastsplit_spec_deltas_total",
            "Churn events applied to the fleet spec",
            stats.spec_deltas,
        ),
        counter(
            "fastsplit_retired_decisions_total",
            "Decisions served from a retired tier archive",
            stats.retired_decisions,
        ),
        counter(
            "fastsplit_degraded_decisions_total",
            "Decisions served with degraded provenance",
            stats.degraded_decisions,
        ),
        counter(
            "fastsplit_quantized_requests_total",
            "Plan requests snapped to a sigma-bucket representative",
            stats.quantized_requests,
        ),
    ]
}

/// Snapshot a [`ShardedFleetPlanner`]: its composed [`fleet_metrics`]
/// plus the shard-layout gauge (shard counts are deployment shape, not a
/// [`FleetStats`] counter — the flat-equality pins stay exact).
pub fn sharded_metrics(planner: &ShardedFleetPlanner) -> Vec<Metric> {
    let mut out = fleet_metrics(&planner.stats());
    out.push(Metric {
        name: "fastsplit_shards",
        help: "Worker shards the tier set is partitioned across",
        kind: MetricKind::Gauge,
        value: planner.num_shards() as u64,
    });
    out
}

/// Snapshot a whole [`PlannerService`]: the wrapped planner's
/// [`fleet_metrics`] plus the service layer's own counters and fleet
/// shape gauges.
pub fn service_metrics(service: &PlannerService) -> Vec<Metric> {
    let mut out = fleet_metrics(&service.stats());
    let spec = service.spec();
    out.push(Metric {
        name: "fastsplit_degraded_stale_total",
        help: "Decisions degraded for stale or expired reports",
        kind: MetricKind::Counter,
        value: service.degraded_stale(),
    });
    out.push(Metric {
        name: "fastsplit_degraded_budget_total",
        help: "Decisions degraded for solve-budget exhaustion",
        kind: MetricKind::Counter,
        value: service.degraded_budget(),
    });
    out.push(Metric {
        name: "fastsplit_service_clock",
        help: "Newest epoch tick the service planned at",
        kind: MetricKind::Gauge,
        value: service.now(),
    });
    out.push(Metric {
        name: "fastsplit_device_slots",
        help: "Device slots the fleet spec tracks",
        kind: MetricKind::Gauge,
        value: spec.num_devices() as u64,
    });
    out.push(Metric {
        name: "fastsplit_active_devices",
        help: "Device slots currently mapped to a live tier",
        kind: MetricKind::Gauge,
        value: spec.active_devices() as u64,
    });
    out.push(Metric {
        name: "fastsplit_tiers",
        help: "Tier slots (live and retired) in the fleet spec",
        kind: MetricKind::Gauge,
        value: spec.num_tiers() as u64,
    });
    out.push(Metric {
        name: "fastsplit_report_refusals_total",
        help: "Link reports refused by input validation",
        kind: MetricKind::Counter,
        value: service.refused_reports(),
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::partition::fleet::{FleetSpec, SpecDelta};
    use crate::partition::service::ServiceOptions;
    use crate::partition::types::Link;
    use crate::profiles::{CostGraph, DeviceProfile, TrainCfg};

    /// The golden snapshot: names, HELP/TYPE lines, order and value
    /// formatting are pinned byte-for-byte, so the scrape format cannot
    /// drift without this diff lighting up.
    #[test]
    fn prometheus_rendering_matches_the_golden_snapshot() {
        let stats = FleetStats {
            plans: 1,
            requests: 2,
            refreshes: 3,
            flow_solves: 4,
            linear_scans: 5,
            incremental_solves: 6,
            repair_pushes: 7,
            augment_rounds: 8,
            full_vertices: 9,
            full_edges: 10,
            reduced_vertices: 11,
            reduced_edges: 12,
            blocks_detected: 13,
            blocks_abstracted: 14,
            price_iterations: 15,
            joint_resolves: 16,
            fallback_cold_solves: 17,
            spec_deltas: 18,
            retired_decisions: 19,
            degraded_decisions: 20,
            quantized_requests: 21,
            // PR 10 topology counters: deliberately absent from the
            // scrape (the golden string below is unchanged), so the
            // literal pins that growing `FleetStats` did not disturb the
            // byte-stable format.
            dp_transitions: 22,
            assignment_moves: 23,
            inner_makespan_solves: 24,
        };
        let golden = concat!(
            "# HELP fastsplit_plans_total Batched plan calls served\n",
            "# TYPE fastsplit_plans_total counter\n",
            "fastsplit_plans_total 1\n",
            "# HELP fastsplit_requests_total Plan requests across all plan calls\n",
            "# TYPE fastsplit_requests_total counter\n",
            "fastsplit_requests_total 2\n",
            "# HELP fastsplit_refreshes_total O(E) capacity-refresh passes\n",
            "# TYPE fastsplit_refreshes_total counter\n",
            "fastsplit_refreshes_total 3\n",
            "# HELP fastsplit_flow_solves_total Dinic runs\n",
            "# TYPE fastsplit_flow_solves_total counter\n",
            "fastsplit_flow_solves_total 4\n",
            "# HELP fastsplit_linear_scans_total Linear-scan solves on chain solve DAGs\n",
            "# TYPE fastsplit_linear_scans_total counter\n",
            "fastsplit_linear_scans_total 5\n",
            "# HELP fastsplit_incremental_solves_total Flow solves that reused the previous flow\n",
            "# TYPE fastsplit_incremental_solves_total counter\n",
            "fastsplit_incremental_solves_total 6\n",
            "# HELP fastsplit_repair_pushes_total Arc cancellations by incremental repair\n",
            "# TYPE fastsplit_repair_pushes_total counter\n",
            "fastsplit_repair_pushes_total 7\n",
            "# HELP fastsplit_augment_rounds_total BFS phases of incremental augmentation\n",
            "# TYPE fastsplit_augment_rounds_total counter\n",
            "fastsplit_augment_rounds_total 8\n",
            "# HELP fastsplit_full_dag_vertices Vertices of the full model DAG\n",
            "# TYPE fastsplit_full_dag_vertices gauge\n",
            "fastsplit_full_dag_vertices 9\n",
            "# HELP fastsplit_full_dag_edges Edges of the full model DAG\n",
            "# TYPE fastsplit_full_dag_edges gauge\n",
            "fastsplit_full_dag_edges 10\n",
            "# HELP fastsplit_solve_dag_vertices Vertices of the DAG the engine solves on\n",
            "# TYPE fastsplit_solve_dag_vertices gauge\n",
            "fastsplit_solve_dag_vertices 11\n",
            "# HELP fastsplit_solve_dag_edges Edges of the DAG the engine solves on\n",
            "# TYPE fastsplit_solve_dag_edges gauge\n",
            "fastsplit_solve_dag_edges 12\n",
            "# HELP fastsplit_blocks_detected Blocks found by Alg. 3 detection\n",
            "# TYPE fastsplit_blocks_detected gauge\n",
            "fastsplit_blocks_detected 13\n",
            "# HELP fastsplit_blocks_abstracted Blocks abstracted under Theorem 2\n",
            "# TYPE fastsplit_blocks_abstracted gauge\n",
            "fastsplit_blocks_abstracted 14\n",
            "# HELP fastsplit_price_iterations_total Joint-planner congestion price probes\n",
            "# TYPE fastsplit_price_iterations_total counter\n",
            "fastsplit_price_iterations_total 15\n",
            "# HELP fastsplit_joint_resolves_total Priced per-tier re-solves of the joint loop\n",
            "# TYPE fastsplit_joint_resolves_total counter\n",
            "fastsplit_joint_resolves_total 16\n",
            "# HELP fastsplit_fallback_cold_solves_total Incremental repairs that fell back cold\n",
            "# TYPE fastsplit_fallback_cold_solves_total counter\n",
            "fastsplit_fallback_cold_solves_total 17\n",
            "# HELP fastsplit_spec_deltas_total Churn events applied to the fleet spec\n",
            "# TYPE fastsplit_spec_deltas_total counter\n",
            "fastsplit_spec_deltas_total 18\n",
            "# HELP fastsplit_retired_decisions_total Decisions served from a retired tier archive\n",
            "# TYPE fastsplit_retired_decisions_total counter\n",
            "fastsplit_retired_decisions_total 19\n",
            "# HELP fastsplit_degraded_decisions_total Decisions served with degraded provenance\n",
            "# TYPE fastsplit_degraded_decisions_total counter\n",
            "fastsplit_degraded_decisions_total 20\n",
            "# HELP fastsplit_quantized_requests_total Plan requests snapped to a sigma-bucket representative\n",
            "# TYPE fastsplit_quantized_requests_total counter\n",
            "fastsplit_quantized_requests_total 21\n",
        );
        assert_eq!(render_prometheus(&fleet_metrics(&stats)), golden);
    }

    fn spec_for(model: &str, devices: usize) -> FleetSpec {
        let m = models::by_name(model).unwrap();
        FleetSpec::from_fleet(&DeviceProfile::fleet_of(devices), |d| {
            CostGraph::build(&m, d, &DeviceProfile::rtx_a6000(), &TrainCfg::default())
        })
    }

    /// Byte-stability over a real seeded run: two services driven through
    /// the identical report/churn/epoch sequence render identical scrape
    /// text, and the service tail carries the right values.
    #[test]
    fn service_scrape_is_byte_stable_for_a_fixed_run() {
        let run = || {
            let mut service =
                PlannerService::new(spec_for("googlenet", 4), ServiceOptions::default());
            for d in 0..4 {
                service.report(d, Link::symmetric(5e5), 0);
            }
            service.plan_epoch(0).unwrap();
            service.apply_delta(&SpecDelta::RemoveDevice { device: 3 });
            service.expire_report(1);
            service.plan_epoch(2).unwrap();
            render_prometheus(&service_metrics(&service))
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "same run, same scrape bytes");
        assert!(a.contains("fastsplit_service_clock 2\n"));
        assert!(a.contains("fastsplit_device_slots 4\n"));
        assert!(a.contains("fastsplit_active_devices 3\n"));
        assert!(a.contains("fastsplit_spec_deltas_total 1\n"));
        assert!(a.contains("fastsplit_degraded_stale_total 1\n"));
        assert!(a.contains("fastsplit_report_refusals_total 0\n"));
        assert!(a.ends_with('\n'));
    }

    /// The service scrape counts refused reports (the typed-refusal path
    /// of PR 8): a NaN-rate report bumps the tail counter, nothing else.
    #[test]
    fn service_scrape_counts_report_refusals() {
        let mut service = PlannerService::new(spec_for("googlenet", 4), ServiceOptions::default());
        for d in 0..4 {
            service.report(d, Link::symmetric(5e5), 0);
        }
        let bad = Link {
            up_bps: f64::NAN,
            down_bps: 5e5,
        };
        assert!(service.try_report(1, bad, 1).is_err());
        assert!(service.try_report(99, Link::symmetric(5e5), 1).is_err());
        service.plan_epoch(1).unwrap();
        let text = render_prometheus(&service_metrics(&service));
        assert!(text.contains("fastsplit_report_refusals_total 2\n"));
    }

    /// The sharded scrape is the composed fleet family plus the shard
    /// gauge, and with quantization on the new counter moves.
    #[test]
    fn sharded_scrape_reports_shards_and_quantized_requests() {
        use crate::partition::fleet::{FleetOptions, PlanRequest};
        use crate::partition::joint::JointOptions;
        let options = JointOptions {
            fleet: FleetOptions {
                sigma_buckets_per_decade: 2,
                ..FleetOptions::default()
            },
            ..JointOptions::default()
        };
        let mut planner = ShardedFleetPlanner::new(spec_for("googlenet", 8), 3, options);
        let reqs: Vec<PlanRequest> = (0..8)
            .map(|d| PlanRequest {
                device: d,
                tier: planner.spec().tier_of(d),
                // Two nearby rates per device pair: same sigma-bucket, so
                // the quantizer rewrites the non-canonical member.
                link: Link::symmetric(5e5 * (1.0 + 0.01 * (d / 4) as f64)),
            })
            .collect();
        planner.plan(&reqs);
        let text = render_prometheus(&sharded_metrics(&planner));
        assert!(text.contains("fastsplit_shards 3\n"));
        assert!(text.contains("fastsplit_plans_total 1\n"));
        assert!(text.contains("fastsplit_requests_total 8\n"));
        let quantized = planner.stats().quantized_requests;
        assert!(quantized > 0, "the nearby rates must collapse");
        assert!(text.contains(&format!("fastsplit_quantized_requests_total {quantized}\n")));
    }
}
