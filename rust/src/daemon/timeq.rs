//! A hashed timer wheel on the simulated clock (the kumomta
//! `crates/timeq` shape, sized down to the daemon's needs).
//!
//! Entries hash into `deadline % num_slots` buckets; advancing the clock
//! visits only the slots the elapsed ticks touch, so a mostly-idle wheel
//! costs O(ticks elapsed + entries due) per advance, not O(entries). A
//! jump of a full revolution or more falls back to one scan of every
//! slot. Due entries are returned sorted by `(deadline, insertion seq)`,
//! which makes every firing order deterministic and replayable — the
//! property all the daemon's scheduling tests pin under
//! `PALLAS_TEST_SEED`.
//!
//! The wheel drives three timer families for the daemon: scheduled
//! re-plan ticks, per-device report leases, and retire-TTL expiries
//! (`daemon::mod`). It knows nothing about any of them — items are an
//! opaque `T`.

/// A handle naming one scheduled entry, for [`TimerWheel::cancel`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TimerId {
    slot: usize,
    seq: u64,
}

struct Entry<T> {
    deadline: u64,
    seq: u64,
    item: T,
}

/// The hashed timer wheel. `now` is the last tick [`TimerWheel::advance`]
/// processed; deadlines at or before it fire on the next advance.
pub struct TimerWheel<T> {
    slots: Vec<Vec<Entry<T>>>,
    now: u64,
    next_seq: u64,
    len: usize,
}

impl<T> TimerWheel<T> {
    /// An empty wheel at tick `now` with `num_slots` hash buckets (any
    /// positive count; more slots = fewer collisions for dense horizons).
    pub fn new(now: u64, num_slots: usize) -> TimerWheel<T> {
        assert!(num_slots > 0, "a timer wheel needs at least one slot");
        TimerWheel {
            slots: (0..num_slots).map(|_| Vec::new()).collect(),
            now,
            next_seq: 0,
            len: 0,
        }
    }

    /// Schedule `item` to fire once the clock reaches `deadline`. A
    /// deadline at or before `now` is legal — it lands in the current
    /// slot and fires on the next [`TimerWheel::advance`] (the daemon's
    /// "immediately due" case).
    pub fn insert(&mut self, deadline: u64, item: T) -> TimerId {
        let n = self.slots.len() as u64;
        let slot = (deadline.max(self.now) % n) as usize;
        let seq = self.next_seq;
        self.next_seq += 1;
        self.slots[slot].push(Entry {
            deadline,
            seq,
            item,
        });
        self.len += 1;
        TimerId { slot, seq }
    }

    /// Cancel a scheduled entry, returning its item, or `None` if it
    /// already fired (or was already cancelled).
    pub fn cancel(&mut self, id: TimerId) -> Option<T> {
        let bucket = &mut self.slots[id.slot];
        let at = bucket.iter().position(|e| e.seq == id.seq)?;
        let entry = bucket.swap_remove(at);
        self.len -= 1;
        Some(entry.item)
    }

    /// Advance the clock to `to` (monotone) and collect everything whose
    /// deadline has passed, sorted by `(deadline, insertion seq)` — the
    /// deterministic firing order.
    pub fn advance(&mut self, to: u64) -> Vec<(TimerId, T)> {
        assert!(to >= self.now, "the timer wheel clock is monotone");
        let n = self.slots.len() as u64;
        let mut due: Vec<(TimerId, Entry<T>)> = Vec::new();
        let mut drain_slot = |slots: &mut Vec<Vec<Entry<T>>>, slot: usize| {
            let bucket = &mut slots[slot];
            let mut i = 0;
            while i < bucket.len() {
                if bucket[i].deadline <= to {
                    let entry = bucket.swap_remove(i);
                    due.push((
                        TimerId {
                            slot,
                            seq: entry.seq,
                        },
                        entry,
                    ));
                } else {
                    i += 1;
                }
            }
        };
        if to - self.now >= n {
            // A full revolution or more: every slot is touched anyway.
            for slot in 0..self.slots.len() {
                drain_slot(&mut self.slots, slot);
            }
        } else {
            // Visit exactly the slots the elapsed ticks hash into. The
            // current slot is included (a just-inserted past-deadline
            // entry lives there); revisiting is harmless because due
            // entries are removed as they fire.
            for tick in self.now..=to {
                drain_slot(&mut self.slots, (tick % n) as usize);
            }
        }
        self.len -= due.len();
        self.now = to;
        due.sort_by_key(|(_, e)| (e.deadline, e.seq));
        due.into_iter().map(|(id, e)| (id, e.item)).collect()
    }

    /// Entries currently scheduled.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether no entries are scheduled.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The last tick [`TimerWheel::advance`] processed.
    pub fn now(&self) -> u64 {
        self.now
    }

    /// Every pending entry as `(deadline, item)`, sorted by
    /// `(deadline, insertion seq)` — the same order [`TimerWheel::advance`]
    /// would fire them in. Re-inserting the list in this order into a
    /// fresh wheel at the same `now` reproduces the firing schedule
    /// exactly (new seqs are assigned ascending, so ties keep their
    /// relative order). This is the daemon snapshot's view of the wheel.
    pub(crate) fn entries(&self) -> Vec<(u64, T)>
    where
        T: Clone,
    {
        let mut all: Vec<(u64, u64, T)> = self
            .slots
            .iter()
            .flat_map(|bucket| bucket.iter().map(|e| (e.deadline, e.seq, e.item.clone())))
            .collect();
        all.sort_by_key(|&(deadline, seq, _)| (deadline, seq));
        all.into_iter().map(|(d, _, item)| (d, item)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn same_tick_insert_and_expire() {
        let mut wheel: TimerWheel<&str> = TimerWheel::new(5, 8);
        // Deadline == now and deadline < now both fire on the next
        // advance, even a zero-width one.
        wheel.insert(5, "at-now");
        wheel.insert(3, "past");
        let fired = wheel.advance(5);
        let items: Vec<&str> = fired.iter().map(|(_, i)| *i).collect();
        assert_eq!(items, vec!["past", "at-now"], "(deadline, seq) order");
        assert!(wheel.is_empty());
    }

    #[test]
    fn cancellation_removes_before_and_not_after_firing() {
        let mut wheel: TimerWheel<u32> = TimerWheel::new(0, 8);
        let a = wheel.insert(4, 1);
        let b = wheel.insert(4, 2);
        assert_eq!(wheel.cancel(a), Some(1));
        assert_eq!(wheel.cancel(a), None, "double cancel is None");
        assert_eq!(wheel.len(), 1);
        let fired = wheel.advance(10);
        assert_eq!(fired.len(), 1);
        assert_eq!(fired[0].1, 2);
        assert_eq!(wheel.cancel(b), None, "cancel after firing is None");
    }

    #[test]
    fn far_future_entries_survive_many_empty_ticks() {
        let mut wheel: TimerWheel<&str> = TimerWheel::new(0, 8);
        // 1000 ticks out: hashes into a slot the wheel will sweep ~125
        // times before the deadline, and must survive every sweep.
        wheel.insert(1000, "late");
        for t in 1..1000 {
            assert!(wheel.advance(t).is_empty(), "premature fire at {t}");
            assert_eq!(wheel.len(), 1);
        }
        let fired = wheel.advance(1000);
        assert_eq!(fired.len(), 1);
        assert_eq!(fired[0].1, "late");
    }

    #[test]
    fn large_jumps_fire_everything_due_in_order() {
        let mut wheel: TimerWheel<u64> = TimerWheel::new(0, 8);
        for d in [17u64, 3, 90, 3, 41] {
            wheel.insert(d, d);
        }
        // One jump of many revolutions: all due, (deadline, seq) sorted.
        let fired: Vec<u64> = wheel.advance(100).into_iter().map(|(_, d)| d).collect();
        assert_eq!(fired, vec![3, 3, 17, 41, 90]);
        assert!(wheel.is_empty());
    }

    #[test]
    fn lease_renewal_races_expiry() {
        // The daemon's lease pattern: a renewal cancels the old lease and
        // schedules a new one. Renew exactly at the expiry tick — the
        // cancel wins if it happens before the advance, loses after.
        let mut wheel: TimerWheel<&str> = TimerWheel::new(0, 8);
        let lease = wheel.insert(5, "lease-1");
        // Renewal arrives while the clock is still at 4: old lease is
        // cancelled before it can fire.
        wheel.advance(4);
        assert_eq!(wheel.cancel(lease), Some("lease-1"));
        let lease2 = wheel.insert(9, "lease-2");
        // This renewal is late: the clock passes 9 first.
        let fired = wheel.advance(9);
        assert_eq!(fired.len(), 1);
        assert_eq!(fired[0].1, "lease-2");
        assert_eq!(wheel.cancel(lease2), None, "expired before the renewal");
    }

    /// Determinism pin under `PALLAS_TEST_SEED`: a seeded random schedule
    /// (inserts, cancels, uneven advances) replayed twice fires the same
    /// items in the same order, and the wheel agrees with a naive sorted
    /// list on what fires when.
    #[test]
    fn seeded_schedule_is_deterministic_and_matches_a_naive_queue() {
        let seed = crate::util::rng::test_seed() ^ 0x71AE9;
        let run = |num_slots: usize| -> Vec<(u64, Vec<u64>)> {
            let mut rng = Rng::new(seed);
            let mut wheel: TimerWheel<u64> = TimerWheel::new(0, num_slots);
            let mut ids: Vec<TimerId> = Vec::new();
            let mut out = Vec::new();
            let mut now = 0u64;
            let mut next_item = 0u64;
            for _ in 0..200 {
                for _ in 0..rng.below(4) {
                    let deadline = now + rng.below(40);
                    ids.push(wheel.insert(deadline, next_item));
                    next_item += 1;
                }
                if !ids.is_empty() && rng.chance(0.2) {
                    let at = rng.below(ids.len() as u64) as usize;
                    wheel.cancel(ids.swap_remove(at));
                }
                now += rng.below(7);
                let fired: Vec<u64> = wheel.advance(now).into_iter().map(|(_, i)| i).collect();
                out.push((now, fired));
            }
            out
        };
        let a = run(8);
        let b = run(8);
        assert_eq!(a, b, "same seed, same firing schedule");
        // Slot count changes the hashing but not what fires when.
        let c = run(13);
        assert_eq!(a, c, "firing order is slot-count independent");
    }
}
