//! Graceful-drain primitives (the kumomta `kumo-server-lifecycle`
//! shape): an [`ActivityTracker`] counts outstanding producer activities
//! via RAII [`ActivityHandle`] guards, and shutdown waits for the count
//! to reach zero before the daemon stops intake and drains its queues.
//!
//! The contract the daemon builds on top (`daemon::mod`): a producer
//! holds a handle strictly while handing an event to the channel, so
//! `wait_idle` returning means every event any producer has *started*
//! sending is in the queue — the drain that follows loses nothing.

use std::sync::{Arc, Condvar, Mutex};

/// A shared counter of in-flight activities. Clones observe the same
/// count.
#[derive(Clone, Default)]
pub struct ActivityTracker {
    inner: Arc<(Mutex<usize>, Condvar)>,
}

impl ActivityTracker {
    /// A tracker with no outstanding activity.
    pub fn new() -> ActivityTracker {
        ActivityTracker::default()
    }

    /// Begin an activity: the count stays non-zero until the returned
    /// guard (and all its clones) drop.
    pub fn activity(&self) -> ActivityHandle {
        let (count, _) = &*self.inner;
        *count.lock().expect("activity lock poisoned") += 1;
        ActivityHandle {
            inner: Arc::clone(&self.inner),
        }
    }

    /// Outstanding activity guards right now.
    pub fn outstanding(&self) -> usize {
        let (count, _) = &*self.inner;
        *count.lock().expect("activity lock poisoned")
    }

    /// Block until no activity is outstanding. Returns immediately when
    /// the count is already zero.
    pub fn wait_idle(&self) {
        let (count, idle) = &*self.inner;
        let mut n = count.lock().expect("activity lock poisoned");
        while *n > 0 {
            n = idle.wait(n).expect("activity lock poisoned");
        }
    }
}

/// RAII guard for one activity; cloning extends the activity, the last
/// drop wakes [`ActivityTracker::wait_idle`] waiters.
pub struct ActivityHandle {
    inner: Arc<(Mutex<usize>, Condvar)>,
}

impl Clone for ActivityHandle {
    fn clone(&self) -> ActivityHandle {
        let (count, _) = &*self.inner;
        *count.lock().expect("activity lock poisoned") += 1;
        ActivityHandle {
            inner: Arc::clone(&self.inner),
        }
    }
}

impl Drop for ActivityHandle {
    fn drop(&mut self) {
        let (count, idle) = &*self.inner;
        let mut n = count.lock().expect("activity lock poisoned");
        *n -= 1;
        if *n == 0 {
            idle.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn guards_count_and_release() {
        let tracker = ActivityTracker::new();
        assert_eq!(tracker.outstanding(), 0);
        tracker.wait_idle(); // already idle: no block
        let a = tracker.activity();
        let b = a.clone();
        assert_eq!(tracker.outstanding(), 2);
        drop(a);
        assert_eq!(tracker.outstanding(), 1);
        drop(b);
        assert_eq!(tracker.outstanding(), 0);
        tracker.wait_idle();
    }

    #[test]
    fn wait_idle_blocks_until_the_last_guard_drops() {
        let tracker = ActivityTracker::new();
        let guard = tracker.activity();
        let waiter = {
            let tracker = tracker.clone();
            thread::spawn(move || {
                tracker.wait_idle();
                tracker.outstanding()
            })
        };
        // The waiter cannot finish while the guard lives; dropping it
        // releases the join.
        drop(guard);
        assert_eq!(waiter.join().expect("waiter panicked"), 0);
    }
}
