//! The daemon's injected time source.
//!
//! Policy code in this crate never reads the wall clock (the PR-6
//! simulated-clock rule); the daemon keeps that property by threading
//! every time read through the [`Clock`] trait. Tests and the bench
//! harness drive a [`SimClock`]; a production embedding would implement
//! `Clock` over a monotonic hardware source. Ticks are opaque `u64`s —
//! the epoch granularity of `PlannerService`, not nanoseconds.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// An injected monotone tick source. `Send + Sync` because the daemon
/// worker thread and its producers read it concurrently.
pub trait Clock: Send + Sync {
    /// The current tick. Implementations should be monotone; the daemon
    /// additionally clamps (timer fires) or degrades (explicit plan
    /// requests) when a source misbehaves, so a glitch cannot panic the
    /// worker.
    fn now(&self) -> u64;
}

/// The simulated clock: a shared atomic tick that tests and benches
/// advance by hand. Clones share the same underlying tick.
#[derive(Clone, Debug, Default)]
pub struct SimClock {
    tick: Arc<AtomicU64>,
}

impl SimClock {
    /// A simulated clock starting at `start`.
    pub fn new(start: u64) -> SimClock {
        SimClock {
            tick: Arc::new(AtomicU64::new(start)),
        }
    }

    /// Advance the clock by `by` ticks.
    pub fn advance(&self, by: u64) {
        self.tick.fetch_add(by, Ordering::SeqCst);
    }

    /// Set the clock to an absolute tick — including backwards, which is
    /// exactly how tests exercise the daemon's non-monotone-producer
    /// degraded path.
    pub fn set(&self, to: u64) {
        self.tick.store(to, Ordering::SeqCst);
    }

    /// The current tick.
    pub fn now(&self) -> u64 {
        self.tick.load(Ordering::SeqCst)
    }
}

impl Clock for SimClock {
    fn now(&self) -> u64 {
        SimClock::now(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_clock_clones_share_one_tick() {
        let a = SimClock::new(7);
        let b = a.clone();
        assert_eq!(b.now(), 7);
        a.advance(3);
        assert_eq!(a.now(), 10);
        assert_eq!(b.now(), 10);
        b.set(2);
        assert_eq!(a.now(), 2);
        let dyn_clock: Arc<dyn Clock> = Arc::new(a);
        assert_eq!(dyn_clock.now(), 2);
    }
}
