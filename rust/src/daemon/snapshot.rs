//! The daemon's crash-snapshot byte codec (PR 9).
//!
//! A hand-rolled little-endian codec — no serde, no derive macros —
//! turning a [`DaemonSnapshot`] (the full crash-surviving state of a
//! `Worker`: service image, daemon counters, lease sequences, timer-wheel
//! entries, and the daemon config itself) into bytes and back. The
//! journal layer (`daemon::journal`) wraps these payloads in CRC-framed
//! records; this module knows nothing about files.
//!
//! Design rules, all in service of the crash-recovery pin:
//!
//! * **Self-contained.** The snapshot carries every construction
//!   parameter (service options nested inside the image, daemon config
//!   fields alongside), so recovery needs nothing but the journal
//!   directory — no config has to survive the crash out-of-band.
//! * **Total decoding.** Every decode path returns a typed
//!   [`DecodeError`]; corrupt input can never panic or over-allocate
//!   (every length is bounds-checked against the remaining input before
//!   any allocation).
//! * **Deterministic encoding.** Field order is fixed, integers are
//!   little-endian, floats travel as IEEE-754 bits — encoding the same
//!   state twice yields identical bytes, which is what lets the recovery
//!   tests compare snapshots byte-for-byte.

use crate::graph::Dag;
use crate::partition::fleet::{
    DecisionProvenance, DecisionStats, DegradedReason, FleetImage, FleetOptions, PlanDecision,
    SpecDelta, TierImage,
};
use crate::partition::joint::{JointImage, JointOptions};
use crate::partition::service::{ServiceImage, ServiceOptions};
use crate::partition::types::{Link, Partition};
use crate::profiles::CostGraph;

use super::ingest::DaemonEvent;
use super::{DaemonCounters, TimerItem};

/// CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320) lookup table,
/// built at compile time.
const CRC_TABLE: [u32; 256] = build_crc_table();

const fn build_crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 {
                0xEDB8_8320 ^ (c >> 1)
            } else {
                c >> 1
            };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

/// CRC-32 of `bytes` — the journal's frame checksum.
pub(crate) fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// A typed decode failure: what the cursor refused and why. Corrupt
/// journal payloads surface as these (the journal layer then treats the
/// frame as torn).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) struct DecodeError(pub(crate) &'static str);

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "snapshot decode error: {}", self.0)
    }
}

impl std::error::Error for DecodeError {}

/// Byte encoder: append-only little-endian buffer.
pub(crate) struct Enc {
    pub(crate) buf: Vec<u8>,
}

impl Enc {
    pub(crate) fn new() -> Enc {
        Enc { buf: Vec::new() }
    }

    pub(crate) fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub(crate) fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub(crate) fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub(crate) fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    pub(crate) fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    pub(crate) fn bool(&mut self, v: bool) {
        self.u8(v as u8);
    }

    pub(crate) fn str(&mut self, s: &str) {
        self.usize(s.len());
        self.buf.extend_from_slice(s.as_bytes());
    }
}

/// Byte decoder: a bounds-checked cursor over an input slice. Every
/// failure is a typed [`DecodeError`]; nothing panics on corrupt input.
pub(crate) struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    pub(crate) fn new(buf: &'a [u8]) -> Dec<'a> {
        Dec { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], DecodeError> {
        if self.buf.len() - self.pos < n {
            return Err(DecodeError("unexpected end of input"));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub(crate) fn u8(&mut self) -> Result<u8, DecodeError> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u32(&mut self) -> Result<u32, DecodeError> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    pub(crate) fn u64(&mut self) -> Result<u64, DecodeError> {
        let s = self.take(8)?;
        let mut b = [0u8; 8];
        b.copy_from_slice(s);
        Ok(u64::from_le_bytes(b))
    }

    pub(crate) fn usize(&mut self) -> Result<usize, DecodeError> {
        usize::try_from(self.u64()?).map_err(|_| DecodeError("value overflows usize"))
    }

    pub(crate) fn f64(&mut self) -> Result<f64, DecodeError> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub(crate) fn bool(&mut self) -> Result<bool, DecodeError> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            _ => Err(DecodeError("boolean byte is neither 0 nor 1")),
        }
    }

    /// A collection length, sanity-bounded by the bytes still unread
    /// (every element encodes to at least one byte), so a corrupt length
    /// can never drive a huge allocation.
    pub(crate) fn len(&mut self) -> Result<usize, DecodeError> {
        let n = self.usize()?;
        if n > self.buf.len() - self.pos {
            return Err(DecodeError("collection length exceeds remaining input"));
        }
        Ok(n)
    }

    pub(crate) fn str(&mut self) -> Result<String, DecodeError> {
        let n = self.len()?;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| DecodeError("string is not UTF-8"))
    }

    /// Assert the whole input was consumed — trailing bytes mean a
    /// corrupt or foreign payload.
    pub(crate) fn done(&self) -> Result<(), DecodeError> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            Err(DecodeError("trailing bytes after payload"))
        }
    }
}

// ---------------------------------------------------------------------
// Leaf codecs
// ---------------------------------------------------------------------

fn enc_link(e: &mut Enc, l: &Link) {
    e.f64(l.up_bps);
    e.f64(l.down_bps);
}

fn dec_link(d: &mut Dec) -> Result<Link, DecodeError> {
    Ok(Link {
        up_bps: d.f64()?,
        down_bps: d.f64()?,
    })
}

fn enc_bools(e: &mut Enc, v: &[bool]) {
    e.usize(v.len());
    for &b in v {
        e.bool(b);
    }
}

fn dec_bools(d: &mut Dec) -> Result<Vec<bool>, DecodeError> {
    let n = d.len()?;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(d.bool()?);
    }
    Ok(out)
}

fn enc_f64s(e: &mut Enc, v: &[f64]) {
    e.usize(v.len());
    for &x in v {
        e.f64(x);
    }
}

fn dec_f64s(d: &mut Dec) -> Result<Vec<f64>, DecodeError> {
    let n = d.len()?;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(d.f64()?);
    }
    Ok(out)
}

fn enc_partition(e: &mut Enc, p: &Partition) {
    enc_bools(e, &p.device_set);
    e.f64(p.delay);
}

fn dec_partition(d: &mut Dec) -> Result<Partition, DecodeError> {
    Ok(Partition {
        device_set: dec_bools(d)?,
        delay: d.f64()?,
    })
}

fn enc_cached(e: &mut Enc, cached: &Option<(Link, Partition)>) {
    match cached {
        None => e.u8(0),
        Some((link, partition)) => {
            e.u8(1);
            enc_link(e, link);
            enc_partition(e, partition);
        }
    }
}

fn dec_cached(d: &mut Dec) -> Result<Option<(Link, Partition)>, DecodeError> {
    match d.u8()? {
        0 => Ok(None),
        1 => Ok(Some((dec_link(d)?, dec_partition(d)?))),
        _ => Err(DecodeError("bad Option tag for a cached decision")),
    }
}

fn enc_provenance(e: &mut Enc, p: DecisionProvenance) {
    e.u8(match p {
        DecisionProvenance::Fresh => 0,
        DecisionProvenance::Cached => 1,
        DecisionProvenance::Degraded(DegradedReason::StaleLink) => 2,
        DecisionProvenance::Degraded(DegradedReason::BudgetExceeded) => 3,
        DecisionProvenance::Retired => 4,
    });
}

fn dec_provenance(d: &mut Dec) -> Result<DecisionProvenance, DecodeError> {
    Ok(match d.u8()? {
        0 => DecisionProvenance::Fresh,
        1 => DecisionProvenance::Cached,
        2 => DecisionProvenance::Degraded(DegradedReason::StaleLink),
        3 => DecisionProvenance::Degraded(DegradedReason::BudgetExceeded),
        4 => DecisionProvenance::Retired,
        _ => return Err(DecodeError("bad DecisionProvenance tag")),
    })
}

fn enc_decision(e: &mut Enc, dec: &PlanDecision) {
    e.usize(dec.device);
    e.usize(dec.tier);
    enc_partition(e, &dec.partition);
    match dec.cut_layer {
        None => e.u8(0),
        Some(l) => {
            e.u8(1);
            e.usize(l);
        }
    }
    e.bool(dec.stats.refreshed);
    enc_provenance(e, dec.provenance);
}

fn dec_decision(d: &mut Dec) -> Result<PlanDecision, DecodeError> {
    Ok(PlanDecision {
        device: d.usize()?,
        tier: d.usize()?,
        partition: dec_partition(d)?,
        cut_layer: match d.u8()? {
            0 => None,
            1 => Some(d.usize()?),
            _ => return Err(DecodeError("bad Option tag for cut_layer")),
        },
        stats: DecisionStats {
            refreshed: d.bool()?,
        },
        provenance: dec_provenance(d)?,
    })
}

fn enc_dag(e: &mut Enc, dag: &Dag) {
    e.usize(dag.len());
    for v in 0..dag.len() {
        e.str(dag.label(v));
    }
    e.usize(dag.edges().len());
    for edge in dag.edges() {
        e.usize(edge.from);
        e.usize(edge.to);
        e.f64(edge.weight);
    }
}

fn dec_dag(d: &mut Dec) -> Result<Dag, DecodeError> {
    let n = d.len()?;
    let mut dag = Dag::new();
    for _ in 0..n {
        let label = d.str()?;
        dag.add_node(label);
    }
    let m = d.len()?;
    for _ in 0..m {
        let from = d.usize()?;
        let to = d.usize()?;
        let weight = d.f64()?;
        // `Dag::add_edge` asserts these; a corrupt payload must decode to
        // a typed error, not a panic.
        if from >= n || to >= n || from == to {
            return Err(DecodeError("malformed DAG edge"));
        }
        dag.add_edge(from, to, weight);
    }
    Ok(dag)
}

fn enc_costs(e: &mut Enc, c: &CostGraph) {
    enc_dag(e, &c.dag);
    enc_f64s(e, &c.xi_d);
    enc_f64s(e, &c.xi_s);
    enc_f64s(e, &c.act_bytes);
    enc_f64s(e, &c.param_bytes);
    e.f64(c.n_loc);
}

fn dec_costs(d: &mut Dec) -> Result<CostGraph, DecodeError> {
    Ok(CostGraph {
        dag: dec_dag(d)?,
        xi_d: dec_f64s(d)?,
        xi_s: dec_f64s(d)?,
        act_bytes: dec_f64s(d)?,
        param_bytes: dec_f64s(d)?,
        n_loc: d.f64()?,
    })
}

pub(crate) fn enc_delta(e: &mut Enc, delta: &SpecDelta) {
    match delta {
        SpecDelta::AddTier { name, costs } => {
            e.u8(0);
            e.str(name);
            enc_costs(e, costs);
        }
        SpecDelta::RetireTier { tier } => {
            e.u8(1);
            e.usize(*tier);
        }
        SpecDelta::AddDevice { device, tier } => {
            e.u8(2);
            e.usize(*device);
            e.usize(*tier);
        }
        SpecDelta::RemoveDevice { device } => {
            e.u8(3);
            e.usize(*device);
        }
        SpecDelta::MigrateDevice { device, tier } => {
            e.u8(4);
            e.usize(*device);
            e.usize(*tier);
        }
    }
}

pub(crate) fn dec_delta(d: &mut Dec) -> Result<SpecDelta, DecodeError> {
    Ok(match d.u8()? {
        0 => {
            let name = d.str()?;
            let costs = dec_costs(d)?;
            // Tier names are `&'static str` by the spec's contract; a
            // journaled AddTier re-leaks its name once per replay —
            // bounded by the journal length, same as `from_image`.
            SpecDelta::AddTier {
                name: Box::leak(name.into_boxed_str()),
                costs,
            }
        }
        1 => SpecDelta::RetireTier { tier: d.usize()? },
        2 => SpecDelta::AddDevice {
            device: d.usize()?,
            tier: d.usize()?,
        },
        3 => SpecDelta::RemoveDevice { device: d.usize()? },
        4 => SpecDelta::MigrateDevice {
            device: d.usize()?,
            tier: d.usize()?,
        },
        _ => return Err(DecodeError("bad SpecDelta tag")),
    })
}

pub(crate) fn enc_event(e: &mut Enc, event: &DaemonEvent) {
    match event {
        DaemonEvent::Delta(delta) => {
            e.u8(0);
            enc_delta(e, delta);
        }
        DaemonEvent::Report { device, link, tick } => {
            e.u8(1);
            e.usize(*device);
            enc_link(e, link);
            e.u64(*tick);
        }
    }
}

pub(crate) fn dec_event(d: &mut Dec) -> Result<DaemonEvent, DecodeError> {
    Ok(match d.u8()? {
        0 => DaemonEvent::Delta(dec_delta(d)?),
        1 => DaemonEvent::Report {
            device: d.usize()?,
            link: dec_link(d)?,
            tick: d.u64()?,
        },
        _ => return Err(DecodeError("bad DaemonEvent tag")),
    })
}

fn enc_timer_item(e: &mut Enc, item: &TimerItem) {
    match item {
        TimerItem::Replan { at } => {
            e.u8(0);
            e.u64(*at);
        }
        TimerItem::Lease { device, seq } => {
            e.u8(1);
            e.usize(*device);
            e.u64(*seq);
        }
        TimerItem::RetireExpiry { tier } => {
            e.u8(2);
            e.usize(*tier);
        }
    }
}

fn dec_timer_item(d: &mut Dec) -> Result<TimerItem, DecodeError> {
    Ok(match d.u8()? {
        0 => TimerItem::Replan { at: d.u64()? },
        1 => TimerItem::Lease {
            device: d.usize()?,
            seq: d.u64()?,
        },
        2 => TimerItem::RetireExpiry { tier: d.usize()? },
        _ => return Err(DecodeError("bad TimerItem tag")),
    })
}

// ---------------------------------------------------------------------
// Options codecs
// ---------------------------------------------------------------------

fn enc_fleet_options(e: &mut Enc, o: &FleetOptions) {
    e.bool(o.pin_inputs);
    e.bool(o.closure_edges);
    e.bool(o.block_reduction);
    e.bool(o.incremental);
    e.u64(o.retire_ttl);
    e.u32(o.sigma_buckets_per_decade);
}

fn dec_fleet_options(d: &mut Dec) -> Result<FleetOptions, DecodeError> {
    Ok(FleetOptions {
        pin_inputs: d.bool()?,
        closure_edges: d.bool()?,
        block_reduction: d.bool()?,
        incremental: d.bool()?,
        retire_ttl: d.u64()?,
        sigma_buckets_per_decade: d.u32()?,
    })
}

fn enc_joint_options(e: &mut Enc, o: &JointOptions) {
    e.f64(o.server_capacity);
    enc_fleet_options(e, &o.fleet);
}

fn dec_joint_options(d: &mut Dec) -> Result<JointOptions, DecodeError> {
    let server_capacity = d.f64()?;
    if !(server_capacity > 0.0) {
        return Err(DecodeError("server capacity must be positive"));
    }
    Ok(JointOptions {
        server_capacity,
        fleet: dec_fleet_options(d)?,
    })
}

fn enc_service_options(e: &mut Enc, o: &ServiceOptions) {
    e.u64(o.staleness_bound);
    e.u64(o.solve_budget);
    enc_joint_options(e, &o.joint);
}

fn dec_service_options(d: &mut Dec) -> Result<ServiceOptions, DecodeError> {
    Ok(ServiceOptions {
        staleness_bound: d.u64()?,
        solve_budget: d.u64()?,
        joint: dec_joint_options(d)?,
    })
}

// ---------------------------------------------------------------------
// Image codecs
// ---------------------------------------------------------------------

fn enc_tier_image(e: &mut Enc, t: &TierImage) {
    match t {
        TierImage::Active { solved, counters } => {
            e.u8(0);
            enc_cached(e, solved);
            for &c in counters {
                e.u64(c);
            }
        }
        TierImage::Retired {
            last,
            ttl,
            counters,
        } => {
            e.u8(1);
            enc_cached(e, last);
            e.u64(*ttl);
            for &c in counters {
                e.u64(c);
            }
        }
    }
}

fn dec_counters7(d: &mut Dec) -> Result<[u64; 7], DecodeError> {
    let mut counters = [0u64; 7];
    for c in &mut counters {
        *c = d.u64()?;
    }
    Ok(counters)
}

fn dec_tier_image(d: &mut Dec) -> Result<TierImage, DecodeError> {
    Ok(match d.u8()? {
        0 => TierImage::Active {
            solved: dec_cached(d)?,
            counters: dec_counters7(d)?,
        },
        1 => TierImage::Retired {
            last: dec_cached(d)?,
            ttl: d.u64()?,
            counters: dec_counters7(d)?,
        },
        _ => return Err(DecodeError("bad TierImage tag")),
    })
}

fn enc_fleet_image(e: &mut Enc, f: &FleetImage) {
    e.usize(f.tier_names.len());
    for name in &f.tier_names {
        e.str(name);
    }
    e.usize(f.tier_costs.len());
    for costs in &f.tier_costs {
        enc_costs(e, costs);
    }
    enc_bools(e, &f.retired);
    e.usize(f.tier_of_device.len());
    for t in &f.tier_of_device {
        match t {
            None => e.u8(0),
            Some(tier) => {
                e.u8(1);
                e.usize(*tier);
            }
        }
    }
    e.usize(f.tiers.len());
    for t in &f.tiers {
        enc_tier_image(e, t);
    }
    e.u64(f.plans);
    e.u64(f.requests);
    e.u64(f.spec_deltas);
    e.u64(f.retired_decisions);
    e.u64(f.degraded_decisions);
    e.u64(f.quantized_requests);
}

fn dec_fleet_image(d: &mut Dec) -> Result<FleetImage, DecodeError> {
    let n_names = d.len()?;
    let mut tier_names = Vec::with_capacity(n_names);
    for _ in 0..n_names {
        tier_names.push(d.str()?);
    }
    let n_costs = d.len()?;
    let mut tier_costs = Vec::with_capacity(n_costs);
    for _ in 0..n_costs {
        tier_costs.push(dec_costs(d)?);
    }
    let retired = dec_bools(d)?;
    let n_devices = d.len()?;
    let mut tier_of_device = Vec::with_capacity(n_devices);
    for _ in 0..n_devices {
        tier_of_device.push(match d.u8()? {
            0 => None,
            1 => Some(d.usize()?),
            _ => return Err(DecodeError("bad Option tag for a device mapping")),
        });
    }
    let n_tiers = d.len()?;
    let mut tiers = Vec::with_capacity(n_tiers);
    for _ in 0..n_tiers {
        tiers.push(dec_tier_image(d)?);
    }
    let img = FleetImage {
        tier_names,
        tier_costs,
        retired,
        tier_of_device,
        tiers,
        plans: d.u64()?,
        requests: d.u64()?,
        spec_deltas: d.u64()?,
        retired_decisions: d.u64()?,
        degraded_decisions: d.u64()?,
        quantized_requests: d.u64()?,
    };
    // Cross-field invariants `FleetSpec::from_parts` / `from_image` would
    // assert — refused here as typed errors so corrupt input cannot
    // panic the recovery path.
    if img.tier_names.len() != img.tier_costs.len()
        || img.tier_names.len() != img.retired.len()
        || img.tier_names.len() != img.tiers.len()
        || img.tier_names.is_empty()
    {
        return Err(DecodeError("fleet image tier tables disagree"));
    }
    if !img
        .tier_of_device
        .iter()
        .flatten()
        .all(|&t| t < img.tier_names.len() && !img.retired[t])
    {
        return Err(DecodeError("device mapped to unknown or retired tier"));
    }
    Ok(img)
}

fn enc_joint_image(e: &mut Enc, j: &JointImage) {
    enc_joint_options(e, &j.options);
    enc_fleet_image(e, &j.fleet);
    match &j.probe {
        None => e.u8(0),
        Some(p) => {
            e.u8(1);
            enc_fleet_image(e, p);
        }
    }
    e.u64(j.price_iterations);
    e.u64(j.joint_resolves);
    match j.last_makespan {
        None => e.u8(0),
        Some(m) => {
            e.u8(1);
            e.f64(m);
        }
    }
    match j.last_congestion {
        None => e.u8(0),
        Some(c) => {
            e.u8(1);
            e.f64(c);
        }
    }
}

fn dec_joint_image(d: &mut Dec) -> Result<JointImage, DecodeError> {
    Ok(JointImage {
        options: dec_joint_options(d)?,
        fleet: dec_fleet_image(d)?,
        probe: match d.u8()? {
            0 => None,
            1 => Some(dec_fleet_image(d)?),
            _ => return Err(DecodeError("bad Option tag for the probe image")),
        },
        price_iterations: d.u64()?,
        joint_resolves: d.u64()?,
        last_makespan: match d.u8()? {
            0 => None,
            1 => Some(d.f64()?),
            _ => return Err(DecodeError("bad Option tag for last_makespan")),
        },
        last_congestion: match d.u8()? {
            0 => None,
            1 => Some(d.f64()?),
            _ => return Err(DecodeError("bad Option tag for last_congestion")),
        },
    })
}

fn enc_service_image(e: &mut Enc, s: &ServiceImage) {
    enc_service_options(e, &s.options);
    enc_joint_image(e, &s.joint);
    e.usize(s.reports.len());
    for r in &s.reports {
        match r {
            None => e.u8(0),
            Some((link, tick)) => {
                e.u8(1);
                enc_link(e, link);
                e.u64(*tick);
            }
        }
    }
    e.usize(s.last_good.len());
    for g in &s.last_good {
        match g {
            None => e.u8(0),
            Some(decision) => {
                e.u8(1);
                enc_decision(e, decision);
            }
        }
    }
    enc_bools(e, &s.forced_stale);
    e.u64(s.now);
    e.u64(s.degraded_stale);
    e.u64(s.degraded_budget);
    e.u64(s.refused_reports);
}

fn dec_service_image(d: &mut Dec) -> Result<ServiceImage, DecodeError> {
    let options = dec_service_options(d)?;
    let joint = dec_joint_image(d)?;
    let n_reports = d.len()?;
    let mut reports = Vec::with_capacity(n_reports);
    for _ in 0..n_reports {
        reports.push(match d.u8()? {
            0 => None,
            1 => Some((dec_link(d)?, d.u64()?)),
            _ => return Err(DecodeError("bad Option tag for a report slot")),
        });
    }
    let n_good = d.len()?;
    let mut last_good = Vec::with_capacity(n_good);
    for _ in 0..n_good {
        last_good.push(match d.u8()? {
            0 => None,
            1 => Some(dec_decision(d)?),
            _ => return Err(DecodeError("bad Option tag for a last-good slot")),
        });
    }
    let img = ServiceImage {
        options,
        joint,
        reports,
        last_good,
        forced_stale: dec_bools(d)?,
        now: d.u64()?,
        degraded_stale: d.u64()?,
        degraded_budget: d.u64()?,
        refused_reports: d.u64()?,
    };
    if img.reports.len() != img.last_good.len() || img.reports.len() != img.forced_stale.len() {
        return Err(DecodeError("service image per-device tables disagree"));
    }
    Ok(img)
}

fn enc_daemon_counters(e: &mut Enc, c: &DaemonCounters) {
    e.u64(c.events_ingested);
    e.u64(c.deltas_ingested);
    e.u64(c.reports_ingested);
    e.u64(c.rejected_events);
    e.u64(c.coalesced_deltas);
    e.u64(c.coalesced_reports);
    e.u64(c.timer_fires);
    e.u64(c.replan_ticks);
    e.u64(c.lease_expiries);
    e.u64(c.retire_expiries);
    e.u64(c.clock_errors);
}

fn dec_daemon_counters(d: &mut Dec) -> Result<DaemonCounters, DecodeError> {
    Ok(DaemonCounters {
        events_ingested: d.u64()?,
        deltas_ingested: d.u64()?,
        reports_ingested: d.u64()?,
        rejected_events: d.u64()?,
        coalesced_deltas: d.u64()?,
        coalesced_reports: d.u64()?,
        timer_fires: d.u64()?,
        replan_ticks: d.u64()?,
        lease_expiries: d.u64()?,
        retire_expiries: d.u64()?,
        clock_errors: d.u64()?,
    })
}

// ---------------------------------------------------------------------
// The snapshot
// ---------------------------------------------------------------------

/// The full crash-surviving state of a daemon worker at a quiescent
/// point (coalescer empty, no fired batch in flight): the daemon config,
/// the service image (which nests its own options, planner images and
/// per-device tables), the daemon counters, the per-device lease
/// sequences, and the timer wheel's clock + pending entries in canonical
/// `(deadline, insertion seq)` order (`TimerWheel::entries`).
pub(crate) struct DaemonSnapshot {
    pub(crate) replan_every: u64,
    pub(crate) lease_ttl: Option<u64>,
    pub(crate) wheel_slots: u64,
    pub(crate) snapshot_every: u64,
    pub(crate) ingest_capacity: u64,
    pub(crate) service: ServiceImage,
    pub(crate) counters: DaemonCounters,
    pub(crate) lease_seq: Vec<u64>,
    pub(crate) wheel_now: u64,
    pub(crate) wheel_entries: Vec<(u64, TimerItem)>,
}

impl DaemonSnapshot {
    pub(crate) fn encode(&self) -> Vec<u8> {
        let mut e = Enc::new();
        e.u64(self.replan_every);
        match self.lease_ttl {
            None => e.u8(0),
            Some(ttl) => {
                e.u8(1);
                e.u64(ttl);
            }
        }
        e.u64(self.wheel_slots);
        e.u64(self.snapshot_every);
        e.u64(self.ingest_capacity);
        enc_service_image(&mut e, &self.service);
        enc_daemon_counters(&mut e, &self.counters);
        e.usize(self.lease_seq.len());
        for &s in &self.lease_seq {
            e.u64(s);
        }
        e.u64(self.wheel_now);
        e.usize(self.wheel_entries.len());
        for (deadline, item) in &self.wheel_entries {
            e.u64(*deadline);
            enc_timer_item(&mut e, item);
        }
        e.buf
    }

    pub(crate) fn decode(bytes: &[u8]) -> Result<DaemonSnapshot, DecodeError> {
        let mut d = Dec::new(bytes);
        let replan_every = d.u64()?;
        if replan_every == 0 {
            return Err(DecodeError("replan_every must be positive"));
        }
        let lease_ttl = match d.u8()? {
            0 => None,
            1 => Some(d.u64()?),
            _ => return Err(DecodeError("bad Option tag for lease_ttl")),
        };
        let wheel_slots = d.u64()?;
        if wheel_slots == 0 {
            return Err(DecodeError("the timer wheel needs at least one slot"));
        }
        let snapshot_every = d.u64()?;
        let ingest_capacity = d.u64()?;
        let service = dec_service_image(&mut d)?;
        let counters = dec_daemon_counters(&mut d)?;
        let n_leases = d.len()?;
        let mut lease_seq = Vec::with_capacity(n_leases);
        for _ in 0..n_leases {
            lease_seq.push(d.u64()?);
        }
        let wheel_now = d.u64()?;
        let n_entries = d.len()?;
        let mut wheel_entries = Vec::with_capacity(n_entries);
        for _ in 0..n_entries {
            let deadline = d.u64()?;
            let item = dec_timer_item(&mut d)?;
            wheel_entries.push((deadline, item));
        }
        d.done()?;
        Ok(DaemonSnapshot {
            replan_every,
            lease_ttl,
            wheel_slots,
            snapshot_every,
            ingest_capacity,
            service,
            counters,
            lease_seq,
            wheel_now,
            wheel_entries,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::partition::service::PlannerService;
    use crate::partition::fleet::FleetSpec;
    use crate::profiles::{DeviceProfile, TrainCfg};

    fn sample_service() -> PlannerService {
        let m = models::by_name("googlenet").unwrap();
        let spec = FleetSpec::from_fleet(&DeviceProfile::fleet_of(3), |d| {
            CostGraph::build(&m, d, &DeviceProfile::rtx_a6000(), &TrainCfg::default())
        });
        let mut service = PlannerService::new(spec, ServiceOptions::default());
        for d in 0..3 {
            service.report(d, Link::symmetric(4e5 + d as f64 * 1e5), 0);
        }
        service.plan_epoch(0).unwrap();
        service.apply_delta(&SpecDelta::RemoveDevice { device: 2 });
        service
    }

    fn sample_snapshot() -> DaemonSnapshot {
        DaemonSnapshot {
            replan_every: 3,
            lease_ttl: Some(7),
            wheel_slots: 256,
            snapshot_every: 32,
            ingest_capacity: 1024,
            service: sample_service().export_image(),
            counters: DaemonCounters {
                events_ingested: 12,
                deltas_ingested: 2,
                reports_ingested: 9,
                rejected_events: 1,
                coalesced_deltas: 2,
                coalesced_reports: 8,
                timer_fires: 5,
                replan_ticks: 4,
                lease_expiries: 1,
                retire_expiries: 0,
                clock_errors: 0,
            },
            lease_seq: vec![3, 1, 0, 2],
            wheel_now: 11,
            wheel_entries: vec![
                (12, TimerItem::Replan { at: 12 }),
                (13, TimerItem::Lease { device: 1, seq: 1 }),
                (75, TimerItem::RetireExpiry { tier: 2 }),
            ],
        }
    }

    /// CRC-32 (IEEE) against the classic check vector.
    #[test]
    fn crc32_matches_the_reference_vector() {
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    /// Encode → decode → re-encode is the identity on bytes: the codec
    /// round-trips a real post-epoch service image (cached decisions,
    /// churned spec, counters) exactly.
    #[test]
    fn snapshot_roundtrip_is_byte_identical() {
        let snapshot = sample_snapshot();
        let bytes = snapshot.encode();
        let decoded = DaemonSnapshot::decode(&bytes).expect("valid bytes decode");
        assert_eq!(bytes, decoded.encode(), "re-encoding must reproduce bytes");
    }

    /// Every truncation of a valid payload decodes to a typed error —
    /// never a panic, never a bogus success.
    #[test]
    fn truncated_snapshots_fail_typed() {
        let bytes = sample_snapshot().encode();
        for cut in 0..bytes.len() {
            assert!(
                DaemonSnapshot::decode(&bytes[..cut]).is_err(),
                "a {cut}-byte prefix of {} must not decode",
                bytes.len()
            );
        }
    }

    /// Events and deltas round-trip through the frame-payload codec.
    #[test]
    fn event_roundtrip_covers_every_variant() {
        let m = models::by_name("googlenet").unwrap();
        let costs = CostGraph::build(
            &m,
            &DeviceProfile::jetson_tx2(),
            &DeviceProfile::rtx_a6000(),
            &TrainCfg::default(),
        );
        let events = [
            DaemonEvent::Delta(SpecDelta::AddTier {
                name: "tier-x",
                costs,
            }),
            DaemonEvent::Delta(SpecDelta::RetireTier { tier: 1 }),
            DaemonEvent::Delta(SpecDelta::AddDevice { device: 5, tier: 0 }),
            DaemonEvent::Delta(SpecDelta::RemoveDevice { device: 5 }),
            DaemonEvent::Delta(SpecDelta::MigrateDevice { device: 2, tier: 1 }),
            DaemonEvent::Report {
                device: 3,
                link: Link {
                    up_bps: 1.5e5,
                    down_bps: 2.5e5,
                },
                tick: 42,
            },
        ];
        for event in &events {
            let mut e = Enc::new();
            enc_event(&mut e, event);
            let mut d = Dec::new(&e.buf);
            let back = dec_event(&mut d).expect("valid event decodes");
            d.done().expect("event payload fully consumed");
            let mut e2 = Enc::new();
            enc_event(&mut e2, &back);
            assert_eq!(e.buf, e2.buf, "event re-encoding must reproduce bytes");
        }
    }

    /// Unknown tags are refused with typed errors.
    #[test]
    fn bad_tags_are_refused() {
        let mut d = Dec::new(&[9]);
        assert!(dec_event(&mut d).is_err());
        let mut d = Dec::new(&[7]);
        assert!(dec_timer_item(&mut d).is_err());
        let mut d = Dec::new(&[5]);
        assert!(dec_provenance(&mut d).is_err());
        // A boolean byte that is neither 0 nor 1 is corrupt, not truthy.
        let mut d = Dec::new(&[2]);
        assert!(d.bool().is_err());
    }
}
