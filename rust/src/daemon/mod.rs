//! The planner daemon: `partition::service::PlannerService` as a
//! long-lived system process (PR 7).
//!
//! PRs 1–6 built an exact, churn-tolerant planning *library* ticked by a
//! simulator. This module gives it the daemon face the ROADMAP calls
//! for, with std::thread + mpsc channels only (no async runtime —
//! consistent with the vendored rayon-shim approach):
//!
//! * [`ingest`] — concurrent producers send [`DaemonEvent`]s down a
//!   *bounded* mpsc channel (typed [`SendError::Backpressure`] when
//!   full — shed, counted, never blocking the producer); a [`Coalescer`]
//!   folds them between plan ticks into the smallest batch that replays
//!   bit-identically to the raw stream (add+remove cancels, migrate
//!   chains collapse, reports are last-writer-wins), validating at the
//!   door so a misbehaving producer is counted and refused instead of
//!   crashing the loop.
//! * [`timeq`] — a hashed [`TimerWheel`] (the kumomta `crates/timeq`
//!   shape) schedules re-plan ticks, per-device report leases (expiry ⇒
//!   the device plans as `Degraded(StaleLink)` *before* the staleness
//!   bound would notice — lease beats bound) and retire-TTL expiries.
//!   Time comes from an injected [`Clock`]; every test runs on
//!   [`SimClock`] with zero wall-clock in policy code.
//! * [`lifecycle`] — graceful drain: [`DaemonHandle::shutdown`] waits
//!   for in-flight sends ([`ActivityTracker`] guards), stops intake,
//!   flushes the coalesced backlog into the service *without planning*,
//!   and hands back the final state — no event loss, no post-shutdown
//!   solves (both pinned by the drain test).
//! * [`metrics`] — the scrape surface: [`DaemonHandle::metrics`]
//!   renders `FleetStats` + service + daemon counters as Prometheus
//!   text, byte-stable under the golden test.
//! * [`journal`] — opt-in crash safety (PR 9): with
//!   [`DaemonConfig::journal_dir`] set, every event, wheel advance, plan
//!   request and the final drain is written ahead as a CRC-framed record
//!   behind a full state snapshot, so [`PlannerDaemon::recover`]
//!   restores the daemon bit-identically from `snapshot + tail replay`.
//!   Torn tails truncate (counted, typed, never a panic); foreign or
//!   cross-version journals refuse with a [`JournalError`].
//!
//! Contracts are documented in RESILIENCE.md ("Daemon contracts" and
//! "Durability contracts"); the headline pins replay seeded
//! `ChurnScript`s through the daemon demanding bit-identical epochs —
//! against a raw uncoalesced `PlannerService`, and (in [`journal`])
//! against crash-and-recover runs cut at every frame boundary.

pub mod clock;
pub mod ingest;
pub mod journal;
pub mod lifecycle;
pub mod metrics;
pub(crate) mod snapshot;
pub mod timeq;

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::{self, JoinHandle};

use crate::partition::fleet::{
    DecisionProvenance, DegradedReason, FleetSpec, FleetStats, PlanDecision, SpecDelta,
};
use crate::partition::service::{PlannerService, ServiceOptions};

use journal::{Frame, JournalWriter, RecoveredJournal};
use snapshot::DaemonSnapshot;

pub use clock::{Clock, SimClock};
pub use ingest::{CoalescedItem, Coalescer, DaemonEvent, IngestError};
pub use journal::{JournalError, RecoveryReport};
pub use lifecycle::{ActivityHandle, ActivityTracker};
pub use metrics::{fleet_metrics, render_prometheus, service_metrics, Metric, MetricKind};
pub use timeq::{TimerId, TimerWheel};

/// Construction-time policy of the daemon.
#[derive(Clone, Debug)]
pub struct DaemonConfig {
    /// Schedule a re-plan every this many clock ticks (>= 1).
    pub replan_every: u64,
    /// Report lease: a device whose newest accepted report is older than
    /// this many ticks is force-expired (planned as
    /// `Degraded(StaleLink)`) without waiting for the service's
    /// staleness bound. `None` (default) disables leases.
    pub lease_ttl: Option<u64>,
    /// Hash buckets of the timer wheel.
    pub wheel_slots: usize,
    /// Policy of the wrapped [`PlannerService`].
    pub service: ServiceOptions,
    /// Write-ahead journal directory. `None` (default) runs the daemon
    /// exactly as PR 7/8 did — durability is strictly opt-in.
    pub journal_dir: Option<PathBuf>,
    /// Rotate the journal onto a fresh snapshot file after this many
    /// planned epochs, bounding recovery replay time.
    pub snapshot_every: u64,
    /// Bound of the ingest channel; a full queue sheds with
    /// [`SendError::Backpressure`] instead of blocking producers.
    pub ingest_capacity: usize,
}

impl Default for DaemonConfig {
    fn default() -> DaemonConfig {
        DaemonConfig {
            replan_every: 1,
            lease_ttl: None,
            wheel_slots: 256,
            service: ServiceOptions::default(),
            journal_dir: None,
            snapshot_every: 32,
            ingest_capacity: 1024,
        }
    }
}

/// What a wheel entry means when it fires.
#[derive(Clone, Copy, Debug)]
pub(crate) enum TimerItem {
    /// The scheduled re-plan for tick `at` (reschedules itself).
    Replan { at: u64 },
    /// Device `device`'s report lease ran out; stale unless a newer
    /// report bumped the lease seq past `seq`.
    Lease { device: usize, seq: u64 },
    /// A retired tier's archive TTL ran out (wall ticks, not plan
    /// epochs — see `FleetPlanner::expire_retired`).
    RetireExpiry { tier: usize },
}

/// How a drained shutdown ended — recorded as the journal's final frame
/// so recovery can tell a graceful stop from a crash.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DrainOutcome {
    /// A graceful [`DaemonHandle::shutdown`]: intake idled before the
    /// drain, so every started send is in the final state.
    Clean,
    /// The handle was dropped: the drain flushed whatever had already
    /// arrived, with no idle wait.
    BestEffort,
}

/// Why an [`EventSender::send`] was refused.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SendError {
    /// The bounded ingest channel is full; the event was shed and
    /// counted in `fastsplit_ingest_shed_total`.
    Backpressure,
    /// The daemon has shut down.
    Closed,
}

impl std::fmt::Display for SendError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SendError::Backpressure => write!(f, "the ingest channel is full (event shed)"),
            SendError::Closed => write!(f, "the daemon has shut down"),
        }
    }
}

impl std::error::Error for SendError {}

/// Daemon-level counters, alongside the planner's [`FleetStats`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DaemonCounters {
    /// Raw events received (accepted + rejected).
    pub events_ingested: u64,
    /// Accepted churn deltas.
    pub deltas_ingested: u64,
    /// Accepted link reports.
    pub reports_ingested: u64,
    /// Events refused at the door ([`IngestError`]).
    pub rejected_events: u64,
    /// Deltas that survived coalescing and reached the service.
    pub coalesced_deltas: u64,
    /// Reports that survived coalescing and reached the service.
    pub coalesced_reports: u64,
    /// Timer-wheel entries fired (all kinds).
    pub timer_fires: u64,
    /// Scheduled re-plan ticks executed.
    pub replan_ticks: u64,
    /// Report leases that expired unrenewed.
    pub lease_expiries: u64,
    /// Retire-TTL expiries applied.
    pub retire_expiries: u64,
    /// Epochs degraded by a non-monotone clock read.
    pub clock_errors: u64,
}

impl DaemonCounters {
    /// The daemon counter family for the metrics scrape.
    pub fn metrics(&self) -> Vec<Metric> {
        let counter = |name, help, value| Metric {
            name,
            help,
            kind: MetricKind::Counter,
            value,
        };
        vec![
            counter(
                "fastsplit_daemon_events_ingested_total",
                "Raw events received by the daemon",
                self.events_ingested,
            ),
            counter(
                "fastsplit_daemon_deltas_ingested_total",
                "Churn deltas accepted at the door",
                self.deltas_ingested,
            ),
            counter(
                "fastsplit_daemon_reports_ingested_total",
                "Link reports accepted at the door",
                self.reports_ingested,
            ),
            counter(
                "fastsplit_daemon_rejected_events_total",
                "Events refused at the door",
                self.rejected_events,
            ),
            counter(
                "fastsplit_daemon_coalesced_deltas_total",
                "Deltas surviving coalescing into the service",
                self.coalesced_deltas,
            ),
            counter(
                "fastsplit_daemon_coalesced_reports_total",
                "Reports surviving coalescing into the service",
                self.coalesced_reports,
            ),
            counter(
                "fastsplit_daemon_timer_fires_total",
                "Timer-wheel entries fired",
                self.timer_fires,
            ),
            counter(
                "fastsplit_daemon_replan_ticks_total",
                "Scheduled re-plan ticks executed",
                self.replan_ticks,
            ),
            counter(
                "fastsplit_daemon_lease_expiries_total",
                "Report leases expired unrenewed",
                self.lease_expiries,
            ),
            counter(
                "fastsplit_daemon_retire_expiries_total",
                "Retire-TTL expiries applied",
                self.retire_expiries,
            ),
            counter(
                "fastsplit_daemon_clock_errors_total",
                "Epochs degraded by non-monotone clock reads",
                self.clock_errors,
            ),
        ]
    }
}

/// Durability counters of the write-ahead journal.
#[derive(Clone, Copy, Debug, Default)]
struct JournalStats {
    /// Frames appended (snapshots included).
    frames: u64,
    /// Bytes appended (headers + frames).
    bytes: u64,
    /// Snapshot frames written (creations + rotations).
    snapshots: u64,
    /// Torn-tail truncations observed at recovery.
    torn: u64,
    /// Times this state was recovered from a journal.
    recoveries: u64,
    /// Recoveries whose journal had no drain frame (a crash).
    dirty_recoveries: u64,
    /// I/O failures; each one degrades journaling off rather than
    /// crashing the planner.
    io_errors: u64,
}

/// One planned (or clock-degraded) epoch the daemon produced.
#[derive(Clone, Debug)]
pub struct EpochOutcome {
    /// The tick the epoch was planned at (the requested tick when the
    /// clock read was rejected).
    pub tick: u64,
    /// The epoch's decisions, device-slot order.
    pub decisions: Vec<PlanDecision>,
    /// True when the clock read was non-monotone and the epoch was
    /// served entirely from last-good decisions.
    pub clock_degraded: bool,
}

/// What one [`DaemonHandle::pump`] call did.
#[derive(Clone, Debug, Default)]
pub struct PumpReport {
    /// Wheel entries fired by this pump.
    pub timer_fires: u64,
    /// Leases expired by this pump.
    pub lease_expiries: u64,
    /// Retire-TTL expiries applied by this pump.
    pub retire_expiries: u64,
    /// Epochs planned by this pump, in firing order.
    pub epochs: Vec<EpochOutcome>,
}

/// The drained final state [`DaemonHandle::shutdown`] hands back.
/// (No `Debug`: `FleetSpec` holds per-tier cost graphs.)
#[derive(Clone)]
pub struct DrainReport {
    /// Coalesced deltas flushed into the service during drain.
    pub flushed_deltas: u64,
    /// Coalesced reports flushed into the service during drain.
    pub flushed_reports: u64,
    /// Last-good decisions per active device at shutdown (no solves are
    /// run to produce these — the in-flight epoch is served from cache).
    pub final_decisions: Vec<PlanDecision>,
    /// The fleet spec after the final flush.
    pub spec: FleetSpec,
    /// The planner's final counters.
    pub stats: FleetStats,
    /// The final metrics scrape (service + daemon families).
    pub metrics: String,
    /// The daemon's final counters.
    pub counters: DaemonCounters,
}

/// Requests the worker thread understands.
// `Event` carries a `SpecDelta` (whose `AddTier` holds a `CostGraph`)
// inline: boxing it would put an allocation on the per-event ingest hot
// path to slim down the rare control-plane variants.
#[allow(clippy::large_enum_variant)]
enum Msg {
    Event(DaemonEvent),
    Pump(Sender<PumpReport>),
    PlanNow(Sender<EpochOutcome>),
    Metrics(Sender<String>),
    Stats(Sender<FleetStats>),
    Counters(Sender<DaemonCounters>),
    Shutdown(Sender<DrainReport>, DrainOutcome),
}

/// A cloneable producer endpoint. Each send holds an activity guard for
/// exactly the enqueue, so [`DaemonHandle::shutdown`]'s idle wait proves
/// every started send is in the queue before the drain begins.
#[derive(Clone)]
pub struct EventSender {
    tx: SyncSender<Msg>,
    tracker: ActivityTracker,
    shed: Arc<AtomicU64>,
}

impl EventSender {
    /// Enqueue one event without blocking: a full channel sheds the
    /// event with [`SendError::Backpressure`] (counted in
    /// `fastsplit_ingest_shed_total`); a shut-down daemon returns
    /// [`SendError::Closed`].
    pub fn send(&self, event: DaemonEvent) -> Result<(), SendError> {
        let _guard = self.tracker.activity();
        match self.tx.try_send(Msg::Event(event)) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(_)) => {
                self.shed.fetch_add(1, Ordering::Relaxed);
                Err(SendError::Backpressure)
            }
            Err(TrySendError::Disconnected(_)) => Err(SendError::Closed),
        }
    }
}

/// The planner daemon. [`PlannerDaemon::spawn`] starts the worker
/// thread; the returned [`DaemonHandle`] is the control plane.
pub struct PlannerDaemon;

impl PlannerDaemon {
    /// Spawn the daemon over a fresh service for `spec`. The first
    /// re-plan is scheduled `replan_every` ticks after the clock's
    /// current reading. With [`DaemonConfig::journal_dir`] set, the
    /// journal opens (snapshot first) before the worker thread starts —
    /// a journal I/O failure degrades to non-durable operation, counted
    /// in `fastsplit_journal_io_errors_total`, never a panic.
    pub fn spawn(spec: FleetSpec, config: DaemonConfig, clock: Arc<dyn Clock>) -> DaemonHandle {
        assert!(config.replan_every >= 1, "replan_every must be positive");
        assert!(config.ingest_capacity >= 1, "ingest_capacity must be positive");
        let (tx, rx) = mpsc::sync_channel(config.ingest_capacity);
        let tracker = ActivityTracker::new();
        let shed = Arc::new(AtomicU64::new(0));
        let start = clock.now();
        let mut wheel = TimerWheel::new(start, config.wheel_slots);
        let first = start + config.replan_every;
        wheel.insert(first, TimerItem::Replan { at: first });
        let coalescer = Coalescer::new(&spec);
        let fingerprint = spec.fingerprint();
        let mut worker = Worker {
            service: PlannerService::new(spec, config.service),
            coalescer,
            wheel,
            clock,
            config,
            counters: DaemonCounters::default(),
            lease_seq: Vec::new(),
            journal: None,
            journal_seq: 0,
            fingerprint,
            plans_since_snapshot: 0,
            planned_this_batch: false,
            journal_stats: JournalStats::default(),
            shed: Arc::clone(&shed),
            rx,
        };
        if worker.config.journal_dir.is_some() {
            worker.open_journal(0);
        }
        let thread = thread::Builder::new()
            .name("fastsplit-planner".into())
            .spawn(move || worker.run())
            .expect("spawn the planner daemon thread");
        DaemonHandle {
            tx,
            tracker,
            thread: Some(thread),
            shed,
        }
    }

    /// Recover a daemon from the newest recoverable journal in `dir`:
    /// restore the snapshot, replay the tail (events re-ingest through
    /// the coalescer under their journaled clock readings; wheel
    /// advances re-fire their timers), truncate any torn tail, and
    /// resume journaling in place. The clock is not consulted during
    /// replay — every replayed step uses the tick the journal recorded.
    pub fn recover(
        dir: impl AsRef<Path>,
        clock: Arc<dyn Clock>,
    ) -> Result<(DaemonHandle, RecoveryReport), JournalError> {
        Self::recover_inner(dir.as_ref(), None, clock)
    }

    /// [`PlannerDaemon::recover`], refusing journals whose fleet
    /// fingerprint differs from `fingerprint`
    /// ([`JournalError::ForeignModel`]) — replaying a different model's
    /// events would corrupt state silently.
    pub fn recover_expecting(
        dir: impl AsRef<Path>,
        fingerprint: u64,
        clock: Arc<dyn Clock>,
    ) -> Result<(DaemonHandle, RecoveryReport), JournalError> {
        Self::recover_inner(dir.as_ref(), Some(fingerprint), clock)
    }

    fn recover_inner(
        dir: &Path,
        expected: Option<u64>,
        clock: Arc<dyn Clock>,
    ) -> Result<(DaemonHandle, RecoveryReport), JournalError> {
        let RecoveredJournal {
            path,
            seq,
            fingerprint,
            snapshot,
            tail,
            torn_frames,
            valid_len,
            files_skipped,
        } = journal::read_journal(dir, expected)?;

        let snapshot_tick = snapshot.wheel_now;
        let options = snapshot.service.options;
        let config = DaemonConfig {
            replan_every: snapshot.replan_every,
            lease_ttl: snapshot.lease_ttl,
            wheel_slots: (snapshot.wheel_slots as usize).max(1),
            service: options,
            journal_dir: Some(dir.to_path_buf()),
            snapshot_every: snapshot.snapshot_every,
            ingest_capacity: (snapshot.ingest_capacity as usize).max(1),
        };
        // Re-inserting the entries in their sorted (deadline, seq) order
        // renumbers the seqs but preserves every firing tie-break.
        let mut wheel = TimerWheel::new(snapshot.wheel_now, config.wheel_slots);
        for &(deadline, item) in &snapshot.wheel_entries {
            wheel.insert(deadline, item);
        }
        let service = PlannerService::from_image(snapshot.service);
        let coalescer = Coalescer::new(service.spec());
        let (tx, rx) = mpsc::sync_channel(config.ingest_capacity);
        let tracker = ActivityTracker::new();
        let shed = Arc::new(AtomicU64::new(0));
        let dirty = !tail.iter().any(|f| matches!(f, Frame::Drain { .. }));
        let mut worker = Worker {
            service,
            coalescer,
            wheel,
            clock,
            config,
            counters: snapshot.counters,
            lease_seq: snapshot.lease_seq,
            // Journaling stays off during the replay: replayed steps are
            // already on disk.
            journal: None,
            journal_seq: seq,
            fingerprint,
            plans_since_snapshot: 0,
            planned_this_batch: false,
            journal_stats: JournalStats {
                torn: torn_frames,
                recoveries: 1,
                dirty_recoveries: u64::from(dirty),
                ..JournalStats::default()
            },
            shed: Arc::clone(&shed),
            rx,
        };

        let mut report = RecoveryReport {
            torn_frames,
            replayed_frames: 0,
            replayed_events: 0,
            snapshot_tick,
            shutdown: None,
            files_skipped,
        };
        for frame in tail {
            report.replayed_frames += 1;
            match frame {
                Frame::Event { now, event } => {
                    report.replayed_events += 1;
                    worker.ingest_at(now, event);
                }
                Frame::Advance { to } => worker.replay_advance(to),
                Frame::PlanNow { now } => {
                    let base = now.max(worker.wheel.now());
                    let _ = worker.plan_at(now, base);
                }
                Frame::Drain { now, outcome } => {
                    let base = worker.wheel.now().max(now);
                    worker.flush_into_service(base);
                    report.shutdown = Some(outcome);
                }
                // The parser refuses mid-file snapshots; nothing to do.
                Frame::Snapshot(_) => {}
            }
        }
        // Replayed plans must not trigger a rotation while the replay's
        // unflushed events still sit in the coalescer.
        worker.planned_this_batch = false;
        match JournalWriter::resume(&path, valid_len) {
            Ok(writer) => worker.journal = Some(writer),
            Err(_) => worker.journal_stats.io_errors += 1,
        }

        let thread = thread::Builder::new()
            .name("fastsplit-planner".into())
            .spawn(move || worker.run())
            .expect("spawn the planner daemon thread");
        Ok((
            DaemonHandle {
                tx,
                tracker,
                thread: Some(thread),
                shed,
            },
            report,
        ))
    }
}

/// Control plane of a running daemon. Dropping the handle shuts the
/// worker down (a best-effort drain); [`DaemonHandle::shutdown`] is the
/// graceful path that returns the drained state.
pub struct DaemonHandle {
    tx: SyncSender<Msg>,
    tracker: ActivityTracker,
    thread: Option<JoinHandle<()>>,
    shed: Arc<AtomicU64>,
}

impl DaemonHandle {
    /// A cloneable producer endpoint for event ingestion.
    pub fn sender(&self) -> EventSender {
        EventSender {
            tx: self.tx.clone(),
            tracker: self.tracker.clone(),
            shed: Arc::clone(&self.shed),
        }
    }

    /// Enqueue one event from the control plane.
    pub fn send(&self, event: DaemonEvent) -> Result<(), SendError> {
        self.sender().send(event)
    }

    /// Events shed at the bounded ingest channel so far.
    pub fn shed(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    fn request<T>(&self, wrap: impl FnOnce(Sender<T>) -> Msg) -> T {
        let (reply, rx) = mpsc::channel();
        self.tx.send(wrap(reply)).expect("the daemon is running");
        rx.recv().expect("the daemon replies")
    }

    /// Advance the timer wheel to the clock's current reading and run
    /// everything that fires — scheduled re-plans included.
    pub fn pump(&self) -> PumpReport {
        self.request(Msg::Pump)
    }

    /// Flush the coalesced backlog and plan one epoch at the clock's
    /// current reading, off the wheel's schedule. A non-monotone clock
    /// reading degrades the epoch (see [`EpochOutcome::clock_degraded`])
    /// instead of panicking.
    pub fn plan_now(&self) -> EpochOutcome {
        self.request(Msg::PlanNow)
    }

    /// Render the Prometheus scrape (service + daemon metric families).
    pub fn metrics(&self) -> String {
        self.request(Msg::Metrics)
    }

    /// The planner's counters.
    pub fn stats(&self) -> FleetStats {
        self.request(Msg::Stats)
    }

    /// The daemon's counters.
    pub fn counters(&self) -> DaemonCounters {
        self.request(Msg::Counters)
    }

    /// Graceful drain: wait for in-flight sends, stop intake, flush the
    /// coalesced backlog into the service (no planning), and hand back
    /// the final state. The worker thread is joined before returning;
    /// the journal's final frame records [`DrainOutcome::Clean`].
    pub fn shutdown(mut self) -> DrainReport {
        self.tracker.wait_idle();
        let report = self.request(|reply| Msg::Shutdown(reply, DrainOutcome::Clean));
        if let Some(thread) = self.thread.take() {
            thread.join().expect("the daemon thread exits cleanly");
        }
        report
    }

    /// Simulate a crash (the fault-injection hook): close the channel
    /// without any drain and join the worker. No drain frame reaches the
    /// journal, so a subsequent [`PlannerDaemon::recover`] reports
    /// `shutdown: None` — a dirty shutdown.
    pub fn abandon(mut self) {
        let thread = self.thread.take();
        drop(self);
        if let Some(thread) = thread {
            let _ = thread.join();
        }
    }
}

impl Drop for DaemonHandle {
    fn drop(&mut self) {
        if let Some(thread) = self.thread.take() {
            let (reply, _rx) = mpsc::channel();
            let _ = self
                .tx
                .send(Msg::Shutdown(reply, DrainOutcome::BestEffort));
            let _ = thread.join();
        }
    }
}

/// The single worker thread owning the service, the coalescer and the
/// wheel — no shared mutable state, every interaction is a message.
struct Worker {
    service: PlannerService,
    coalescer: Coalescer,
    wheel: TimerWheel<TimerItem>,
    clock: Arc<dyn Clock>,
    config: DaemonConfig,
    counters: DaemonCounters,
    /// Monotone per-device lease sequence; a lease entry only fires its
    /// expiry if its seq is still the device's newest (renewal-beats-
    /// expiry without wheel cancellation).
    lease_seq: Vec<u64>,
    /// The write-ahead journal, when durability is on. Every I/O error
    /// degrades this to `None` (counted) instead of crashing.
    journal: Option<JournalWriter>,
    /// Seq of the journal file currently appended to.
    journal_seq: u64,
    /// The fleet's shape fingerprint, stamped into journal headers.
    fingerprint: u64,
    /// Planned epochs since the last snapshot frame (rotation cadence).
    plans_since_snapshot: u64,
    /// True while the message batch being processed has planned (and
    /// therefore flushed) — the only moment a rotation snapshot cannot
    /// miss coalesced-but-unflushed events.
    planned_this_batch: bool,
    journal_stats: JournalStats,
    shed: Arc<AtomicU64>,
    rx: Receiver<Msg>,
}

impl Worker {
    fn run(mut self) {
        while let Ok(msg) = self.rx.recv() {
            match msg {
                Msg::Event(event) => self.ingest(event),
                Msg::Pump(reply) => {
                    let report = self.pump();
                    let _ = reply.send(report);
                    self.maybe_rotate();
                }
                Msg::PlanNow(reply) => {
                    let now = self.clock.now();
                    if self.journal.is_some() {
                        self.journal_frame(journal::plan_now_payload(now));
                    }
                    let outcome = self.plan_at(now, now.max(self.wheel.now()));
                    let _ = reply.send(outcome);
                    self.maybe_rotate();
                }
                Msg::Metrics(reply) => {
                    let _ = reply.send(self.render());
                }
                Msg::Stats(reply) => {
                    let _ = reply.send(self.service.stats());
                }
                Msg::Counters(reply) => {
                    let _ = reply.send(self.counters);
                }
                Msg::Shutdown(reply, outcome) => {
                    let report = self.drain(outcome);
                    let _ = reply.send(report);
                    return;
                }
            }
        }
        // The channel closed without a shutdown message: a simulated (or
        // real) crash. No drain, no drain frame — recovery will see a
        // dirty journal.
    }

    /// Append one frame; an I/O failure degrades journaling off
    /// (counted) rather than crashing the planner.
    fn journal_frame(&mut self, payload: Vec<u8>) {
        if let Some(writer) = self.journal.as_mut() {
            match writer.append(&payload) {
                Ok(n) => {
                    self.journal_stats.frames += 1;
                    self.journal_stats.bytes += n;
                }
                Err(_) => {
                    self.journal_stats.io_errors += 1;
                    self.journal = None;
                }
            }
        }
    }

    fn ingest(&mut self, event: DaemonEvent) {
        // One clock read per event: the journal must record exactly the
        // reading the lease arm uses, or replay would re-arm differently.
        let now = self.clock.now();
        if self.journal.is_some() {
            self.journal_frame(journal::event_payload(now, &event));
        }
        self.ingest_at(now, event);
    }

    /// The ingest body under an explicit clock reading — shared by live
    /// ingestion and journal replay.
    fn ingest_at(&mut self, now: u64, event: DaemonEvent) {
        self.counters.events_ingested += 1;
        let report_device = match &event {
            DaemonEvent::Report { device, .. } => Some(*device),
            DaemonEvent::Delta(_) => None,
        };
        match self.coalescer.push(event) {
            Ok(()) => match report_device {
                Some(device) => {
                    self.counters.reports_ingested += 1;
                    if let Some(ttl) = self.config.lease_ttl {
                        if self.lease_seq.len() <= device {
                            self.lease_seq.resize(device + 1, 0);
                        }
                        self.lease_seq[device] += 1;
                        let seq = self.lease_seq[device];
                        self.wheel.insert(now + ttl, TimerItem::Lease { device, seq });
                    }
                }
                None => self.counters.deltas_ingested += 1,
            },
            Err(_) => self.counters.rejected_events += 1,
        }
    }

    /// Advance the wheel to the clock and process fires until nothing
    /// more is due — a re-plan rescheduled at an already-past deadline
    /// (the clock jumped several periods) still runs within this pump.
    fn pump(&mut self) -> PumpReport {
        let mut report = PumpReport::default();
        loop {
            let now = self.clock.now().max(self.wheel.now());
            // Every advance is journaled, the final empty one included:
            // it moves the wheel clock, which later inserts hash against.
            if self.journal.is_some() {
                self.journal_frame(journal::advance_payload(now));
            }
            let fired = self.wheel.advance(now);
            if fired.is_empty() {
                break;
            }
            self.process_fired(now, fired, &mut report);
        }
        report
    }

    /// Re-run one journaled wheel advance during recovery replay.
    fn replay_advance(&mut self, to: u64) {
        let to = to.max(self.wheel.now());
        let fired = self.wheel.advance(to);
        if !fired.is_empty() {
            let mut report = PumpReport::default();
            self.process_fired(to, fired, &mut report);
        }
    }

    /// Process one batch of fired wheel entries at wheel time `now`.
    fn process_fired(&mut self, now: u64, fired: Vec<(u64, TimerItem)>, report: &mut PumpReport) {
        for (_, item) in fired {
            self.counters.timer_fires += 1;
            report.timer_fires += 1;
            match item {
                TimerItem::Replan { at } => {
                    // Clamp a late fire forward to the service clock
                    // so a jumped schedule cannot look non-monotone.
                    let tick = at.max(self.service.now());
                    let outcome = self.plan_at(tick, now);
                    self.counters.replan_ticks += 1;
                    report.epochs.push(outcome);
                    let next = at + self.config.replan_every;
                    self.wheel.insert(next, TimerItem::Replan { at: next });
                }
                TimerItem::Lease { device, seq } => {
                    let renewed = self.lease_seq.get(device).copied().unwrap_or(0) != seq;
                    let active = self.service.spec().tier_of_opt(device).is_some();
                    if !renewed && active {
                        self.service.expire_report(device);
                        self.counters.lease_expiries += 1;
                        report.lease_expiries += 1;
                    }
                }
                TimerItem::RetireExpiry { tier } => {
                    self.service.expire_retired(tier);
                    self.counters.retire_expiries += 1;
                    report.retire_expiries += 1;
                }
            }
        }
    }

    /// Flush the coalesced backlog into the service, scheduling the
    /// retire-TTL expiry for every retirement that goes through. `base`
    /// is the wall tick retirements age from — always derived from
    /// journaled readings so replay arms the same deadlines.
    fn flush_into_service(&mut self, base: u64) -> (u64, u64) {
        let items = self.coalescer.flush();
        let (mut deltas, mut reports) = (0u64, 0u64);
        for item in items {
            match item {
                CoalescedItem::Delta(delta) => {
                    if let SpecDelta::RetireTier { tier } = &delta {
                        let ttl = self.service.options().joint.fleet.retire_ttl;
                        self.wheel
                            .insert(base + ttl, TimerItem::RetireExpiry { tier: *tier });
                    }
                    self.service.apply_delta(&delta);
                    deltas += 1;
                }
                CoalescedItem::Report { device, link, tick } => {
                    // The coalescer already refused malformed reports, but
                    // the refusal policy must hold even for links that
                    // bypass it — route through the typed entry point (the
                    // service counts any refusal) instead of the panicking
                    // wrapper.
                    let _ = self.service.try_report(device, link, tick);
                    reports += 1;
                }
            }
        }
        self.counters.coalesced_deltas += deltas;
        self.counters.coalesced_reports += reports;
        (deltas, reports)
    }

    /// Flush (retirements aging from `base`), then plan one epoch at
    /// `tick`. A rejected (non-monotone) tick serves the whole epoch
    /// from last-good decisions marked `Degraded(StaleLink)` — the
    /// daemon never panics on a bad clock.
    fn plan_at(&mut self, tick: u64, base: u64) -> EpochOutcome {
        self.flush_into_service(base);
        self.plans_since_snapshot += 1;
        self.planned_this_batch = true;
        match self.service.plan_epoch(tick) {
            Ok(decisions) => EpochOutcome {
                tick,
                decisions,
                clock_degraded: false,
            },
            Err(_) => {
                self.counters.clock_errors += 1;
                let decisions = self.last_good_decisions(true);
                EpochOutcome {
                    tick,
                    decisions,
                    clock_degraded: true,
                }
            }
        }
    }

    /// Last-good decisions for every active device, slot order.
    /// `degrade` re-marks them `Degraded(StaleLink)`; either way
    /// `refreshed` is false (nothing was solved to produce these).
    fn last_good_decisions(&self, degrade: bool) -> Vec<PlanDecision> {
        let spec = self.service.spec();
        let mut out = Vec::new();
        for d in 0..spec.num_devices() {
            if spec.tier_of_opt(d).is_none() {
                continue;
            }
            if let Some(decision) = self.service.last_good(d) {
                let mut decision = decision.clone();
                decision.stats.refreshed = false;
                if degrade {
                    decision.provenance = DecisionProvenance::Degraded(DegradedReason::StaleLink);
                }
                out.push(decision);
            }
        }
        out
    }

    /// The full worker state as a snapshot — only meaningful at a
    /// coalescer-empty point (every caller rotates right after a plan's
    /// flush, or before any event arrived).
    fn take_snapshot(&self) -> DaemonSnapshot {
        DaemonSnapshot {
            replan_every: self.config.replan_every,
            lease_ttl: self.config.lease_ttl,
            wheel_slots: self.config.wheel_slots as u64,
            snapshot_every: self.config.snapshot_every,
            ingest_capacity: self.config.ingest_capacity as u64,
            service: self.service.export_image(),
            counters: self.counters,
            lease_seq: self.lease_seq.clone(),
            wheel_now: self.wheel.now(),
            wheel_entries: self.wheel.entries(),
        }
    }

    /// Open (or rotate onto) journal file `seq`: snapshot first, then
    /// prune older rotations. Failure degrades journaling off.
    fn open_journal(&mut self, seq: u64) {
        let Some(dir) = self.config.journal_dir.clone() else {
            return;
        };
        let snapshot = self.take_snapshot();
        match JournalWriter::create(&dir, seq, self.fingerprint, &snapshot) {
            Ok((writer, bytes)) => {
                self.journal = Some(writer);
                self.journal_seq = seq;
                self.journal_stats.frames += 1;
                self.journal_stats.bytes += bytes;
                self.journal_stats.snapshots += 1;
                self.plans_since_snapshot = 0;
                journal::prune_below(&dir, seq);
            }
            Err(_) => {
                self.journal_stats.io_errors += 1;
                self.journal = None;
            }
        }
    }

    /// Rotate after a batch that planned, once enough epochs accumulated
    /// since the last snapshot. The planned-in-this-batch gate is the
    /// safety argument: a plan flushes the coalescer and no event can
    /// arrive mid-batch (the worker processes one message at a time), so
    /// the rotation snapshot never strands coalesced-but-unflushed
    /// events in a pruned file.
    fn maybe_rotate(&mut self) {
        let planned = std::mem::take(&mut self.planned_this_batch);
        if planned
            && self.journal.is_some()
            && self.plans_since_snapshot >= self.config.snapshot_every
        {
            self.open_journal(self.journal_seq + 1);
        }
    }

    /// The journal + backpressure counter family. Rendered on every
    /// scrape — zeros when durability is off — so dashboards need no
    /// conditional families.
    fn journal_metrics(&self) -> Vec<Metric> {
        let counter = |name, help, value| Metric {
            name,
            help,
            kind: MetricKind::Counter,
            value,
        };
        vec![
            counter(
                "fastsplit_ingest_shed_total",
                "Events shed at the bounded ingest channel",
                self.shed.load(Ordering::Relaxed),
            ),
            counter(
                "fastsplit_journal_frames_total",
                "Frames appended to the write-ahead journal",
                self.journal_stats.frames,
            ),
            counter(
                "fastsplit_journal_bytes_total",
                "Bytes appended to the write-ahead journal",
                self.journal_stats.bytes,
            ),
            counter(
                "fastsplit_journal_snapshots_total",
                "Snapshot frames written (creations + rotations)",
                self.journal_stats.snapshots,
            ),
            counter(
                "fastsplit_journal_torn_frames_total",
                "Torn journal tails truncated at recovery",
                self.journal_stats.torn,
            ),
            counter(
                "fastsplit_journal_recoveries_total",
                "Times this daemon state was recovered from a journal",
                self.journal_stats.recoveries,
            ),
            counter(
                "fastsplit_journal_dirty_recoveries_total",
                "Recoveries from a journal without a drain frame",
                self.journal_stats.dirty_recoveries,
            ),
            counter(
                "fastsplit_journal_io_errors_total",
                "Journal I/O failures (journaling degraded off)",
                self.journal_stats.io_errors,
            ),
        ]
    }

    fn render(&self) -> String {
        let mut all = service_metrics(&self.service);
        all.extend(self.counters.metrics());
        all.extend(self.journal_metrics());
        render_prometheus(&all)
    }

    /// The drain: ingest whatever is already in the channel (shutdown
    /// waited for in-flight sends first, so this is everything), record
    /// the drain frame, flush into the service *without planning*, and
    /// snapshot the final state. No solver work happens past this point.
    fn drain(&mut self, outcome: DrainOutcome) -> DrainReport {
        while let Ok(msg) = self.rx.try_recv() {
            if let Msg::Event(event) = msg {
                self.ingest(event);
            }
            // Other requests at drain time are dropped; their reply
            // channels hang up and the caller sees the shutdown.
        }
        let now = self.clock.now();
        if self.journal.is_some() {
            self.journal_frame(journal::drain_payload(now, outcome));
        }
        let (flushed_deltas, flushed_reports) = self.flush_into_service(self.wheel.now().max(now));
        DrainReport {
            flushed_deltas,
            flushed_reports,
            final_decisions: self.last_good_decisions(false),
            spec: self.service.spec().clone(),
            stats: self.service.stats(),
            metrics: self.render(),
            counters: self.counters,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::partition::fleet::FleetOptions;
    use crate::partition::joint::JointOptions;
    use crate::partition::types::Link;
    use crate::profiles::{CostGraph, DeviceProfile, TrainCfg};
    use crate::util::prop::churn_script;
    use crate::util::rng::Rng;

    const REPLAY_MODELS: [&str; 3] = ["googlenet", "block-residual", "block-inception"];

    fn spec_for(model: &str, devices: usize) -> FleetSpec {
        let m = models::by_name(model).unwrap();
        FleetSpec::from_fleet(&DeviceProfile::fleet_of(devices), |d| {
            CostGraph::build(&m, d, &DeviceProfile::rtx_a6000(), &TrainCfg::default())
        })
    }

    fn assert_decisions_bit_identical(a: &[PlanDecision], b: &[PlanDecision], context: &str) {
        assert_eq!(a.len(), b.len(), "{context}: decision counts differ");
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.device, y.device, "{context}");
            assert_eq!(x.tier, y.tier, "{context}");
            assert_eq!(x.cut_layer, y.cut_layer, "{context}");
            assert_eq!(x.partition.device_set, y.partition.device_set, "{context}");
            assert_eq!(
                x.partition.delay.to_bits(),
                y.partition.delay.to_bits(),
                "{context}"
            );
        }
    }

    /// The headline pin (acceptance criterion): seeded churn streams fed
    /// through the daemon — coalesced between ticks, planned on the
    /// wheel's schedule — produce epochs bit-identical to a raw
    /// uncoalesced `PlannerService` replay, while `spec_deltas` stays
    /// measurably below the raw event count. An add+remove cancel pair
    /// is injected every tick so coalescing provably fires on every
    /// model and seed.
    #[test]
    fn daemon_coalesced_replay_is_bit_identical_to_the_raw_service() {
        let base = crate::util::rng::test_seed();
        const EVERY: u64 = 3;
        const TICKS: usize = 12;
        for (i, model) in REPLAY_MODELS.iter().enumerate() {
            let mut rng = Rng::new(base ^ (0xDAE0 + ((i as u64 + 1) << 40)));
            let spec = spec_for(model, 6);
            let script = churn_script(&mut rng, spec.num_tiers(), 6, TICKS, 0.35, 0.3);
            let options = ServiceOptions {
                joint: JointOptions {
                    fleet: FleetOptions::bit_identical(),
                    ..JointOptions::default()
                },
                ..ServiceOptions::default()
            };
            let clock = SimClock::new(0);
            let daemon = PlannerDaemon::spawn(
                spec.clone(),
                DaemonConfig {
                    replan_every: EVERY,
                    lease_ttl: None,
                    service: options,
                    ..DaemonConfig::default()
                },
                Arc::new(clock.clone()),
            );
            let sender = daemon.sender();
            let mut reference = PlannerService::new(spec, options);
            let mut raw_events = 0u64;
            let mut daemon_epochs: Vec<EpochOutcome> = Vec::new();
            let mut reference_epochs: Vec<(u64, Vec<PlanDecision>)> = Vec::new();
            for (tick, step) in script.ticks.iter().enumerate() {
                let tick = tick as u64;
                clock.set(tick);
                // A cancel pair on an unused slot: coalescing erases it,
                // the raw stream pays two deltas for it.
                for delta in [
                    SpecDelta::AddDevice { device: 6, tier: 0 },
                    SpecDelta::RemoveDevice { device: 6 },
                ] {
                    assert!(sender.send(DaemonEvent::Delta(delta.clone())).is_ok());
                    reference.apply_delta(&delta);
                    raw_events += 1;
                }
                for ev in &step.events {
                    let delta = ev.to_delta();
                    assert!(sender.send(DaemonEvent::Delta(delta.clone())).is_ok());
                    reference.apply_delta(&delta);
                    raw_events += 1;
                }
                for &(d, link) in &step.reports {
                    assert!(sender
                        .send(DaemonEvent::Report {
                            device: d,
                            link,
                            tick,
                        })
                        .is_ok());
                    reference.report(d, link, tick);
                }
                let pump = daemon.pump();
                daemon_epochs.extend(pump.epochs);
                if tick > 0 && tick % EVERY == 0 {
                    reference_epochs.push((tick, reference.plan_epoch(tick).unwrap()));
                }
            }
            // The final scheduled epoch after the script.
            let final_tick = TICKS as u64;
            clock.set(final_tick);
            let pump = daemon.pump();
            daemon_epochs.extend(pump.epochs);
            reference_epochs.push((final_tick, reference.plan_epoch(final_tick).unwrap()));

            assert_eq!(
                daemon_epochs.len(),
                reference_epochs.len(),
                "{model}: epoch schedules diverged"
            );
            for (got, (tick, want)) in daemon_epochs.iter().zip(&reference_epochs) {
                assert_eq!(got.tick, *tick, "{model}: epoch ticks diverged");
                assert!(!got.clock_degraded, "{model}: spurious clock degradation");
                assert_decisions_bit_identical(
                    &got.decisions,
                    want,
                    &format!("{model} epoch {tick}"),
                );
            }
            let daemon_stats = daemon.stats();
            assert!(
                daemon_stats.spec_deltas < raw_events,
                "{model}: coalescing must measurably fire \
                 ({} applied vs {raw_events} raw)",
                daemon_stats.spec_deltas,
            );
            assert_eq!(
                daemon_stats.spec_deltas,
                daemon.counters().coalesced_deltas,
                "{model}: daemon and planner delta accounting agree"
            );
            daemon.shutdown();
        }
    }

    /// The drain contract: shutdown stops intake, flushes every queued
    /// event into the service without planning (no post-shutdown
    /// solves), and serves the in-flight epoch from last-good decisions.
    #[test]
    fn daemon_drain_loses_no_events_and_runs_no_solves() {
        let clock = SimClock::new(0);
        let daemon = PlannerDaemon::spawn(
            spec_for("googlenet", 4),
            DaemonConfig {
                replan_every: 10,
                ..DaemonConfig::default()
            },
            Arc::new(clock.clone()),
        );
        let link = Link::symmetric(5e5);
        for d in 0..4 {
            assert!(daemon
                .send(DaemonEvent::Report {
                    device: d,
                    link,
                    tick: 0,
                })
                .is_ok());
        }
        let epoch = daemon.plan_now();
        assert_eq!(epoch.decisions.len(), 4);
        assert!(!epoch.clock_degraded);
        let solves_before = daemon.stats().solves();

        // Queue churn + a report + a cancel pair; none of it is planned
        // (the next scheduled re-plan is far away), all of it must land.
        let sender = daemon.sender();
        clock.set(1);
        for delta in [
            SpecDelta::RemoveDevice { device: 1 },
            SpecDelta::MigrateDevice { device: 2, tier: 0 },
            SpecDelta::AddDevice { device: 9, tier: 0 },
            SpecDelta::RemoveDevice { device: 9 },
        ] {
            assert!(sender.send(DaemonEvent::Delta(delta)).is_ok());
        }
        assert!(sender
            .send(DaemonEvent::Report {
                device: 0,
                link: Link::symmetric(6e5),
                tick: 1,
            })
            .is_ok());

        let report = daemon.shutdown();
        assert_eq!(
            report.stats.solves(),
            solves_before,
            "drain must not run solves"
        );
        assert_eq!(report.flushed_deltas, 2, "cancel pair coalesced away");
        assert_eq!(report.flushed_reports, 1, "the queued report landed");
        assert_eq!(report.spec.tier_of_opt(1), None, "removal flushed");
        assert_eq!(report.spec.tier_of_opt(2), Some(0), "migration flushed");
        let served: Vec<usize> = report.final_decisions.iter().map(|d| d.device).collect();
        assert!(served.contains(&0) && served.contains(&3));
        assert!(!served.contains(&1), "departed device serves nothing");
        assert!(
            !served.contains(&2),
            "a migrated device's last-good belonged to the old tier"
        );
        assert!(report
            .metrics
            .contains("fastsplit_daemon_events_ingested_total 9\n"));
        assert!(report.metrics.contains("fastsplit_spec_deltas_total 2\n"));
        assert_eq!(report.counters.coalesced_deltas, 2);

        // Intake is closed: a pre-obtained sender sees the shutdown.
        assert_eq!(
            sender.send(DaemonEvent::Delta(SpecDelta::RemoveDevice { device: 0 })),
            Err(SendError::Closed)
        );
    }

    /// Lease-vs-staleness precedence: with an infinite staleness bound,
    /// an unrenewed report lease alone degrades the device — and a
    /// renewed lease never fires.
    #[test]
    fn daemon_lease_expiry_degrades_before_the_staleness_bound() {
        let clock = SimClock::new(0);
        let daemon = PlannerDaemon::spawn(
            spec_for("googlenet", 4),
            DaemonConfig {
                replan_every: 1,
                lease_ttl: Some(2),
                ..DaemonConfig::default()
            },
            Arc::new(clock.clone()),
        );
        let link = Link::symmetric(5e5);
        for d in 0..4 {
            assert!(daemon
                .send(DaemonEvent::Report {
                    device: d,
                    link,
                    tick: 0,
                })
                .is_ok());
        }
        let mut degraded_by_tick: Vec<(u64, Vec<usize>)> = Vec::new();
        for tick in 1..=4u64 {
            clock.set(tick);
            // Every device reports every tick except device 2, silent
            // through ticks 1-2 and back at tick 3.
            for d in 0..4 {
                if d == 2 && (tick == 1 || tick == 2) {
                    continue;
                }
                assert!(daemon
                    .send(DaemonEvent::Report {
                        device: d,
                        link,
                        tick,
                    })
                    .is_ok());
            }
            let pump = daemon.pump();
            for epoch in pump.epochs {
                let degraded: Vec<usize> = epoch
                    .decisions
                    .iter()
                    .filter(|d| matches!(d.provenance, DecisionProvenance::Degraded(_)))
                    .map(|d| d.device)
                    .collect();
                degraded_by_tick.push((epoch.tick, degraded));
            }
        }
        assert_eq!(
            degraded_by_tick,
            vec![
                (1, vec![]),
                (2, vec![2]), // the lease (ttl 2, last report at 0) fired
                (3, vec![]),  // the tick-3 report cleared the flag
                (4, vec![]),
            ],
            "lease expiry must degrade exactly device 2 at exactly tick 2"
        );
        let counters = daemon.counters();
        assert_eq!(counters.lease_expiries, 1, "renewed leases never fire");
        daemon.shutdown();
    }

    /// A non-monotone clock read degrades the epoch (every active device
    /// served last-good, marked stale) and recovers on the next sane
    /// read — the daemon never panics on a producer's bad clock.
    #[test]
    fn daemon_clock_regression_degrades_and_recovers() {
        let clock = SimClock::new(5);
        let daemon = PlannerDaemon::spawn(
            spec_for("googlenet", 4),
            DaemonConfig {
                replan_every: 100,
                ..DaemonConfig::default()
            },
            Arc::new(clock.clone()),
        );
        let link = Link::symmetric(5e5);
        for d in 0..4 {
            assert!(daemon
                .send(DaemonEvent::Report {
                    device: d,
                    link,
                    tick: 5,
                })
                .is_ok());
        }
        let fresh = daemon.plan_now();
        assert!(!fresh.clock_degraded);
        assert_eq!(fresh.decisions.len(), 4);

        clock.set(3); // the clock runs backwards
        let degraded = daemon.plan_now();
        assert!(degraded.clock_degraded);
        assert_eq!(degraded.decisions.len(), 4);
        assert!(degraded.decisions.iter().all(|d| matches!(
            d.provenance,
            DecisionProvenance::Degraded(DegradedReason::StaleLink)
        )));
        assert_eq!(daemon.counters().clock_errors, 1);

        clock.set(6);
        for d in 0..4 {
            assert!(daemon
                .send(DaemonEvent::Report {
                    device: d,
                    link: Link::symmetric(6e5),
                    tick: 6,
                })
                .is_ok());
        }
        let recovered = daemon.plan_now();
        assert!(!recovered.clock_degraded);
        assert!(recovered
            .decisions
            .iter()
            .all(|d| !matches!(d.provenance, DecisionProvenance::Degraded(_))));
        daemon.shutdown();
    }

    /// Retire-TTL expiries ride the wheel: a retirement schedules its
    /// expiry at `retirement + retire_ttl` wall ticks, and pumping past
    /// that deadline applies it exactly once.
    #[test]
    fn daemon_retire_ttl_expiry_fires_on_the_wheel() {
        let clock = SimClock::new(0);
        let daemon = PlannerDaemon::spawn(
            spec_for("block-residual", 4),
            DaemonConfig {
                replan_every: 1000,
                ..DaemonConfig::default()
            },
            Arc::new(clock.clone()),
        );
        let link = Link::symmetric(5e5);
        for d in 0..4 {
            assert!(daemon
                .send(DaemonEvent::Report {
                    device: d,
                    link,
                    tick: 0,
                })
                .is_ok());
        }
        assert_eq!(daemon.plan_now().decisions.len(), 4);
        assert!(daemon
            .send(DaemonEvent::Delta(SpecDelta::RetireTier { tier: 3 }))
            .is_ok());
        let flushed = daemon.plan_now();
        assert_eq!(flushed.decisions.len(), 3, "tier 3's device detached");

        // The default retire TTL is 64 wall ticks from the flush.
        clock.set(63);
        assert_eq!(daemon.pump().retire_expiries, 0, "one tick early");
        clock.set(64);
        let pump = daemon.pump();
        assert_eq!(pump.retire_expiries, 1, "the expiry fires on time");
        assert_eq!(daemon.counters().retire_expiries, 1);
        daemon.shutdown();
    }

    /// The bounded ingest channel sheds instead of blocking: a full
    /// queue returns `SendError::Backpressure` (counted), a closed one
    /// `SendError::Closed` (not counted as a shed).
    #[test]
    fn ingest_backpressure_sheds_typed_and_counts() {
        let (tx, rx) = mpsc::sync_channel(1);
        let shed = Arc::new(AtomicU64::new(0));
        let sender = EventSender {
            tx,
            tracker: ActivityTracker::new(),
            shed: Arc::clone(&shed),
        };
        let event = || DaemonEvent::Delta(SpecDelta::RemoveDevice { device: 0 });
        assert_eq!(sender.send(event()), Ok(()));
        assert_eq!(sender.send(event()), Err(SendError::Backpressure));
        assert_eq!(sender.send(event()), Err(SendError::Backpressure));
        assert_eq!(shed.load(Ordering::Relaxed), 2, "every shed is counted");
        drop(rx);
        assert_eq!(sender.send(event()), Err(SendError::Closed));
        assert_eq!(
            shed.load(Ordering::Relaxed),
            2,
            "a closed channel is not a shed"
        );
    }

    /// The journal + shed families render (as zeros) even with
    /// durability off, so dashboards need no conditional scrape.
    #[test]
    fn journal_and_shed_metrics_render_zero_when_durability_is_off() {
        let daemon = PlannerDaemon::spawn(
            spec_for("googlenet", 2),
            DaemonConfig::default(),
            Arc::new(SimClock::new(0)),
        );
        let scrape = daemon.metrics();
        assert!(scrape.contains("fastsplit_ingest_shed_total 0\n"));
        assert!(scrape.contains("fastsplit_journal_frames_total 0\n"));
        assert!(scrape.contains("fastsplit_journal_recoveries_total 0\n"));
        assert!(scrape.contains("fastsplit_journal_io_errors_total 0\n"));
        assert_eq!(daemon.shed(), 0);
        daemon.shutdown();
    }
}
