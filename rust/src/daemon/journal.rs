//! The daemon's write-ahead event journal (PR 9).
//!
//! Durability is strictly opt-in: with `DaemonConfig::journal_dir` set,
//! the worker records every accepted *and* rejected [`DaemonEvent`],
//! every timer-wheel advance, every explicit plan request and the final
//! drain as length-prefixed, CRC-32-checksummed frames — each written
//! *before* the coalescer or the wheel applies it. Every journal file
//! opens with a version-and-fingerprint header and a full
//! [`DaemonSnapshot`] frame, so recovery is always `snapshot + tail
//! replay` and never needs out-of-band configuration.
//!
//! File format (`wal-{seq}.log`, all integers little-endian):
//!
//! ```text
//! header : magic u32 ("FSJL") | version u32 | fleet fingerprint u64 | seq u64
//! frame  : payload len u32 | crc32(payload) u32 | payload
//! payload: kind u8 (0 snapshot, 1 event, 2 advance, 3 plan-now, 4 drain) | body
//! ```
//!
//! Recovery policy, pinned by the tests below and documented in
//! RESILIENCE.md ("Durability contracts"):
//!
//! * **Torn tails truncate.** The first bad frame (short, oversized,
//!   CRC mismatch, undecodable, or a mid-file snapshot) ends the replay;
//!   it is counted, the file is truncated back to the last good frame,
//!   and the daemon resumes appending there. Never a panic.
//! * **Foreign journals refuse typed.** A cross-version header or (under
//!   [`super::PlannerDaemon::recover_expecting`]) a fleet fingerprint
//!   mismatch is a typed [`JournalError`], not a fallback — replaying a
//!   different model's events would corrupt state silently.
//! * **Older files are fallbacks for corruption only.** A newest file
//!   with an unreadable header or snapshot frame falls back to the next
//!   rotation; typed version/fingerprint refusals do not.
//!
//! Durability bound: frames are `write_all` + `flush`ed (OS page cache),
//! not fsynced — the fault model is process crash, not power loss.

use std::fs::{self, File, OpenOptions};
use std::io::{Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use super::ingest::DaemonEvent;
use super::snapshot::{self, crc32, DaemonSnapshot, Dec, DecodeError, Enc};
use super::DrainOutcome;

/// `b"FSJL"` as a little-endian u32: the journal file magic.
pub(crate) const MAGIC: u32 = 0x4C4A_5346;
/// Journal format version; recovery refuses any other.
pub(crate) const VERSION: u32 = 1;
/// Header length: magic + version + fingerprint + seq.
pub(crate) const HEADER_LEN: usize = 24;
/// Upper bound on a single frame's payload — a corrupt length field can
/// never drive a huge allocation past this.
const MAX_FRAME_LEN: usize = 16 << 20;

/// Why a journal directory could not be recovered from. Every refusal is
/// typed; recovery never panics on foreign or corrupt input.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum JournalError {
    /// The directory holds no `wal-*.log` files (or does not exist).
    NoJournal,
    /// The filesystem failed underneath the reader.
    Io(String),
    /// The newest candidate file does not start with the journal magic.
    BadMagic(u32),
    /// The journal was written by a different format version.
    Version {
        /// The version the header carries.
        found: u32,
    },
    /// The journal belongs to a different model fleet (fingerprint
    /// mismatch under [`super::PlannerDaemon::recover_expecting`]).
    ForeignModel {
        /// The fingerprint the caller expected.
        expected: u64,
        /// The fingerprint the journal header carries.
        found: u64,
    },
    /// No candidate file yields a decodable snapshot frame.
    CorruptSnapshot,
}

impl std::fmt::Display for JournalError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JournalError::NoJournal => write!(f, "no journal files in the directory"),
            JournalError::Io(e) => write!(f, "journal I/O error: {e}"),
            JournalError::BadMagic(m) => {
                write!(f, "not a fastsplit journal (magic 0x{m:08X})")
            }
            JournalError::Version { found } => {
                write!(f, "journal format version {found} is not {VERSION}")
            }
            JournalError::ForeignModel { expected, found } => write!(
                f,
                "journal belongs to a different model fleet \
                 (fingerprint 0x{found:016X}, expected 0x{expected:016X})"
            ),
            JournalError::CorruptSnapshot => {
                write!(f, "no usable snapshot frame in any journal file")
            }
        }
    }
}

impl std::error::Error for JournalError {}

/// What a recovery did, alongside the recovered `DaemonHandle`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RecoveryReport {
    /// Torn-tail truncations (0 when the file ended on a frame boundary).
    pub torn_frames: u64,
    /// Tail frames replayed after the snapshot.
    pub replayed_frames: u64,
    /// Journaled events re-ingested during the replay.
    pub replayed_events: u64,
    /// The timer-wheel tick the snapshot was taken at.
    pub snapshot_tick: u64,
    /// How the journaled run ended: `Some(Clean)` after a graceful
    /// [`super::DaemonHandle::shutdown`], `Some(BestEffort)` after a
    /// dropped handle, `None` when the journal just stops — a crash.
    pub shutdown: Option<DrainOutcome>,
    /// Newer journal files skipped for corruption before one recovered.
    pub files_skipped: u64,
}

/// One decoded journal frame.
pub(crate) enum Frame {
    /// A full worker snapshot — always and only a file's first frame.
    Snapshot(DaemonSnapshot),
    /// One ingested event and the clock reading it was ingested at (the
    /// reading also arms the report lease, so replay must reuse it).
    Event { now: u64, event: DaemonEvent },
    /// One timer-wheel advance of a pump iteration (including the final
    /// empty advance — it moves the wheel clock, which later inserts
    /// hash against).
    Advance { to: u64 },
    /// An explicit off-schedule plan request at clock reading `now`.
    PlanNow { now: u64 },
    /// The final drain: clock reading and how the run ended.
    Drain { now: u64, outcome: DrainOutcome },
}

impl Frame {
    pub(crate) fn decode(bytes: &[u8]) -> Result<Frame, DecodeError> {
        let mut d = Dec::new(bytes);
        let frame = match d.u8()? {
            0 => {
                // The snapshot codec consumes (and end-checks) the rest.
                return Ok(Frame::Snapshot(DaemonSnapshot::decode(&bytes[1..])?));
            }
            1 => Frame::Event {
                now: d.u64()?,
                event: snapshot::dec_event(&mut d)?,
            },
            2 => Frame::Advance { to: d.u64()? },
            3 => Frame::PlanNow { now: d.u64()? },
            4 => Frame::Drain {
                now: d.u64()?,
                outcome: match d.u8()? {
                    0 => DrainOutcome::Clean,
                    1 => DrainOutcome::BestEffort,
                    _ => return Err(DecodeError("bad DrainOutcome tag")),
                },
            },
            _ => return Err(DecodeError("bad frame kind tag")),
        };
        d.done()?;
        Ok(frame)
    }
}

pub(crate) fn snapshot_payload(s: &DaemonSnapshot) -> Vec<u8> {
    let mut e = Enc::new();
    e.u8(0);
    e.buf.extend_from_slice(&s.encode());
    e.buf
}

pub(crate) fn event_payload(now: u64, event: &DaemonEvent) -> Vec<u8> {
    let mut e = Enc::new();
    e.u8(1);
    e.u64(now);
    snapshot::enc_event(&mut e, event);
    e.buf
}

pub(crate) fn advance_payload(to: u64) -> Vec<u8> {
    let mut e = Enc::new();
    e.u8(2);
    e.u64(to);
    e.buf
}

pub(crate) fn plan_now_payload(now: u64) -> Vec<u8> {
    let mut e = Enc::new();
    e.u8(3);
    e.u64(now);
    e.buf
}

pub(crate) fn drain_payload(now: u64, outcome: DrainOutcome) -> Vec<u8> {
    let mut e = Enc::new();
    e.u8(4);
    e.u64(now);
    e.u8(match outcome {
        DrainOutcome::Clean => 0,
        DrainOutcome::BestEffort => 1,
    });
    e.buf
}

fn header_bytes(fingerprint: u64, seq: u64) -> [u8; HEADER_LEN] {
    let mut h = [0u8; HEADER_LEN];
    h[0..4].copy_from_slice(&MAGIC.to_le_bytes());
    h[4..8].copy_from_slice(&VERSION.to_le_bytes());
    h[8..16].copy_from_slice(&fingerprint.to_le_bytes());
    h[16..24].copy_from_slice(&seq.to_le_bytes());
    h
}

/// The append side of one journal file. Frames hit the OS on every
/// append (`write_all` + `flush`); the caller owns the byte/frame
/// accounting and the degrade-on-error policy.
pub(crate) struct JournalWriter {
    file: File,
}

impl JournalWriter {
    /// Create `wal-{seq}.log` atomically (written as `.tmp`, renamed once
    /// the header and snapshot frame are down) and return the writer plus
    /// the bytes written. A file that exists always starts with a
    /// complete snapshot.
    pub(crate) fn create(
        dir: &Path,
        seq: u64,
        fingerprint: u64,
        snapshot: &DaemonSnapshot,
    ) -> std::io::Result<(JournalWriter, u64)> {
        fs::create_dir_all(dir)?;
        let tmp = dir.join(format!("wal-{seq}.log.tmp"));
        let path = dir.join(format!("wal-{seq}.log"));
        let mut file = File::create(&tmp)?;
        file.write_all(&header_bytes(fingerprint, seq))?;
        let mut writer = JournalWriter { file };
        let frame_bytes = writer.append(&snapshot_payload(snapshot))?;
        fs::rename(&tmp, &path)?;
        Ok((writer, HEADER_LEN as u64 + frame_bytes))
    }

    /// Re-open a recovered file for appending: truncate the torn tail
    /// back to `valid_len` and seek to the new end.
    pub(crate) fn resume(path: &Path, valid_len: u64) -> std::io::Result<JournalWriter> {
        let mut file = OpenOptions::new().read(true).write(true).open(path)?;
        file.set_len(valid_len)?;
        file.seek(SeekFrom::End(0))?;
        Ok(JournalWriter { file })
    }

    /// Append one CRC-framed record; returns the bytes written.
    pub(crate) fn append(&mut self, payload: &[u8]) -> std::io::Result<u64> {
        let mut record = Vec::with_capacity(payload.len() + 8);
        record.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        record.extend_from_slice(&crc32(payload).to_le_bytes());
        record.extend_from_slice(payload);
        self.file.write_all(&record)?;
        self.file.flush()?;
        Ok(record.len() as u64)
    }
}

/// One successfully read journal file, ready to replay.
pub(crate) struct RecoveredJournal {
    pub(crate) path: PathBuf,
    pub(crate) seq: u64,
    pub(crate) fingerprint: u64,
    pub(crate) snapshot: DaemonSnapshot,
    /// Frames after the snapshot, in journal order.
    pub(crate) tail: Vec<Frame>,
    pub(crate) torn_frames: u64,
    /// Byte offset of the last good frame's end — the truncation point.
    pub(crate) valid_len: u64,
    pub(crate) files_skipped: u64,
}

/// Every `wal-{seq}.log` in `dir`, newest seq first. A missing directory
/// is an empty listing (the caller maps that to [`JournalError::NoJournal`]).
fn list_wal_files(dir: &Path) -> Result<Vec<(u64, PathBuf)>, JournalError> {
    let entries = match fs::read_dir(dir) {
        Ok(entries) => entries,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
        Err(e) => return Err(JournalError::Io(e.to_string())),
    };
    let mut out = Vec::new();
    for entry in entries {
        let entry = entry.map_err(|e| JournalError::Io(e.to_string()))?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let seq = name
            .strip_prefix("wal-")
            .and_then(|rest| rest.strip_suffix(".log"))
            .and_then(|s| s.parse::<u64>().ok());
        if let Some(seq) = seq {
            out.push((seq, entry.path()));
        }
    }
    out.sort_by(|a, b| b.0.cmp(&a.0));
    Ok(out)
}

/// Delete every journal file older than `keep_seq` (rotation cleanup;
/// best-effort, a leftover file is skipped at the next recovery anyway).
pub(crate) fn prune_below(dir: &Path, keep_seq: u64) {
    if let Ok(files) = list_wal_files(dir) {
        for (seq, path) in files {
            if seq < keep_seq {
                let _ = fs::remove_file(path);
            }
        }
    }
}

/// Walk the frames of one file. Returns the decoded frames, the byte
/// offset the walk stopped at (the truncation point for a torn tail) and
/// the torn count (1 when trailing bytes had to be dropped, else 0).
fn parse_frames(bytes: &[u8]) -> (Vec<Frame>, u64, u64) {
    let mut pos = HEADER_LEN.min(bytes.len());
    let mut frames: Vec<Frame> = Vec::new();
    let mut torn = 0u64;
    loop {
        let remaining = bytes.len() - pos;
        if remaining == 0 {
            break;
        }
        if remaining < 8 {
            torn = 1;
            break;
        }
        let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(bytes[pos + 4..pos + 8].try_into().unwrap());
        if len > MAX_FRAME_LEN || len > remaining - 8 {
            torn = 1;
            break;
        }
        let payload = &bytes[pos + 8..pos + 8 + len];
        if crc32(payload) != crc {
            torn = 1;
            break;
        }
        match Frame::decode(payload) {
            // A snapshot is only legal as a file's first frame; a
            // mid-file one means torn rotation state — truncate there.
            Ok(Frame::Snapshot(s)) if !frames.is_empty() => {
                drop(s);
                torn = 1;
                break;
            }
            Ok(frame) => {
                frames.push(frame);
                pos += 8 + len;
            }
            Err(_) => {
                torn = 1;
                break;
            }
        }
    }
    (frames, pos as u64, torn)
}

fn read_one(path: &Path, seq: u64, expected: Option<u64>) -> Result<RecoveredJournal, JournalError> {
    let bytes = fs::read(path).map_err(|e| JournalError::Io(e.to_string()))?;
    if bytes.len() < 8 {
        return Err(JournalError::BadMagic(0));
    }
    let magic = u32::from_le_bytes(bytes[0..4].try_into().unwrap());
    if magic != MAGIC {
        return Err(JournalError::BadMagic(magic));
    }
    let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
    if version != VERSION {
        return Err(JournalError::Version { found: version });
    }
    if bytes.len() < HEADER_LEN {
        return Err(JournalError::CorruptSnapshot);
    }
    let fingerprint = u64::from_le_bytes(bytes[8..16].try_into().unwrap());
    if let Some(expected) = expected {
        if expected != fingerprint {
            return Err(JournalError::ForeignModel {
                expected,
                found: fingerprint,
            });
        }
    }
    let (frames, valid_len, torn_frames) = parse_frames(&bytes);
    let mut frames = frames.into_iter();
    let snapshot = match frames.next() {
        Some(Frame::Snapshot(s)) => s,
        _ => return Err(JournalError::CorruptSnapshot),
    };
    Ok(RecoveredJournal {
        path: path.to_path_buf(),
        seq,
        fingerprint,
        snapshot,
        tail: frames.collect(),
        torn_frames,
        valid_len,
        files_skipped: 0,
    })
}

/// Read the newest recoverable journal in `dir`. Corrupt newer files
/// fall back to older rotations (counted in `files_skipped`); typed
/// version/fingerprint refusals abort the whole recovery instead.
pub(crate) fn read_journal(
    dir: &Path,
    expected: Option<u64>,
) -> Result<RecoveredJournal, JournalError> {
    let candidates = list_wal_files(dir)?;
    if candidates.is_empty() {
        return Err(JournalError::NoJournal);
    }
    let mut first_error: Option<JournalError> = None;
    let mut skipped = 0u64;
    for (seq, path) in candidates {
        match read_one(&path, seq, expected) {
            Ok(mut recovered) => {
                recovered.files_skipped = skipped;
                return Ok(recovered);
            }
            Err(e @ (JournalError::Version { .. } | JournalError::ForeignModel { .. })) => {
                return Err(e)
            }
            Err(e) => {
                if first_error.is_none() {
                    first_error = Some(e);
                }
                skipped += 1;
            }
        }
    }
    Err(first_error.unwrap_or(JournalError::NoJournal))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::daemon::{
        DaemonConfig, DaemonHandle, DrainReport, EpochOutcome, PlannerDaemon, SimClock,
    };
    use crate::models;
    use crate::partition::fleet::{FleetOptions, FleetSpec, PlanDecision, SpecDelta};
    use crate::partition::joint::JointOptions;
    use crate::partition::service::ServiceOptions;
    use crate::partition::types::Link;
    use crate::profiles::{CostGraph, DeviceProfile, TrainCfg};
    use crate::util::prop::{churn_script, ChurnTick, CrashScript};
    use crate::util::rng::Rng;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Arc;

    fn temp_dir(tag: &str) -> PathBuf {
        static COUNTER: AtomicU64 = AtomicU64::new(0);
        let n = COUNTER.fetch_add(1, Ordering::SeqCst);
        let dir = std::env::temp_dir().join(format!(
            "fastsplit-journal-{tag}-{}-{n}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).expect("create the test journal dir");
        dir
    }

    fn spec_for(model: &str, devices: usize) -> FleetSpec {
        let m = models::by_name(model).unwrap();
        FleetSpec::from_fleet(&DeviceProfile::fleet_of(devices), |d| {
            CostGraph::build(&m, d, &DeviceProfile::rtx_a6000(), &TrainCfg::default())
        })
    }

    /// The crash-harness daemon config: bit-identical planning, leases on
    /// the wheel, and a snapshot cadence too large to rotate — every
    /// crash run stays in `wal-0.log` so truncation points are the whole
    /// story.
    fn config_for(journal_dir: Option<PathBuf>) -> DaemonConfig {
        DaemonConfig {
            replan_every: 2,
            lease_ttl: Some(3),
            service: ServiceOptions {
                joint: JointOptions {
                    fleet: FleetOptions::bit_identical(),
                    ..JointOptions::default()
                },
                ..ServiceOptions::default()
            },
            journal_dir,
            snapshot_every: u64::MAX,
            ..DaemonConfig::default()
        }
    }

    /// One tick's events under the canonical order `CrashScript` counts
    /// in: churn deltas first, then reports.
    fn tick_events(step: &ChurnTick, tick: u64) -> Vec<DaemonEvent> {
        step.events
            .iter()
            .map(|ev| DaemonEvent::Delta(ev.to_delta()))
            .chain(step.reports.iter().map(|&(device, link)| DaemonEvent::Report {
                device,
                link,
                tick,
            }))
            .collect()
    }

    /// Drive `script` through a daemon from the position a crashed run
    /// stopped at (`consumed` = events already journaled; 0 = a fresh
    /// run). Ticks before the resume position are re-pumped without
    /// sending: the event count cannot say how far the crashed run's
    /// *pumping* got, and a pump over already-covered ground fires
    /// nothing (due entries fire exactly once).
    fn drive(
        daemon: &DaemonHandle,
        clock: &SimClock,
        script: &CrashScript,
        consumed: u64,
    ) -> Vec<EpochOutcome> {
        let (start_tick, skip_within) = script.resume_position(consumed);
        let mut epochs = Vec::new();
        for tick in 0..start_tick {
            clock.set(tick as u64);
            epochs.extend(daemon.pump().epochs);
        }
        for (tick, step) in script.script.ticks.iter().enumerate().skip(start_tick) {
            clock.set(tick as u64);
            let skip = if tick == start_tick { skip_within } else { 0 };
            for event in tick_events(step, tick as u64).into_iter().skip(skip) {
                daemon.send(event).expect("the daemon accepts the event");
            }
            epochs.extend(daemon.pump().epochs);
        }
        clock.set(script.script.ticks.len() as u64);
        epochs.extend(daemon.pump().epochs);
        epochs
    }

    fn assert_decisions_bit_identical(a: &[PlanDecision], b: &[PlanDecision], context: &str) {
        assert_eq!(a.len(), b.len(), "{context}: decision counts differ");
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.device, y.device, "{context}");
            assert_eq!(x.tier, y.tier, "{context}");
            assert_eq!(x.cut_layer, y.cut_layer, "{context}");
            assert_eq!(x.partition.device_set, y.partition.device_set, "{context}");
            assert_eq!(
                x.partition.delay.to_bits(),
                y.partition.delay.to_bits(),
                "{context}"
            );
        }
    }

    /// The scrape minus the journal/backpressure families — those count
    /// I/O the crashed run did twice (pre-crash + post-recovery), so the
    /// bit-identity pin covers everything else.
    fn stable_scrape(metrics: &str) -> String {
        metrics
            .lines()
            .filter(|line| {
                !line.contains("fastsplit_journal_") && !line.contains("fastsplit_ingest_shed")
            })
            .collect::<Vec<_>>()
            .join("\n")
    }

    fn assert_drains_bit_identical(a: &DrainReport, b: &DrainReport, context: &str) {
        assert_decisions_bit_identical(&a.final_decisions, &b.final_decisions, context);
        assert_eq!(a.stats, b.stats, "{context}: FleetStats diverged");
        assert_eq!(a.counters, b.counters, "{context}: daemon counters diverged");
        assert_eq!(
            stable_scrape(&a.metrics),
            stable_scrape(&b.metrics),
            "{context}: scrape diverged"
        );
    }

    /// Byte offsets where each frame of a well-formed journal ends —
    /// the crash points of the headline pin.
    fn frame_boundaries(bytes: &[u8]) -> Vec<usize> {
        let mut pos = HEADER_LEN;
        let mut out = Vec::new();
        while pos < bytes.len() {
            let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()) as usize;
            pos += 8 + len;
            assert!(pos <= bytes.len(), "the baseline journal must be whole");
            out.push(pos);
        }
        out
    }

    /// **The headline pin (acceptance criterion).** A seeded churn script
    /// runs once uninterrupted through a journaled daemon. Then, for
    /// *every* frame boundary of the journal it wrote, a fresh daemon is
    /// recovered from the journal truncated at that boundary — the state
    /// a crash at that instant leaves on disk — and the script is
    /// resumed. Every crash point must reproduce the uninterrupted run
    /// bit-identically: the remaining epochs' decisions, the final
    /// `FleetStats`, the daemon counters and the Prometheus scrape
    /// (modulo the journal's own I/O counters).
    #[test]
    fn crash_at_every_frame_boundary_recovers_bit_identically() {
        let mut rng = Rng::new(crate::util::rng::test_seed() ^ 0x0009_C0FF_EE00);
        let spec = spec_for("googlenet", 4);
        let script = CrashScript::new(churn_script(&mut rng, spec.num_tiers(), 4, 6, 0.35, 0.3));

        let base_dir = temp_dir("crash-base");
        let clock = SimClock::new(0);
        let daemon = PlannerDaemon::spawn(
            spec.clone(),
            config_for(Some(base_dir.clone())),
            Arc::new(clock.clone()),
        );
        let base_epochs = drive(&daemon, &clock, &script, 0);
        let base_report = daemon.shutdown();
        let bytes = fs::read(base_dir.join("wal-0.log")).expect("the journal exists");
        let boundaries = frame_boundaries(&bytes);
        assert!(
            boundaries.len() as u64 > script.total_events(),
            "every event must have its own frame"
        );

        for (k, &cut) in boundaries.iter().enumerate() {
            let dir = temp_dir(&format!("crash-{k}"));
            fs::write(dir.join("wal-0.log"), &bytes[..cut]).unwrap();
            let clock = SimClock::new(0);
            let (daemon, recovery) = PlannerDaemon::recover(&dir, Arc::new(clock.clone()))
                .unwrap_or_else(|e| panic!("crash point {k}: recovery refused: {e}"));
            assert_eq!(recovery.torn_frames, 0, "crash point {k}: clean boundary");
            let epochs = drive(&daemon, &clock, &script, recovery.replayed_events);
            assert!(
                epochs.len() <= base_epochs.len(),
                "crash point {k}: more epochs than the uninterrupted run"
            );
            let suffix = &base_epochs[base_epochs.len() - epochs.len()..];
            for (got, want) in epochs.iter().zip(suffix) {
                assert_eq!(got.tick, want.tick, "crash point {k}: epoch ticks diverged");
                assert_decisions_bit_identical(
                    &got.decisions,
                    &want.decisions,
                    &format!("crash point {k} epoch {}", want.tick),
                );
            }
            let report = daemon.shutdown();
            assert_drains_bit_identical(&report, &base_report, &format!("crash point {k}"));
            let _ = fs::remove_dir_all(&dir);
        }
        let _ = fs::remove_dir_all(&base_dir);
    }

    /// Durability is observation-free: the same script through a
    /// journal-on and a journal-off daemon yields bit-identical epochs,
    /// `FleetStats`, counters and scrape — the journal-off path is
    /// exactly the PR 8 daemon.
    #[test]
    fn journal_off_and_on_runs_are_bit_identical() {
        let mut rng = Rng::new(crate::util::rng::test_seed() ^ 0x0FF0);
        let spec = spec_for("block-residual", 4);
        let script = CrashScript::new(churn_script(&mut rng, spec.num_tiers(), 4, 6, 0.35, 0.3));
        let run = |journal_dir: Option<PathBuf>| {
            let clock = SimClock::new(0);
            let daemon = PlannerDaemon::spawn(
                spec.clone(),
                config_for(journal_dir),
                Arc::new(clock.clone()),
            );
            let epochs = drive(&daemon, &clock, &script, 0);
            (epochs, daemon.shutdown())
        };
        let dir = temp_dir("on-off");
        let (on_epochs, on_report) = run(Some(dir.clone()));
        let (off_epochs, off_report) = run(None);
        assert_eq!(on_epochs.len(), off_epochs.len(), "epoch schedules diverged");
        for (a, b) in on_epochs.iter().zip(&off_epochs) {
            assert_eq!(a.tick, b.tick);
            assert_decisions_bit_identical(&a.decisions, &b.decisions, "journal on/off");
        }
        assert_drains_bit_identical(&on_report, &off_report, "journal on/off");
        // The journal families render on both sides — zeros when off.
        assert!(off_report
            .metrics
            .contains("fastsplit_journal_frames_total 0\n"));
        assert!(on_report
            .metrics
            .contains("fastsplit_journal_snapshots_total 1\n"));
        let _ = fs::remove_dir_all(&dir);
    }

    /// The corruption fuzz lane: seeded bit flips and truncations of a
    /// valid journal either recover a strict prefix (functional daemon,
    /// recovery counted) or refuse with a typed error — never a panic.
    #[test]
    fn corrupt_journals_recover_a_prefix_or_refuse_typed_never_panic() {
        let mut rng = Rng::new(crate::util::rng::test_seed() ^ 0x0BAD_F00D);
        let spec = spec_for("googlenet", 4);
        let script = CrashScript::new(churn_script(&mut rng, spec.num_tiers(), 4, 4, 0.35, 0.3));
        let base_dir = temp_dir("fuzz-base");
        let clock = SimClock::new(0);
        let daemon = PlannerDaemon::spawn(
            spec.clone(),
            config_for(Some(base_dir.clone())),
            Arc::new(clock.clone()),
        );
        drive(&daemon, &clock, &script, 0);
        daemon.shutdown();
        let bytes = fs::read(base_dir.join("wal-0.log")).unwrap();
        let total_frames = frame_boundaries(&bytes).len() as u64;

        for trial in 0..96 {
            let mut mutated = bytes.clone();
            if rng.chance(0.5) {
                let at = rng.index(mutated.len());
                mutated[at] ^= 1 << rng.index(8);
            } else {
                let cut = rng.index(mutated.len() + 1);
                mutated.truncate(cut);
            }
            let dir = temp_dir(&format!("fuzz-{trial}"));
            fs::write(dir.join("wal-0.log"), &mutated).unwrap();
            match PlannerDaemon::recover(&dir, Arc::new(SimClock::new(0))) {
                Ok((daemon, recovery)) => {
                    assert!(
                        recovery.replayed_frames < total_frames,
                        "trial {trial}: replayed past the intact journal"
                    );
                    let scrape = daemon.metrics();
                    assert!(
                        scrape.contains("fastsplit_journal_recoveries_total 1\n"),
                        "trial {trial}: recovery must be counted"
                    );
                    daemon.shutdown();
                }
                Err(e) => {
                    // A typed refusal; rendering it must not panic either.
                    let _ = e.to_string();
                }
            }
            let _ = fs::remove_dir_all(&dir);
        }
        let _ = fs::remove_dir_all(&base_dir);
    }

    /// Foreign and cross-version journals refuse typed: wrong magic,
    /// wrong version, wrong fleet fingerprint, and the empty/missing
    /// directory each map to their own `JournalError` — and the matching
    /// fingerprint recovers.
    #[test]
    fn recovery_refuses_foreign_version_and_garbage_journals_typed() {
        let empty = temp_dir("refusal-empty");
        assert_eq!(
            PlannerDaemon::recover(&empty, Arc::new(SimClock::new(0))).err(),
            Some(JournalError::NoJournal).map(|e| e),
            "an empty directory has no journal"
        );
        assert!(matches!(
            PlannerDaemon::recover(empty.join("missing"), Arc::new(SimClock::new(0))).err(),
            Some(JournalError::NoJournal)
        ));
        fs::write(empty.join("wal-0.log"), b"not a journal at all").unwrap();
        assert!(matches!(
            PlannerDaemon::recover(&empty, Arc::new(SimClock::new(0))).err(),
            Some(JournalError::BadMagic(_))
        ));

        // A real googlenet journal.
        let dir = temp_dir("refusal-real");
        let spec = spec_for("googlenet", 3);
        {
            let clock = SimClock::new(0);
            let daemon = PlannerDaemon::spawn(
                spec.clone(),
                config_for(Some(dir.clone())),
                Arc::new(clock.clone()),
            );
            for d in 0..3 {
                daemon
                    .send(DaemonEvent::Report {
                        device: d,
                        link: Link::symmetric(5e5),
                        tick: 0,
                    })
                    .unwrap();
            }
            daemon.plan_now();
            daemon.shutdown();
        }

        // Cross-version: patch the header's version field to 2.
        let bytes = fs::read(dir.join("wal-0.log")).unwrap();
        let versioned = temp_dir("refusal-version");
        let mut patched = bytes.clone();
        patched[4..8].copy_from_slice(&2u32.to_le_bytes());
        fs::write(versioned.join("wal-0.log"), &patched).unwrap();
        assert_eq!(
            PlannerDaemon::recover(&versioned, Arc::new(SimClock::new(0))).err(),
            Some(JournalError::Version { found: 2 })
        );

        // Foreign model: expect a block-residual fleet over the
        // googlenet journal.
        let foreign = spec_for("block-residual", 3).fingerprint();
        let err = PlannerDaemon::recover_expecting(&dir, foreign, Arc::new(SimClock::new(0)))
            .err()
            .expect("a foreign journal must refuse");
        match err {
            JournalError::ForeignModel { expected, found } => {
                assert_eq!(expected, foreign);
                assert_eq!(found, spec.fingerprint());
            }
            e => panic!("wrong refusal: {e}"),
        }

        // The matching fingerprint recovers cleanly.
        let (daemon, recovery) =
            PlannerDaemon::recover_expecting(&dir, spec.fingerprint(), Arc::new(SimClock::new(1)))
                .expect("the matching fingerprint recovers");
        assert_eq!(recovery.shutdown, Some(crate::daemon::DrainOutcome::Clean));
        assert_eq!(recovery.files_skipped, 0);
        daemon.shutdown();
        let _ = fs::remove_dir_all(&empty);
        let _ = fs::remove_dir_all(&dir);
        let _ = fs::remove_dir_all(&versioned);
    }

    /// The drain-outcome satellite: recovery distinguishes a graceful
    /// shutdown (`Some(Clean)`), a dropped handle (`Some(BestEffort)`)
    /// and a crash (`None` — no drain frame), and counts dirty
    /// recoveries in the scrape.
    #[test]
    fn recovery_distinguishes_clean_best_effort_and_dirty_shutdowns() {
        use crate::daemon::DrainOutcome;
        let spec = spec_for("googlenet", 3);
        let cases: [(u8, Option<DrainOutcome>); 3] = [
            (0, Some(DrainOutcome::Clean)),
            (1, Some(DrainOutcome::BestEffort)),
            (2, None),
        ];
        for (exit, want) in cases {
            let dir = temp_dir(&format!("exit-{exit}"));
            {
                let clock = SimClock::new(0);
                let daemon = PlannerDaemon::spawn(
                    spec.clone(),
                    config_for(Some(dir.clone())),
                    Arc::new(clock.clone()),
                );
                for d in 0..3 {
                    daemon
                        .send(DaemonEvent::Report {
                            device: d,
                            link: Link::symmetric(5e5),
                            tick: 0,
                        })
                        .unwrap();
                }
                daemon.plan_now();
                if exit == 0 {
                    daemon.shutdown();
                } else if exit == 1 {
                    drop(daemon);
                } else {
                    // The simulated crash: close the channel without any
                    // drain — the journal just stops.
                    daemon.abandon();
                }
            }
            let (daemon, recovery) = PlannerDaemon::recover(&dir, Arc::new(SimClock::new(1)))
                .unwrap_or_else(|e| panic!("exit mode {exit}: {e}"));
            assert_eq!(recovery.shutdown, want, "exit mode {exit}");
            let scrape = daemon.metrics();
            let dirty = u64::from(want.is_none());
            assert!(
                scrape.contains(&format!("fastsplit_journal_dirty_recoveries_total {dirty}\n")),
                "exit mode {exit}: dirty accounting"
            );
            assert!(scrape.contains("fastsplit_journal_recoveries_total 1\n"));
            // The pre-crash state survived: all three devices still plan.
            assert_eq!(
                daemon.plan_now().decisions.len(),
                3,
                "exit mode {exit}: recovered state plans"
            );
            daemon.shutdown();
            let _ = fs::remove_dir_all(&dir);
        }
    }

    /// Rotation keeps recovery cheap: with a small `snapshot_every`, the
    /// journal rotates to a fresh snapshot file, old files are pruned,
    /// and recovery from the newest rotation still lands on the same
    /// state as the running daemon reported.
    #[test]
    fn snapshot_rotation_prunes_old_files_and_still_recovers() {
        let mut rng = Rng::new(crate::util::rng::test_seed() ^ 0x0707);
        let spec = spec_for("googlenet", 4);
        let script = CrashScript::new(churn_script(&mut rng, spec.num_tiers(), 4, 8, 0.35, 0.3));
        let dir = temp_dir("rotate");
        let clock = SimClock::new(0);
        let daemon = PlannerDaemon::spawn(
            spec.clone(),
            DaemonConfig {
                snapshot_every: 2,
                ..config_for(Some(dir.clone()))
            },
            Arc::new(clock.clone()),
        );
        drive(&daemon, &clock, &script, 0);
        let base_report = daemon.shutdown();
        let files = fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok()?.file_name().into_string().ok())
            .filter(|n| n.starts_with("wal-") && n.ends_with(".log"))
            .collect::<Vec<_>>();
        assert_eq!(files.len(), 1, "rotation prunes old files: {files:?}");
        assert_ne!(files[0], "wal-0.log", "the journal must have rotated");

        let (daemon, recovery) = PlannerDaemon::recover(&dir, Arc::new(SimClock::new(
            script.script.ticks.len() as u64,
        )))
        .expect("the rotated journal recovers");
        assert_eq!(recovery.shutdown, Some(crate::daemon::DrainOutcome::Clean));
        let report = daemon.shutdown();
        assert_drains_bit_identical(&report, &base_report, "rotated recovery");
        let _ = fs::remove_dir_all(&dir);
    }
}
