//! # fastsplit
//!
//! Production-grade reproduction of *"Fast AI Model Partition for Split
//! Learning over Edge Networks"* (Li, Wu, Wu, Shen, 2025).
//!
//! The crate implements the paper's full system as a three-layer stack:
//!
//! * **L3 (this crate)** — the coordination contribution: representing an
//!   arbitrary AI model as a DAG with delay-encoding edge weights
//!   ([`partition`]), solving the optimal split-learning cut as a minimum
//!   s-t cut via maximum flow ([`maxflow`]), the low-complexity block-wise
//!   variant ([`partition::blockwise`]), an edge-network simulator
//!   ([`net`]), the SL training-delay simulator ([`sim`]), a long-lived
//!   planner daemon with coalescing ingest, timer-wheel scheduling,
//!   graceful drain and a Prometheus scrape ([`daemon`]), and a leader
//!   coordinator that re-partitions per epoch and drives real split
//!   training through PJRT ([`coordinator`], [`runtime`]).
//! * **L2 (python/compile/model.py)** — a split-trainable JAX model lowered
//!   once to HLO text artifacts per cut point.
//! * **L1 (python/compile/kernels/)** — Pallas matmul kernel used by L2.
//!
//! See `DESIGN.md` for the experiment index mapping every paper figure and
//! table to a harness in [`experiments`].

pub mod util;
pub mod graph;
pub mod maxflow;
pub mod models;
pub mod profiles;
pub mod partition;
pub mod daemon;
pub mod net;
pub mod sim;
pub mod runtime;
pub mod coordinator;
pub mod experiments;
