//! Generic directed-graph substrate used by the partitioning algorithms.
//!
//! [`Dag`] is an adjacency-list DAG with O(1) edge-weight access, topological
//! sorting, ancestor/descendant closures, and lower-set (order-ideal)
//! enumeration — the machinery the paper's Alg. 1-4 and the brute-force
//! baseline (problem (12)) are built on.

pub mod dag;
pub mod lower_sets;

pub use dag::{Dag, EdgeId, NodeId};
pub use lower_sets::{count_lower_sets, enumerate_lower_sets, enumerate_lower_sets_capped};
