//! Adjacency-list directed graph with weighted edges.

/// Vertex handle (index into the graph's vertex table).
pub type NodeId = usize;
/// Edge handle (index into the graph's edge table).
pub type EdgeId = usize;

/// A directed edge with an f64 weight (delay in seconds for partition DAGs).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Edge {
    pub from: NodeId,
    pub to: NodeId,
    pub weight: f64,
}

/// Directed graph stored as vertex-indexed out/in adjacency lists.
///
/// Invariants: vertices are labelled; parallel edges are allowed (the
/// partition builder merges them where the paper requires); weights are
/// finite unless explicitly `f64::INFINITY` (closure-enforcing edges).
#[derive(Clone, Debug, Default)]
pub struct Dag {
    labels: Vec<String>,
    edges: Vec<Edge>,
    out_adj: Vec<Vec<EdgeId>>,
    in_adj: Vec<Vec<EdgeId>>,
}

impl Dag {
    pub fn new() -> Dag {
        Dag::default()
    }

    /// Add a labelled vertex, returning its id.
    pub fn add_node<S: Into<String>>(&mut self, label: S) -> NodeId {
        let id = self.labels.len();
        self.labels.push(label.into());
        self.out_adj.push(Vec::new());
        self.in_adj.push(Vec::new());
        id
    }

    /// Add a directed edge, returning its id.
    pub fn add_edge(&mut self, from: NodeId, to: NodeId, weight: f64) -> EdgeId {
        assert!(from < self.len() && to < self.len(), "edge endpoints must exist");
        assert!(from != to, "self-loops are not allowed");
        let id = self.edges.len();
        self.edges.push(Edge { from, to, weight });
        self.out_adj[from].push(id);
        self.in_adj[to].push(id);
        id
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    pub fn label(&self, v: NodeId) -> &str {
        &self.labels[v]
    }

    pub fn edge(&self, e: EdgeId) -> Edge {
        self.edges[e]
    }

    pub fn edges(&self) -> &[Edge] {
        &self.edges
    }

    pub fn set_weight(&mut self, e: EdgeId, weight: f64) {
        self.edges[e].weight = weight;
    }

    /// Outgoing edge ids of `v`.
    pub fn out_edges(&self, v: NodeId) -> &[EdgeId] {
        &self.out_adj[v]
    }

    /// Incoming edge ids of `v`.
    pub fn in_edges(&self, v: NodeId) -> &[EdgeId] {
        &self.in_adj[v]
    }

    /// Child vertex ids of `v` (may contain duplicates if parallel edges).
    pub fn children(&self, v: NodeId) -> Vec<NodeId> {
        self.out_adj[v].iter().map(|&e| self.edges[e].to).collect()
    }

    /// Parent vertex ids of `v`.
    pub fn parents(&self, v: NodeId) -> Vec<NodeId> {
        self.in_adj[v].iter().map(|&e| self.edges[e].from).collect()
    }

    pub fn out_degree(&self, v: NodeId) -> usize {
        self.out_adj[v].len()
    }

    pub fn in_degree(&self, v: NodeId) -> usize {
        self.in_adj[v].len()
    }

    /// Kahn topological sort. Returns `None` if the graph has a cycle.
    pub fn topo_order(&self) -> Option<Vec<NodeId>> {
        let mut indeg: Vec<usize> = (0..self.len()).map(|v| self.in_degree(v)).collect();
        let mut queue: Vec<NodeId> = (0..self.len()).filter(|&v| indeg[v] == 0).collect();
        let mut order = Vec::with_capacity(self.len());
        let mut head = 0;
        while head < queue.len() {
            let v = queue[head];
            head += 1;
            order.push(v);
            for &e in &self.out_adj[v] {
                let to = self.edges[e].to;
                indeg[to] -= 1;
                if indeg[to] == 0 {
                    queue.push(to);
                }
            }
        }
        if order.len() == self.len() {
            Some(order)
        } else {
            None
        }
    }

    /// True if the directed graph has no cycle.
    pub fn is_acyclic(&self) -> bool {
        self.topo_order().is_some()
    }

    /// Vertices reachable from `start` (including it) following out-edges.
    pub fn descendants(&self, start: NodeId) -> Vec<bool> {
        self.reach(start, false)
    }

    /// Vertices that can reach `start` (including it) following in-edges.
    pub fn ancestors(&self, start: NodeId) -> Vec<bool> {
        self.reach(start, true)
    }

    fn reach(&self, start: NodeId, reverse: bool) -> Vec<bool> {
        let mut seen = vec![false; self.len()];
        let mut stack = vec![start];
        seen[start] = true;
        while let Some(v) = stack.pop() {
            let adj = if reverse { &self.in_adj[v] } else { &self.out_adj[v] };
            for &e in adj {
                let next = if reverse { self.edges[e].from } else { self.edges[e].to };
                if !seen[next] {
                    seen[next] = true;
                    stack.push(next);
                }
            }
        }
        seen
    }

    /// Graphviz DOT rendering (edge weights become labels).
    pub fn to_dot(&self) -> String {
        let mut s = String::from("digraph G {\n  rankdir=LR;\n");
        for (v, label) in self.labels.iter().enumerate() {
            s.push_str(&format!("  n{v} [label=\"{label}\"];\n"));
        }
        for e in &self.edges {
            s.push_str(&format!(
                "  n{} -> n{} [label=\"{:.3}\"];\n",
                e.from, e.to, e.weight
            ));
        }
        s.push_str("}\n");
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> Dag {
        // 0 -> 1 -> 3, 0 -> 2 -> 3
        let mut g = Dag::new();
        for i in 0..4 {
            g.add_node(format!("v{i}"));
        }
        g.add_edge(0, 1, 1.0);
        g.add_edge(0, 2, 2.0);
        g.add_edge(1, 3, 3.0);
        g.add_edge(2, 3, 4.0);
        g
    }

    #[test]
    fn adjacency_consistency() {
        let g = diamond();
        assert_eq!(g.len(), 4);
        assert_eq!(g.num_edges(), 4);
        assert_eq!(g.children(0), vec![1, 2]);
        assert_eq!(g.parents(3), vec![1, 2]);
        assert_eq!(g.out_degree(0), 2);
        assert_eq!(g.in_degree(3), 2);
        assert_eq!(g.edge(g.out_edges(0)[1]).weight, 2.0);
    }

    #[test]
    fn topo_order_valid() {
        let g = diamond();
        let order = g.topo_order().unwrap();
        let pos: Vec<usize> = {
            let mut p = vec![0; g.len()];
            for (i, &v) in order.iter().enumerate() {
                p[v] = i;
            }
            p
        };
        for e in g.edges() {
            assert!(pos[e.from] < pos[e.to]);
        }
    }

    #[test]
    fn cycle_detected() {
        let mut g = Dag::new();
        let a = g.add_node("a");
        let b = g.add_node("b");
        g.add_edge(a, b, 1.0);
        g.add_edge(b, a, 1.0);
        assert!(!g.is_acyclic());
        assert!(g.topo_order().is_none());
    }

    #[test]
    fn reachability() {
        let g = diamond();
        let d = g.descendants(1);
        assert_eq!(d, vec![false, true, false, true]);
        let a = g.ancestors(3);
        assert_eq!(a, vec![true, true, true, true]);
    }

    #[test]
    #[should_panic(expected = "self-loops")]
    fn rejects_self_loop() {
        let mut g = Dag::new();
        let a = g.add_node("a");
        g.add_edge(a, a, 1.0);
    }

    #[test]
    fn dot_export_mentions_all_edges() {
        let g = diamond();
        let dot = g.to_dot();
        assert_eq!(dot.matches("->").count(), 4);
    }
}
