//! Lower-set (order-ideal) enumeration over a DAG.
//!
//! A feasible split-learning cut assigns a *lower set* of the layer DAG to
//! the device (problem (12)'s precedence constraint: no device layer may
//! depend on a server layer). The brute-force baseline enumerates exactly
//! these sets, which is the paper's `O(2^|V| (|V|+|E|))` method.

use super::dag::{Dag, NodeId};

/// Enumerate all lower sets of `g`, invoking `f` with a membership mask for
/// each (the empty set and the full set included). Order of enumeration is
/// deterministic. Uses DFS over topological prefixes with pruning: a vertex
/// may be added only once all its parents are in the set.
pub fn enumerate_lower_sets<F: FnMut(&[bool])>(g: &Dag, mut f: F) {
    let order = g.topo_order().expect("lower sets require an acyclic graph");
    let n = g.len();
    let mut in_set = vec![false; n];
    // missing_parents[v] = number of parents of v not yet in the set.
    let mut missing: Vec<usize> = (0..n).map(|v| g.in_degree(v)).collect();

    // Recursive enumeration over the topological order: at position i we
    // decide membership for order[i]; including it requires missing == 0;
    // excluding it forbids including any of its descendants, which is
    // enforced lazily via the missing-parent counters (a descendant can't
    // reach missing==0 if an ancestor is excluded... except through other
    // parents — so we must also mark exclusion explicitly).
    fn rec<F: FnMut(&[bool])>(
        g: &Dag,
        order: &[NodeId],
        i: usize,
        in_set: &mut Vec<bool>,
        missing: &mut Vec<usize>,
        f: &mut F,
    ) {
        if i == order.len() {
            f(in_set);
            return;
        }
        let v = order[i];
        // Branch 1: exclude v. All descendants with v as a parent keep
        // missing > 0 through the counter (we never decrement).
        rec(g, order, i + 1, in_set, missing, f);
        // Branch 2: include v, if permitted.
        if missing[v] == 0 {
            in_set[v] = true;
            for &e in g.out_edges(v) {
                missing[g.edge(e).to] -= 1;
            }
            rec(g, order, i + 1, in_set, missing, f);
            for &e in g.out_edges(v) {
                missing[g.edge(e).to] += 1;
            }
            in_set[v] = false;
        }
    }

    rec(g, &order, 0, &mut in_set, &mut missing, &mut f);
}

/// Count lower sets without materializing them.
pub fn count_lower_sets(g: &Dag) -> u64 {
    let mut count = 0u64;
    enumerate_lower_sets(g, |_| count += 1);
    count
}

/// Materialize every lower set of `g`, but give up (returning `None`) as
/// soon as more than `cap` exist. [`count_lower_sets`] is O(#lower sets),
/// which is exponential on branchy DAGs — a caller that only wants the
/// sets *when they are few* (the multi-hop DP's exact path) must be able
/// to probe without paying the full enumeration on a model where the
/// count explodes. Enumeration order matches [`enumerate_lower_sets`].
pub fn enumerate_lower_sets_capped(g: &Dag, cap: usize) -> Option<Vec<Vec<bool>>> {
    let order = g.topo_order().expect("lower sets require an acyclic graph");
    let n = g.len();
    let mut in_set = vec![false; n];
    let mut missing: Vec<usize> = (0..n).map(|v| g.in_degree(v)).collect();
    let mut out: Vec<Vec<bool>> = Vec::new();

    // Same DFS as `enumerate_lower_sets`, with a boolean "keep going"
    // return threaded through so the recursion can abort the moment the
    // cap is exceeded instead of finishing an exponential walk.
    fn rec(
        g: &Dag,
        order: &[NodeId],
        i: usize,
        in_set: &mut Vec<bool>,
        missing: &mut Vec<usize>,
        cap: usize,
        out: &mut Vec<Vec<bool>>,
    ) -> bool {
        if i == order.len() {
            if out.len() >= cap {
                return false;
            }
            out.push(in_set.clone());
            return true;
        }
        let v = order[i];
        if !rec(g, order, i + 1, in_set, missing, cap, out) {
            return false;
        }
        let mut alive = true;
        if missing[v] == 0 {
            in_set[v] = true;
            for &e in g.out_edges(v) {
                missing[g.edge(e).to] -= 1;
            }
            alive = rec(g, order, i + 1, in_set, missing, cap, out);
            for &e in g.out_edges(v) {
                missing[g.edge(e).to] += 1;
            }
            in_set[v] = false;
        }
        alive
    }

    if rec(g, &order, 0, &mut in_set, &mut missing, cap, &mut out) {
        Some(out)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{for_all, random_layer_dag};

    fn chain(n: usize) -> Dag {
        let mut g = Dag::new();
        for i in 0..n {
            g.add_node(format!("v{i}"));
        }
        for i in 1..n {
            g.add_edge(i - 1, i, 1.0);
        }
        g
    }

    #[test]
    fn chain_has_n_plus_one_lower_sets() {
        // Lower sets of a chain are prefixes: n+1 of them.
        for n in 1..8 {
            assert_eq!(count_lower_sets(&chain(n)), (n + 1) as u64);
        }
    }

    #[test]
    fn antichain_has_all_subsets() {
        let mut g = Dag::new();
        for i in 0..5 {
            g.add_node(format!("v{i}"));
        }
        assert_eq!(count_lower_sets(&g), 32);
    }

    #[test]
    fn diamond_count() {
        // 0 -> {1,2} -> 3: lower sets are {}, {0}, {0,1}, {0,2}, {0,1,2},
        // {0,1,2,3} = 6.
        let mut g = Dag::new();
        for i in 0..4 {
            g.add_node(format!("v{i}"));
        }
        g.add_edge(0, 1, 1.0);
        g.add_edge(0, 2, 1.0);
        g.add_edge(1, 3, 1.0);
        g.add_edge(2, 3, 1.0);
        assert_eq!(count_lower_sets(&g), 6);
    }

    #[test]
    fn capped_enumeration_matches_the_uncapped_walk_or_refuses() {
        for_all("lower-set-cap", 24, |rng| {
            let n = 2 + rng.index(8);
            let edges = random_layer_dag(rng, n, 0.25);
            let mut g = Dag::new();
            for i in 0..n {
                g.add_node(format!("v{i}"));
            }
            for (u, v) in edges {
                g.add_edge(u, v, 1.0);
            }
            let count = count_lower_sets(&g) as usize;
            let mut full = Vec::new();
            enumerate_lower_sets(&g, |m| full.push(m.to_vec()));
            // Cap at or above the count: identical sets, identical order.
            assert_eq!(enumerate_lower_sets_capped(&g, count), Some(full));
            // Cap below the count: refused, never silently truncated.
            assert_eq!(enumerate_lower_sets_capped(&g, count - 1), None);
            assert_eq!(enumerate_lower_sets_capped(&g, 0), None);
        });
    }

    #[test]
    fn every_enumerated_set_is_a_lower_set() {
        for_all("lower-set-validity", 40, |rng| {
            let n = 2 + rng.index(9);
            let edges = random_layer_dag(rng, n, 0.25);
            let mut g = Dag::new();
            for i in 0..n {
                g.add_node(format!("v{i}"));
            }
            for (u, v) in edges {
                g.add_edge(u, v, 1.0);
            }
            let mut seen = std::collections::HashSet::new();
            enumerate_lower_sets(&g, |mask| {
                // Validity: every parent of a member is a member.
                for v in 0..n {
                    if mask[v] {
                        for p in g.parents(v) {
                            assert!(mask[p], "vertex {v} in set but parent {p} missing");
                        }
                    }
                }
                // Uniqueness.
                let key: Vec<bool> = mask.to_vec();
                assert!(seen.insert(key), "duplicate lower set");
            });
        });
    }

    #[test]
    fn enumeration_matches_naive_subset_filter() {
        for_all("lower-set-completeness", 24, |rng| {
            let n = 2 + rng.index(7); // keep 2^n small
            let edges = random_layer_dag(rng, n, 0.3);
            let mut g = Dag::new();
            for i in 0..n {
                g.add_node(format!("v{i}"));
            }
            for (u, v) in &edges {
                g.add_edge(*u, *v, 1.0);
            }
            // Naive: filter all 2^n subsets.
            let mut naive = 0u64;
            for mask in 0u32..(1 << n) {
                let ok = edges
                    .iter()
                    .all(|&(u, v)| (mask >> v) & 1 == 0 || (mask >> u) & 1 == 1);
                if ok {
                    naive += 1;
                }
            }
            assert_eq!(count_lower_sets(&g), naive);
        });
    }
}
