//! Device mobility: each device moves along a predefined trajectory at
//! 30 km/h within the base-station coverage area (Sec. VII-B.1).

use crate::util::rng::Rng;

/// A device trajectory: a closed ring path around the base station with a
/// per-device radius band and phase, traversed at constant speed.
#[derive(Clone, Debug)]
pub struct Trajectory {
    /// Mean distance from the base station (m).
    pub mean_radius_m: f64,
    /// Radial oscillation amplitude (m) — the ring is slightly elliptic.
    pub radial_amp_m: f64,
    /// Initial angular phase (rad).
    pub phase: f64,
    /// Angular velocity (rad/s), derived from 30 km/h along the ring.
    pub angular_vel: f64,
    /// Radial oscillation frequency multiplier.
    pub radial_freq: f64,
}

/// Speed of all devices: 30 km/h in m/s.
pub const SPEED_MPS: f64 = 30.0 * 1000.0 / 3600.0;

impl Trajectory {
    /// Sample a random trajectory inside the coverage annulus
    /// [min_radius, max_radius].
    pub fn sample(rng: &mut Rng, min_radius_m: f64, max_radius_m: f64) -> Trajectory {
        assert!(min_radius_m > 0.0 && max_radius_m > min_radius_m);
        let mean = rng.range(min_radius_m * 1.2, max_radius_m * 0.8);
        let amp = rng.range(0.05, 0.25) * mean;
        Trajectory {
            mean_radius_m: mean,
            radial_amp_m: amp,
            phase: rng.range(0.0, std::f64::consts::TAU),
            angular_vel: SPEED_MPS / mean,
            radial_freq: rng.range(1.5, 4.0),
        }
    }

    /// Distance to the base station at time `t` (seconds).
    pub fn distance_at(&self, t: f64) -> f64 {
        let theta = self.phase + self.angular_vel * t;
        (self.mean_radius_m + self.radial_amp_m * (self.radial_freq * theta).sin()).max(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distance_stays_in_band() {
        let mut rng = Rng::new(5);
        for _ in 0..50 {
            let tr = Trajectory::sample(&mut rng, 10.0, 200.0);
            for step in 0..500 {
                let d = tr.distance_at(step as f64 * 7.0);
                assert!(d >= tr.mean_radius_m - tr.radial_amp_m - 1e-9);
                assert!(d <= tr.mean_radius_m + tr.radial_amp_m + 1e-9);
                assert!(d >= 1.0);
            }
        }
    }

    #[test]
    fn movement_actually_changes_distance() {
        let mut rng = Rng::new(6);
        let tr = Trajectory::sample(&mut rng, 10.0, 200.0);
        let d0 = tr.distance_at(0.0);
        let moved = (0..100).any(|i| (tr.distance_at(i as f64 * 10.0) - d0).abs() > 1.0);
        assert!(moved, "device never moved");
    }

    #[test]
    fn speed_constant_is_30_kmh() {
        assert!((SPEED_MPS - 8.3333).abs() < 1e-3);
    }
}
