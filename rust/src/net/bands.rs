//! 3GPP band presets used by the paper: n1 (sub-6 GHz) and n257 (mmWave),
//! with the EIRP/beam parameters of Sec. VII-B.1.

/// Radio band parameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Band {
    pub name: &'static str,
    /// Carrier frequency in GHz.
    pub carrier_ghz: f64,
    /// Channel bandwidth in Hz.
    pub bandwidth_hz: f64,
    /// Server (base station) average EIRP in dBm.
    pub server_eirp_dbm: f64,
    /// Device (UE) transmit power in dBm (23 dBm is the 3GPP power class 3).
    pub device_tx_dbm: f64,
    /// Number of beams N in P = P_e - 10 log10 N.
    pub beams: u32,
    /// Path-loss exponent η in Eq. (24).
    pub path_loss_exp: f64,
    /// Receiver noise figure in dB.
    pub noise_figure_db: f64,
}

impl Band {
    /// n1 (2.1 GHz sub-6): 40 dBm EIRP, 16 beams, 20 MHz.
    pub fn n1() -> Band {
        Band {
            name: "n1",
            carrier_ghz: 2.1,
            bandwidth_hz: 20e6,
            server_eirp_dbm: 40.0,
            device_tx_dbm: 23.0,
            beams: 16,
            path_loss_exp: 3.0,
            noise_figure_db: 7.0,
        }
    }

    /// n257 (28 GHz mmWave): 50 dBm EIRP, 64 beams, 200 MHz.
    pub fn n257() -> Band {
        Band {
            name: "n257",
            carrier_ghz: 28.0,
            bandwidth_hz: 200e6,
            server_eirp_dbm: 50.0,
            device_tx_dbm: 23.0,
            beams: 64,
            path_loss_exp: 2.9,
            noise_figure_db: 7.0,
        }
    }

    pub fn by_name(name: &str) -> Option<Band> {
        match name {
            "n1" | "sub6" => Some(Band::n1()),
            "n257" | "mmwave" => Some(Band::n257()),
            _ => None,
        }
    }

    /// Per-beam transmit power (Sec. VII-B.1): P = P_e - 10 log10 N.
    pub fn server_beam_power_dbm(&self) -> f64 {
        self.server_eirp_dbm - 10.0 * (self.beams as f64).log10()
    }

    /// Thermal noise floor over the band: -174 dBm/Hz + 10 log10 BW + NF.
    pub fn noise_floor_dbm(&self) -> f64 {
        -174.0 + 10.0 * self.bandwidth_hz.log10() + self.noise_figure_db
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn beam_power_matches_formula() {
        let b = Band::n257();
        assert!((b.server_beam_power_dbm() - (50.0 - 10.0 * 64f64.log10())).abs() < 1e-12);
        let b1 = Band::n1();
        assert!((b1.server_beam_power_dbm() - (40.0 - 10.0 * 16f64.log10())).abs() < 1e-12);
    }

    #[test]
    fn noise_floor_reasonable() {
        // 20 MHz: about -94 dBm with 7 dB NF.
        let nf = Band::n1().noise_floor_dbm();
        assert!((-95.5..=-93.0).contains(&nf), "{nf}");
        // 200 MHz is 10 dB higher.
        let nf257 = Band::n257().noise_floor_dbm();
        assert!((nf257 - nf - 10.0).abs() < 1e-9);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(Band::by_name("mmwave").unwrap().name, "n257");
        assert_eq!(Band::by_name("sub6").unwrap().name, "n1");
        assert!(Band::by_name("n77").is_none());
    }
}
