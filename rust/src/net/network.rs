//! The edge network: one base station + a fleet of mobile devices, with
//! per-epoch link-state sampling and the paper's device-selection policy
//! (nearest device, excluded once selected within an epoch round).

use super::bands::Band;
use super::channel::{ChannelCondition, ChannelModel};
use super::mcs::bitrate_bps;
use super::mobility::Trajectory;
use crate::partition::Link;
use crate::util::rng::Rng;

/// Network scenario configuration.
#[derive(Clone, Debug)]
pub struct NetConfig {
    pub band: Band,
    pub condition: ChannelCondition,
    pub rayleigh: bool,
    pub num_devices: usize,
    /// Coverage annulus radii (m).
    pub min_radius_m: f64,
    pub max_radius_m: f64,
    pub seed: u64,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            band: Band::n257(),
            condition: ChannelCondition::Normal,
            rayleigh: false,
            num_devices: 20,
            min_radius_m: 10.0,
            max_radius_m: 150.0,
            seed: 7,
        }
    }
}

/// The net ↔ partition unit boundary: the radio stack (MCS tables, CQI
/// efficiencies) reports **bits per second**, while [`Link`] — and every
/// capacity of the partitioner's flow networks — is **bytes per second**
/// (the profiler reports activation/parameter sizes in bytes). All
/// conversions go through this one constant so the boundary stays in one
/// place; `LinkSample::to_link` is the only crossing.
pub const BITS_PER_BYTE: f64 = 8.0;

/// Floor applied when converting to the partitioner's byte rates: a dead
/// radio sample becomes 1 B/s instead of 0, because `Problem::new`
/// (correctly) rejects non-positive rates — a scheduler never transmits at
/// literally zero forever.
pub const MIN_LINK_BYTES_PER_SEC: f64 = 1.0;

/// Sampled link state of one device at one instant. Rates are **bits/s**
/// (radio convention); convert with [`LinkSample::to_link`] before handing
/// them to the partitioner.
#[derive(Clone, Copy, Debug)]
pub struct LinkSample {
    pub device: usize,
    pub distance_m: f64,
    pub uplink_bps: f64,
    pub downlink_bps: f64,
}

impl LinkSample {
    /// Convert to the partitioner's byte-rate link (bits → bytes, floored
    /// at [`MIN_LINK_BYTES_PER_SEC`]).
    pub fn to_link(self) -> Link {
        debug_assert!(
            self.uplink_bps >= 0.0 && self.downlink_bps >= 0.0,
            "radio rates are non-negative bits/s"
        );
        Link {
            up_bps: (self.uplink_bps / BITS_PER_BYTE).max(MIN_LINK_BYTES_PER_SEC),
            down_bps: (self.downlink_bps / BITS_PER_BYTE).max(MIN_LINK_BYTES_PER_SEC),
        }
    }
}

/// The simulated edge network.
pub struct EdgeNetwork {
    pub cfg: NetConfig,
    channel: ChannelModel,
    trajectories: Vec<Trajectory>,
    rng: Rng,
    /// Devices already selected in the current round (fairness, Sec. VII-B.1).
    selected_this_round: Vec<bool>,
}

impl EdgeNetwork {
    pub fn new(cfg: NetConfig) -> EdgeNetwork {
        let mut rng = Rng::new(cfg.seed);
        let channel = ChannelModel::new(cfg.band, cfg.condition).with_rayleigh(cfg.rayleigh);
        let trajectories = (0..cfg.num_devices)
            .map(|_| Trajectory::sample(&mut rng, cfg.min_radius_m, cfg.max_radius_m))
            .collect();
        EdgeNetwork {
            selected_this_round: vec![false; cfg.num_devices],
            cfg,
            channel,
            trajectories,
            rng,
        }
    }

    pub fn num_devices(&self) -> usize {
        self.trajectories.len()
    }

    /// Sample the link of a specific device at time `t`.
    ///
    /// An epoch's transfers span seconds, far beyond the fading coherence
    /// time, so the effective rate averages `FADE_AVG` independent channel
    /// draws (link adaptation / HARQ smooth deep fades out); a small floor
    /// models retransmission-limited worst-case throughput rather than a
    /// dead link (a scheduler never transmits at CQI 0 forever).
    pub fn sample_link(&mut self, device: usize, t: f64) -> LinkSample {
        const FADE_AVG: usize = 8;
        let d = self.trajectories[device].distance_at(t);
        let mut up = 0.0;
        let mut down = 0.0;
        for _ in 0..FADE_AVG {
            let ul_snr = self.channel.uplink_snr_db(d, &mut self.rng);
            let dl_snr = self.channel.downlink_snr_db(d, &mut self.rng);
            up += bitrate_bps(ul_snr, self.cfg.band.bandwidth_hz);
            down += bitrate_bps(dl_snr, self.cfg.band.bandwidth_hz);
        }
        let floor = self.rate_floor_bps();
        LinkSample {
            device,
            distance_m: d,
            uplink_bps: (up / FADE_AVG as f64).max(floor),
            downlink_bps: (down / FADE_AVG as f64).max(floor),
        }
    }

    /// Retransmission-limited throughput floor: 2% of the CQI-1 rate.
    fn rate_floor_bps(&self) -> f64 {
        0.02 * crate::net::mcs::CQI_EFFICIENCY[1] * self.cfg.band.bandwidth_hz * 0.75
    }

    /// Paper's selection policy: nearest not-yet-selected device; once all
    /// have been selected the round resets (round-robin fairness).
    pub fn select_device(&mut self, t: f64) -> usize {
        if self.selected_this_round.iter().all(|&s| s) {
            self.selected_this_round.fill(false);
        }
        let mut best = None;
        let mut best_d = f64::INFINITY;
        for (i, tr) in self.trajectories.iter().enumerate() {
            if self.selected_this_round[i] {
                continue;
            }
            let d = tr.distance_at(t);
            if d < best_d {
                best_d = d;
                best = Some(i);
            }
        }
        let chosen = best.expect("at least one device");
        self.selected_this_round[chosen] = true;
        chosen
    }

    /// Nominal link: rates averaged over many channel draws at the mean
    /// coverage distance — what a static (OSS) scheme would plan against.
    pub fn nominal_link(&mut self, samples: usize) -> Link {
        let d = (self.cfg.min_radius_m + self.cfg.max_radius_m) / 2.0;
        let mut up = 0.0;
        let mut down = 0.0;
        for _ in 0..samples {
            let ul = self.channel.uplink_snr_db(d, &mut self.rng);
            let dl = self.channel.downlink_snr_db(d, &mut self.rng);
            up += bitrate_bps(ul, self.cfg.band.bandwidth_hz);
            down += bitrate_bps(dl, self.cfg.band.bandwidth_hz);
        }
        LinkSample {
            device: usize::MAX,
            distance_m: d,
            uplink_bps: up / samples as f64,
            downlink_bps: down / samples as f64,
        }
        .to_link()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selection_is_fair_across_a_round() {
        let mut net = EdgeNetwork::new(NetConfig {
            num_devices: 5,
            ..NetConfig::default()
        });
        let mut seen = std::collections::HashSet::new();
        for e in 0..5 {
            seen.insert(net.select_device(e as f64 * 100.0));
        }
        assert_eq!(seen.len(), 5, "each device selected once per round");
        // Next round starts fresh.
        let again = net.select_device(600.0);
        assert!(again < 5);
    }

    #[test]
    fn links_are_positive_and_downlink_dominates_on_average() {
        let mut net = EdgeNetwork::new(NetConfig::default());
        let mut ul = 0.0;
        let mut dl = 0.0;
        for i in 0..200 {
            let s = net.sample_link(i % 20, i as f64 * 3.0);
            assert!(s.uplink_bps >= 0.0);
            assert!(s.downlink_bps >= 0.0);
            ul += s.uplink_bps;
            dl += s.downlink_bps;
        }
        assert!(dl > ul, "downlink should be faster on average");
    }

    #[test]
    fn determinism_per_seed() {
        let run = |seed| {
            let mut net = EdgeNetwork::new(NetConfig {
                seed,
                ..NetConfig::default()
            });
            (0..20)
                .map(|i| net.sample_link(i % 20, i as f64).uplink_bps)
                .collect::<Vec<_>>()
        };
        assert_eq!(run(1), run(1));
        assert_ne!(run(1), run(2));
    }

    #[test]
    fn sub6_vs_mmwave_rates() {
        // mmWave has 10x bandwidth; close-range rates should be higher.
        let rate = |band: Band| {
            let mut net = EdgeNetwork::new(NetConfig {
                band,
                max_radius_m: 60.0,
                ..NetConfig::default()
            });
            let mut total = 0.0;
            for i in 0..300 {
                total += net.sample_link(i % 20, i as f64 * 2.0).downlink_bps;
            }
            total / 300.0
        };
        assert!(rate(Band::n257()) > rate(Band::n1()));
    }

    #[test]
    fn to_link_converts_bits_to_bytes() {
        let s = LinkSample {
            device: 0,
            distance_m: 25.0,
            uplink_bps: 80e6,  // 80 Mb/s radio rate
            downlink_bps: 160e6,
        };
        let l = s.to_link();
        assert_eq!(l.up_bps, 10e6, "80 Mb/s == 10 MB/s");
        assert_eq!(l.down_bps, 20e6);
        // σ sanity through the same boundary: bytes/s in, s/byte out.
        assert!((l.sigma() - (1.0 / 10e6 + 1.0 / 20e6)).abs() < 1e-18);
        // A dead radio sample floors at 1 B/s so Problem::new's positive-
        // rate validation holds downstream.
        let dead = LinkSample {
            device: 0,
            distance_m: 1e4,
            uplink_bps: 0.0,
            downlink_bps: 0.0,
        };
        assert_eq!(dead.to_link().up_bps, MIN_LINK_BYTES_PER_SEC);
        assert_eq!(dead.to_link().down_bps, MIN_LINK_BYTES_PER_SEC);
    }

    #[test]
    fn nominal_link_is_stable() {
        let mut net = EdgeNetwork::new(NetConfig::default());
        let a = net.nominal_link(4000);
        let b = net.nominal_link(4000);
        assert!((a.up_bps - b.up_bps).abs() / a.up_bps < 0.1);
    }
}
