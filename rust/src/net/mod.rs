//! Edge wireless network simulator (Sec. VII-B.1).
//!
//! Implements the paper's own simulator components: 3GPP band presets
//! (n1 sub-6 GHz / n257 mmWave), the Eq. (24) large-scale path-loss model
//! with per-condition shadowing, Eq. (25) Rayleigh small-scale fading, the
//! EIRP/beam transmit-power model, an SNR→CQI→MCS spectral-efficiency
//! mapping (TS 38.214), and waypoint device mobility at 30 km/h.

pub mod bands;
pub mod channel;
pub mod mcs;
pub mod mobility;
pub mod network;

pub use bands::Band;
pub use channel::{ChannelCondition, ChannelModel};
pub use network::{EdgeNetwork, LinkSample, NetConfig, BITS_PER_BYTE, MIN_LINK_BYTES_PER_SEC};
