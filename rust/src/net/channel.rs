//! Channel model: Eq. (24) large-scale path loss with log-normal shadowing
//! and Eq. (25) Rayleigh small-scale fading.

use super::bands::Band;
use crate::util::rng::Rng;

/// The paper's three channel conditions (shadowing σ in dB).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChannelCondition {
    Good,
    Normal,
    Poor,
}

impl ChannelCondition {
    pub fn sigma_db(self) -> f64 {
        match self {
            ChannelCondition::Good => 2.0,
            ChannelCondition::Normal => 4.0,
            ChannelCondition::Poor => 6.0,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ChannelCondition::Good => "good",
            ChannelCondition::Normal => "normal",
            ChannelCondition::Poor => "poor",
        }
    }

    pub fn all() -> [ChannelCondition; 3] {
        [
            ChannelCondition::Good,
            ChannelCondition::Normal,
            ChannelCondition::Poor,
        ]
    }
}

/// Stochastic channel between the base station and one device.
#[derive(Clone, Debug)]
pub struct ChannelModel {
    pub band: Band,
    pub condition: ChannelCondition,
    /// Enable Eq. (25) Rayleigh fading on top of large-scale loss.
    pub rayleigh: bool,
}

impl ChannelModel {
    pub fn new(band: Band, condition: ChannelCondition) -> ChannelModel {
        ChannelModel {
            band,
            condition,
            rayleigh: false,
        }
    }

    pub fn with_rayleigh(mut self, enable: bool) -> ChannelModel {
        self.rayleigh = enable;
        self
    }

    /// Eq. (24): PL(dB) = 32.5 + 20 log10 f + 10 η log10 d + χ,
    /// f in GHz, d in meters, χ ~ N(0, σ²).
    pub fn large_scale_path_loss(&self, distance_m: f64, rng: &mut Rng) -> f64 {
        assert!(distance_m > 0.0, "distance must be positive");
        let shadow = rng.normal(0.0, self.condition.sigma_db());
        32.5 + 20.0 * self.band.carrier_ghz.log10()
            + 10.0 * self.band.path_loss_exp * distance_m.max(1.0).log10()
            + shadow
    }

    /// Effective path loss including Eq. (25) Rayleigh fading when enabled:
    /// PL_small = PL - 10 log10 ψ, ψ ~ Exp(1).
    pub fn path_loss(&self, distance_m: f64, rng: &mut Rng) -> f64 {
        let pl = self.large_scale_path_loss(distance_m, rng);
        if self.rayleigh {
            let psi = rng.exponential().max(1e-9);
            pl - 10.0 * psi.log10()
        } else {
            pl
        }
    }

    /// Downlink SNR in dB at the device.
    ///
    /// The per-beam transmit power is `P_e - 10 log10 N` (Sec. VII-B.1),
    /// but the serving beam recovers the array gain `10 log10 N`, so the
    /// link budget sees the full EIRP — that is what EIRP means.
    pub fn downlink_snr_db(&self, distance_m: f64, rng: &mut Rng) -> f64 {
        self.band.server_eirp_dbm - self.path_loss(distance_m, rng)
            - self.band.noise_floor_dbm()
    }

    /// Uplink SNR in dB at the base station: the UE transmits at its fixed
    /// power class and the BS array contributes (most of) its beamforming
    /// gain on receive, so uplink trails downlink.
    pub fn uplink_snr_db(&self, distance_m: f64, rng: &mut Rng) -> f64 {
        let rx_gain_db = 10.0 * (self.band.beams as f64).log10() * 0.75;
        self.band.device_tx_dbm + rx_gain_db - self.path_loss(distance_m, rng)
            - self.band.noise_floor_dbm()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn path_loss_increases_with_distance() {
        let ch = ChannelModel::new(Band::n257(), ChannelCondition::Good);
        let mut rng = Rng::new(1);
        // Average over shadowing.
        let avg = |d: f64, rng: &mut Rng| -> f64 {
            (0..2000).map(|_| ch.large_scale_path_loss(d, rng)).sum::<f64>() / 2000.0
        };
        let near = avg(10.0, &mut rng);
        let far = avg(100.0, &mut rng);
        // 10x distance at η=2.9 => +29 dB.
        assert!((far - near - 29.0).abs() < 0.5, "near={near} far={far}");
    }

    #[test]
    fn shadowing_sigma_scales_with_condition() {
        let mut rng = Rng::new(2);
        let spread = |cond: ChannelCondition, rng: &mut Rng| -> f64 {
            let ch = ChannelModel::new(Band::n1(), cond);
            let samples: Vec<f64> =
                (0..4000).map(|_| ch.large_scale_path_loss(50.0, rng)).collect();
            crate::util::stats::Summary::of(&samples).std_dev
        };
        let good = spread(ChannelCondition::Good, &mut rng);
        let poor = spread(ChannelCondition::Poor, &mut rng);
        assert!((good - 2.0).abs() < 0.2, "good σ={good}");
        assert!((poor - 6.0).abs() < 0.5, "poor σ={poor}");
    }

    #[test]
    fn rayleigh_adds_variance_and_tail() {
        let mut rng = Rng::new(3);
        let base = ChannelModel::new(Band::n257(), ChannelCondition::Good);
        let fading = base.clone().with_rayleigh(true);
        let sd = |ch: &ChannelModel, rng: &mut Rng| -> f64 {
            let s: Vec<f64> = (0..4000).map(|_| ch.path_loss(50.0, rng)).collect();
            crate::util::stats::Summary::of(&s).std_dev
        };
        assert!(sd(&fading, &mut rng) > sd(&base, &mut rng) * 1.5);
    }

    #[test]
    fn downlink_beats_uplink() {
        let ch = ChannelModel::new(Band::n257(), ChannelCondition::Normal);
        let mut rng = Rng::new(4);
        let n = 1000;
        let (mut dl, mut ul) = (0.0, 0.0);
        for _ in 0..n {
            dl += ch.downlink_snr_db(60.0, &mut rng);
            ul += ch.uplink_snr_db(60.0, &mut rng);
        }
        assert!(dl / n as f64 > ul / n as f64, "server EIRP should win");
    }
}
