//! SNR → CQI → spectral efficiency mapping (3GPP TS 38.214 Table
//! 5.2.2.1-3, 256-QAM table), used to convert simulated link quality into
//! a bitrate as the paper does ("link bitrate is converted by the CQI to
//! MCS mapping table", Sec. VII-B.1).

/// Spectral efficiency (bit/s/Hz) per CQI index 1..=15 (index 0 = out of
/// range / no transmission).
pub const CQI_EFFICIENCY: [f64; 16] = [
    0.0, // CQI 0: out of range
    0.1523, 0.3770, 0.8770, 1.4766, 1.9141, 2.4063, 2.7305, 3.3223, 3.9023, 4.5234, 5.1152,
    5.5547, 6.2266, 6.9141, 7.4063,
];

/// Approximate SNR thresholds (dB) for each CQI (BLER <= 0.1 operating
/// points; standard link-level abstraction values).
pub const CQI_SNR_THRESHOLDS_DB: [f64; 16] = [
    f64::NEG_INFINITY,
    -6.7, -4.7, -2.3, 0.2, 2.4, 4.3, 5.9, 8.1, 10.3, 11.7, 14.1, 16.3, 18.7, 21.0, 22.7,
];

/// Map an SNR to the highest CQI whose threshold it meets.
pub fn snr_to_cqi(snr_db: f64) -> u8 {
    let mut cqi = 0u8;
    for (i, &thr) in CQI_SNR_THRESHOLDS_DB.iter().enumerate() {
        if snr_db >= thr {
            cqi = i as u8;
        }
    }
    cqi
}

/// Link bitrate in bit/s for an SNR over a given bandwidth, including a
/// fixed overhead factor for control signalling (~25% of REs in NR).
pub fn bitrate_bps(snr_db: f64, bandwidth_hz: f64) -> f64 {
    const OVERHEAD: f64 = 0.75;
    let cqi = snr_to_cqi(snr_db) as usize;
    CQI_EFFICIENCY[cqi] * bandwidth_hz * OVERHEAD
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cqi_monotone_in_snr() {
        let mut prev = 0;
        for snr10 in -100..300 {
            let snr = snr10 as f64 / 10.0;
            let cqi = snr_to_cqi(snr);
            assert!(cqi >= prev, "CQI dropped at {snr} dB");
            prev = cqi;
        }
    }

    #[test]
    fn boundary_values() {
        assert_eq!(snr_to_cqi(-10.0), 0);
        assert_eq!(snr_to_cqi(-6.7), 1);
        assert_eq!(snr_to_cqi(22.7), 15);
        assert_eq!(snr_to_cqi(100.0), 15);
    }

    #[test]
    fn efficiency_table_is_increasing() {
        for w in CQI_EFFICIENCY.windows(2) {
            assert!(w[1] > w[0]);
        }
    }

    #[test]
    fn rate_scales_with_bandwidth() {
        let r20 = bitrate_bps(15.0, 20e6);
        let r200 = bitrate_bps(15.0, 200e6);
        assert!((r200 / r20 - 10.0).abs() < 1e-9);
        // 15 dB ~ CQI 11 -> 5.1152 b/s/Hz * 20 MHz * 0.75 ≈ 76.7 Mbps.
        assert!((r20 - 5.1152 * 20e6 * 0.75).abs() < 1.0);
    }

    #[test]
    fn out_of_range_means_zero_rate() {
        assert_eq!(bitrate_bps(-20.0, 20e6), 0.0);
    }
}
