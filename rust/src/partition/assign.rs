//! Device→server assignment for multi-server fleets (PR 10): a
//! per-server capacity **vector** instead of `JointOptions`' one scalar.
//!
//! *Edge-device collaborative split learning with multiple helpers*
//! (arxiv 2403.15815 in PAPERS.md) generalizes the shared-server setting:
//! the fleet fronts S servers, server s offering `capacity[s]` concurrent
//! full-throughput device-equivalents, and the operator must decide
//! **which device trains against which server** before the per-server
//! split/share problem (PR 5's [`JointPlanner`]) even starts. The
//! objective stays the fleet makespan: the max over servers of that
//! server's jointly-priced epoch makespan.
//!
//! [`MultiServerPlanner`] wraps one warm [`JointPlanner`] per server —
//! each riding the PR-4 incremental flow reuse across epochs and
//! candidate evaluations — and searches the assignment space:
//!
//! - **S = 1** delegates to the inner planner verbatim: decisions,
//!   makespan and counters bit-identical, the assignment counters pinned
//!   at zero (the degenerate contract, mirroring the ∞-capacity and K=1
//!   pins).
//! - **Exhaustive** when `S^D` is at most
//!   [`MultiServerOptions::exhaustive_assignments`]: odometer over every
//!   assignment, each scored by the inner planners, with a global
//!   early-exit once a candidate meets the dedicated lower bound (no
//!   assignment beats the slowest device's dedicated optimum).
//! - **Greedy + local search** otherwise: seed by longest-processing-time
//!   over capacity-weighted dedicated delays (or by the previous epoch's
//!   persisted assignment — churn-friendly warm starts), then sweep
//!   single-device moves and pairwise swaps, accepting strict
//!   improvements until a round changes nothing.
//!
//! Search effort lands in the shared [`FleetStats`]:
//! `assignment_moves` (accepted moves/swaps, plus best-candidate
//! adoptions beyond the first on the exhaustive path) and
//! `inner_makespan_solves` (per-server epoch plans used for scoring).
//! [`oracle_multi_server_makespan`] is the brute force the harness pins
//! the planner against: every assignment × PR 5's
//! [`oracle_fleet_makespan`] per server.

use std::collections::BTreeMap;

use super::fleet::{FleetOptions, FleetPlanner, FleetSpec, FleetStats, PlanDecision, PlanRequest};
use super::joint::{oracle_fleet_makespan, JointOptions, JointPlanner};
use super::multihop::fold_counters;
use super::types::Problem;

/// Assignment-tuple budget of [`oracle_multi_server_makespan`] (each tuple
/// costs a full per-server cut-combination sweep — oracle fleets must stay
/// at 2–3 devices over small models).
const ORACLE_ASSIGNMENT_CAP: u64 = 1_000_000;

/// Construction switches of [`MultiServerPlanner`].
#[derive(Clone, Debug, PartialEq)]
pub struct MultiServerOptions {
    /// Per-server capacity in concurrent full-throughput
    /// device-equivalents (the multi-server generalization of
    /// [`JointOptions::server_capacity`]). One entry per server; every
    /// entry must be positive (`f64::INFINITY` = a dedicated server per
    /// assigned device).
    pub server_capacities: Vec<f64>,
    /// Switches of every wrapped per-server engine.
    pub fleet: FleetOptions,
    /// Exhaustive-search bound: enumerate all `S^D` assignments when the
    /// count is at most this, else fall back to greedy + local search.
    pub exhaustive_assignments: u64,
    /// Local-search sweeps (each = one move pass + one swap pass) before
    /// settling; the search also stops early once a sweep changes
    /// nothing.
    pub search_rounds: usize,
}

impl MultiServerOptions {
    /// The common construction: capacities plus default engine switches.
    pub fn with_capacities(server_capacities: Vec<f64>) -> MultiServerOptions {
        MultiServerOptions {
            server_capacities,
            fleet: FleetOptions::default(),
            exhaustive_assignments: 512,
            search_rounds: 3,
        }
    }
}

/// The device→server assignment planner (module docs).
pub struct MultiServerPlanner {
    servers: Vec<JointPlanner>,
    options: MultiServerOptions,
    /// Last materialized assignment, device id → server index. Persists
    /// across epochs and seeds the next epoch's local search.
    assignment: BTreeMap<usize, usize>,
    /// Dedicated-delay probe serving the greedy LPT seed and the
    /// exhaustive path's lower bound (lazily built — the 1-server path
    /// never touches it).
    probe: Option<FleetPlanner>,
    spec: FleetSpec,
    last_makespan: Option<f64>,
    assignment_moves: u64,
    inner_makespan_solves: u64,
}

impl MultiServerPlanner {
    /// Build with default options for the given capacities.
    pub fn with_capacities(spec: FleetSpec, capacities: Vec<f64>) -> MultiServerPlanner {
        MultiServerPlanner::new(spec, MultiServerOptions::with_capacities(capacities))
    }

    pub fn new(spec: FleetSpec, options: MultiServerOptions) -> MultiServerPlanner {
        assert!(
            !options.server_capacities.is_empty(),
            "at least one server is required"
        );
        for (s, &c) in options.server_capacities.iter().enumerate() {
            assert!(c > 0.0, "server {s} capacity must be positive, got {c}");
        }
        let servers = options
            .server_capacities
            .iter()
            .map(|&c| {
                JointPlanner::new(
                    spec.clone(),
                    JointOptions {
                        server_capacity: c,
                        fleet: options.fleet,
                    },
                )
            })
            .collect();
        MultiServerPlanner {
            servers,
            options,
            assignment: BTreeMap::new(),
            probe: None,
            spec,
            last_makespan: None,
            assignment_moves: 0,
            inner_makespan_solves: 0,
        }
    }

    /// Plan one epoch: choose a device→server assignment, solve every
    /// server's joint split/share problem, and return one decision per
    /// request in request order.
    pub fn plan(&mut self, requests: &[PlanRequest]) -> Vec<PlanDecision> {
        if self.servers.len() == 1 {
            // Degenerate contract: one server IS the joint planner —
            // decisions, makespan and counters verbatim, assignment
            // counters untouched at zero.
            let decisions = self.servers[0].plan(requests);
            self.last_makespan = self.servers[0].makespan();
            for r in requests {
                self.assignment.insert(r.device, 0);
            }
            return decisions;
        }
        if requests.is_empty() {
            self.last_makespan = None;
            return Vec::new();
        }
        let d = requests.len() as u32;
        let combos = (self.servers.len() as u64).saturating_pow(d);
        let assign = if combos <= self.options.exhaustive_assignments {
            self.search_exhaustive(requests)
        } else {
            self.search_local(requests)
        };
        self.materialize(requests, &assign)
    }

    /// Makespan of the latest epoch (`None` before the first, or after an
    /// empty one).
    pub fn makespan(&self) -> Option<f64> {
        self.last_makespan
    }

    /// The latest materialized assignment, device id → server index.
    pub fn assignment(&self) -> &BTreeMap<usize, usize> {
        &self.assignment
    }

    /// Override the persisted assignment that seeds the next epoch's
    /// local search (the warm-start hook: operators re-seating a fleet,
    /// tests pinning the search's starting point). Entries for unknown
    /// devices are ignored at seeding time; server indices must be in
    /// range.
    pub fn seed_assignment(&mut self, assignment: BTreeMap<usize, usize>) {
        for (&device, &server) in &assignment {
            assert!(
                server < self.servers.len(),
                "device {device} seeded to unknown server {server}"
            );
        }
        self.assignment = assignment;
    }

    pub fn num_servers(&self) -> usize {
        self.servers.len()
    }

    /// The fleet spec every server serves.
    pub fn spec(&self) -> &FleetSpec {
        &self.spec
    }

    pub fn options(&self) -> &MultiServerOptions {
        &self.options
    }

    /// Aggregate counters: every server engine's additive [`FleetStats`]
    /// counters folded together (plus the seeding probe's, when built),
    /// DAG-shape fields from server 0, plus this planner's
    /// `assignment_moves` / `inner_makespan_solves`. With one server this
    /// is the inner planner's stats verbatim.
    pub fn stats(&self) -> FleetStats {
        let mut s = self.servers[0].stats();
        if self.servers.len() == 1 {
            return s;
        }
        for srv in &self.servers[1..] {
            fold_counters(&mut s, &srv.stats());
        }
        if let Some(p) = &self.probe {
            fold_counters(&mut s, &p.stats());
        }
        s.assignment_moves = self.assignment_moves;
        s.inner_makespan_solves = self.inner_makespan_solves;
        s
    }

    /// Score one assignment: plan every non-empty server group and take
    /// the worst per-server makespan (empty servers contribute nothing).
    fn evaluate(&mut self, requests: &[PlanRequest], assign: &[usize]) -> f64 {
        let mut makespan = 0.0f64;
        for s in 0..self.servers.len() {
            let group: Vec<PlanRequest> = requests
                .iter()
                .enumerate()
                .filter(|&(i, _)| assign[i] == s)
                .map(|(_, &r)| r)
                .collect();
            if group.is_empty() {
                continue;
            }
            self.servers[s].plan(&group);
            self.inner_makespan_solves += 1;
            let m = self.servers[s]
                .makespan()
                .expect("a non-empty epoch has a makespan");
            makespan = makespan.max(m);
        }
        makespan
    }

    /// Odometer over every assignment, keeping the best. Early-exits once
    /// a candidate meets the dedicated lower bound (the slowest device's
    /// dedicated optimum — unbeatable on any server).
    fn search_exhaustive(&mut self, requests: &[PlanRequest]) -> Vec<usize> {
        let s_count = self.servers.len();
        let lower_bound = requests
            .iter()
            .map(|r| self.dedicated_delay(r))
            .fold(0.0f64, f64::max);
        let mut assign = vec![0usize; requests.len()];
        let mut best = self.evaluate(requests, &assign);
        let mut best_assign = assign.clone();
        loop {
            if best <= lower_bound {
                break;
            }
            let mut pos = 0;
            while pos < requests.len() {
                assign[pos] += 1;
                if assign[pos] < s_count {
                    break;
                }
                assign[pos] = 0;
                pos += 1;
            }
            if pos == requests.len() {
                break;
            }
            let makespan = self.evaluate(requests, &assign);
            if makespan < best {
                best = makespan;
                best_assign.copy_from_slice(&assign);
                self.assignment_moves += 1;
            }
        }
        best_assign
    }

    /// Greedy seed + move/swap local search (module docs). Seeds from the
    /// persisted assignment when it covers every request, else by LPT
    /// over capacity-weighted dedicated delays.
    fn search_local(&mut self, requests: &[PlanRequest]) -> Vec<usize> {
        let s_count = self.servers.len();
        let warm: Option<Vec<usize>> = requests
            .iter()
            .map(|r| self.assignment.get(&r.device).copied().filter(|&s| s < s_count))
            .collect();
        let mut assign = match warm {
            Some(a) => a,
            None => self.seed_lpt(requests),
        };
        let mut best = self.evaluate(requests, &assign);
        for _ in 0..self.options.search_rounds {
            let mut improved = false;
            // Move sweep: one device to another server.
            for i in 0..requests.len() {
                let home = assign[i];
                for s in 0..s_count {
                    if s == home {
                        continue;
                    }
                    assign[i] = s;
                    let m = self.evaluate(requests, &assign);
                    if m < best {
                        best = m;
                        self.assignment_moves += 1;
                        improved = true;
                    } else {
                        assign[i] = home;
                    }
                    if assign[i] == s {
                        break; // accepted; re-derive the home server
                    }
                }
            }
            // Swap sweep: exchange two devices' servers (kept quadratic —
            // skipped for very large epochs).
            if requests.len() <= 32 {
                for i in 0..requests.len() {
                    for j in i + 1..requests.len() {
                        if assign[i] == assign[j] {
                            continue;
                        }
                        assign.swap(i, j);
                        let m = self.evaluate(requests, &assign);
                        if m < best {
                            best = m;
                            self.assignment_moves += 1;
                            improved = true;
                        } else {
                            assign.swap(i, j);
                        }
                    }
                }
            }
            if !improved {
                break;
            }
        }
        assign
    }

    /// LPT seed: devices by descending dedicated delay, each placed on
    /// the server with the least capacity-weighted seeded load.
    fn seed_lpt(&mut self, requests: &[PlanRequest]) -> Vec<usize> {
        let delays: Vec<f64> = requests.iter().map(|r| self.dedicated_delay(r)).collect();
        let mut order: Vec<usize> = (0..requests.len()).collect();
        order.sort_by(|&a, &b| delays[b].partial_cmp(&delays[a]).unwrap());
        let mut load = vec![0.0f64; self.servers.len()];
        let mut assign = vec![0usize; requests.len()];
        for &i in &order {
            let mut best = 0;
            for s in 1..self.servers.len() {
                let weigh = |s: usize| load[s] / self.options.server_capacities[s].min(1e18);
                if weigh(s) < weigh(best) {
                    best = s;
                }
            }
            assign[i] = best;
            load[best] += delays[i];
        }
        assign
    }

    /// A device's dedicated-server optimal delay (the per-request lower
    /// bound), served by the lazily built probe engine.
    fn dedicated_delay(&mut self, request: &PlanRequest) -> f64 {
        let probe = self.probe.get_or_insert_with(|| {
            FleetPlanner::with_options(self.spec.clone(), self.options.fleet)
        });
        probe.take_solve(request.tier, request.link).delay
    }

    /// Re-plan the chosen assignment so every server's state and the
    /// returned decisions are consistent, persist it, and record the
    /// epoch makespan.
    fn materialize(
        &mut self,
        requests: &[PlanRequest],
        assign: &[usize],
    ) -> Vec<PlanDecision> {
        let mut decisions: Vec<Option<PlanDecision>> = vec![None; requests.len()];
        let mut makespan = 0.0f64;
        for s in 0..self.servers.len() {
            let members: Vec<usize> = (0..requests.len()).filter(|&i| assign[i] == s).collect();
            if members.is_empty() {
                continue;
            }
            let group: Vec<PlanRequest> = members.iter().map(|&i| requests[i]).collect();
            let planned = self.servers[s].plan(&group);
            self.inner_makespan_solves += 1;
            makespan = makespan.max(
                self.servers[s]
                    .makespan()
                    .expect("a non-empty epoch has a makespan"),
            );
            for (slot, &i) in members.iter().enumerate() {
                decisions[i] = Some(planned[slot].clone());
            }
        }
        for (i, r) in requests.iter().enumerate() {
            self.assignment.insert(r.device, assign[i]);
        }
        self.last_makespan = Some(makespan);
        decisions
            .into_iter()
            .map(|d| d.expect("every request is assigned to exactly one server"))
            .collect()
    }
}

/// Brute-force optimum of the multi-server fleet: enumerate **every**
/// device→server assignment by odometer and score each with PR 5's
/// [`oracle_fleet_makespan`] per non-empty server (empty servers
/// contribute nothing). Deliberately independent of the planner's search
/// and of its inner [`JointPlanner`]s — the harness pins one against the
/// other. Prunes nothing but the global dedicated bound (no assignment
/// beats the slowest device's dedicated optimum, itself computed by
/// enumerating that device's feasible cuts).
pub fn oracle_multi_server_makespan(problems: &[Problem<'_>], capacities: &[f64]) -> f64 {
    assert!(!problems.is_empty(), "oracle needs at least one device");
    assert!(!capacities.is_empty(), "oracle needs at least one server");
    for &c in capacities {
        assert!(c > 0.0, "server capacities must be positive");
    }
    let s_count = capacities.len();
    let combos = (s_count as u64).saturating_pow(problems.len() as u32);
    assert!(
        combos <= ORACLE_ASSIGNMENT_CAP,
        "oracle limited to {ORACLE_ASSIGNMENT_CAP} assignments, got {combos}"
    );
    // The dedicated lower bound: each device's best feasible cut on a
    // server of its own (∞ capacity ≡ dedicated).
    let lower_bound = problems
        .iter()
        .map(|p| oracle_fleet_makespan(std::slice::from_ref(p), f64::INFINITY))
        .fold(0.0f64, f64::max);
    let mut assign = vec![0usize; problems.len()];
    let mut best = f64::INFINITY;
    loop {
        let mut makespan = 0.0f64;
        for s in 0..s_count {
            let group: Vec<Problem<'_>> = problems
                .iter()
                .enumerate()
                .filter(|&(d, _)| assign[d] == s)
                .map(|(_, p)| p.clone())
                .collect();
            if group.is_empty() {
                continue;
            }
            makespan = makespan.max(oracle_fleet_makespan(&group, capacities[s]));
            if makespan >= best {
                break; // this assignment already lost
            }
        }
        if makespan < best {
            best = makespan;
        }
        if best <= lower_bound {
            return best;
        }
        let mut d = 0;
        loop {
            if d == problems.len() {
                return best;
            }
            assign[d] += 1;
            if assign[d] < s_count {
                break;
            }
            assign[d] = 0;
            d += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::partition::types::Link;
    use crate::profiles::{CostGraph, DeviceProfile, TrainCfg};
    use crate::util::prop::{assert_fleet_cost_equal, random_link, seeded_case, CUT_COST_ULPS};
    use crate::util::rng::Rng;

    fn costs_for(model: &str, device: &DeviceProfile) -> CostGraph {
        let m = models::by_name(model).unwrap();
        CostGraph::build(&m, device, &DeviceProfile::rtx_a6000(), &TrainCfg::default())
    }

    fn spec_for(model: &'static str, devices: usize) -> FleetSpec {
        let tiers = [DeviceProfile::jetson_tx2(), DeviceProfile::jetson_orin_nano()];
        let fleet: Vec<DeviceProfile> = (0..devices).map(|d| tiers[d % 2].clone()).collect();
        FleetSpec::from_fleet(&fleet, |d| costs_for(model, d))
    }

    fn epoch_requests(spec: &FleetSpec, rng: &mut Rng) -> Vec<PlanRequest> {
        (0..spec.num_devices())
            .map(|device| PlanRequest {
                device,
                tier: spec.tier_of(device),
                link: random_link(rng),
            })
            .collect()
    }

    /// The degenerate pin: with one server the multi-server planner IS
    /// the joint planner across the whole capacity ladder — decisions,
    /// makespan and stats bit-identical, assignment counters at zero.
    #[test]
    fn one_server_planner_is_bit_identical_to_joint_planner() {
        seeded_case("one-server-bit-identity", 0xA551, |rng| {
            for capacity in [0.6, 1.2, 2.5, f64::INFINITY] {
                let spec = spec_for("lenet5", 3);
                let mut multi = MultiServerPlanner::with_capacities(spec.clone(), vec![capacity]);
                let mut joint = JointPlanner::with_capacity(spec, capacity);
                for _ in 0..4 {
                    let requests = epoch_requests(multi.spec(), rng);
                    let got = multi.plan(&requests);
                    let want = joint.plan(&requests);
                    assert_eq!(got.len(), want.len());
                    for (g, w) in got.iter().zip(&want) {
                        assert_eq!(g.device, w.device);
                        assert_eq!(g.tier, w.tier);
                        assert_eq!(g.partition.device_set, w.partition.device_set);
                        assert_eq!(g.partition.delay.to_bits(), w.partition.delay.to_bits());
                        assert_eq!(g.cut_layer, w.cut_layer);
                    }
                    assert_eq!(
                        multi.makespan().map(f64::to_bits),
                        joint.makespan().map(f64::to_bits)
                    );
                }
                let stats = multi.stats();
                assert_eq!(stats, joint.stats());
                assert_eq!(stats.assignment_moves, 0);
                assert_eq!(stats.inner_makespan_solves, 0);
                assert!(multi.assignment().values().all(|&s| s == 0));
            }
        });
    }

    /// The oracle pin: on 2–3-device / 2-server fleets the exhaustive
    /// planner matches the brute-force assignment × cut-combination
    /// optimum.
    #[test]
    fn planner_matches_the_assignment_oracle_on_small_fleets() {
        seeded_case("multi-server-oracle", 0x5EED5, |rng| {
            for devices in [2usize, 3] {
                let spec = spec_for("lenet5", devices);
                let capacities = vec![rng.range(0.5, 1.0), rng.range(1.0, 2.0)];
                let mut planner =
                    MultiServerPlanner::with_capacities(spec.clone(), capacities.clone());
                for epoch in 0..3 {
                    let requests = epoch_requests(&spec, rng);
                    let decisions = planner.plan(&requests);
                    assert_eq!(decisions.len(), requests.len());
                    for (d, r) in decisions.iter().zip(&requests) {
                        assert_eq!(d.device, r.device);
                        assert_eq!(d.tier, r.tier);
                    }
                    let tier_costs: Vec<&CostGraph> = requests
                        .iter()
                        .map(|r| spec.tier_costs(r.tier))
                        .collect();
                    let problems: Vec<Problem<'_>> = requests
                        .iter()
                        .zip(&tier_costs)
                        .map(|(r, c)| Problem::new(c, r.link))
                        .collect();
                    let oracle = oracle_multi_server_makespan(&problems, &capacities);
                    assert_fleet_cost_equal(
                        planner.makespan().unwrap(),
                        oracle,
                        &format!("{devices} devices epoch {epoch}"),
                    );
                }
                assert!(planner.stats().inner_makespan_solves > 0);
            }
        });
    }

    /// Adding a server never raises the (exhaustively optimal) fleet
    /// makespan — any old assignment is still available.
    #[test]
    fn adding_a_server_never_raises_the_makespan() {
        seeded_case("server-monotonicity", 0xADD5, |rng| {
            let spec = spec_for("lenet5", 3);
            let requests = epoch_requests(&spec, rng);
            let base_cap = rng.range(0.4, 0.9);
            let mut ladder: Vec<f64> = vec![base_cap];
            let mut prev = f64::INFINITY;
            for extra in 0..3 {
                let mut planner =
                    MultiServerPlanner::with_capacities(spec.clone(), ladder.clone());
                planner.plan(&requests);
                let makespan = planner.makespan().unwrap();
                let tol = CUT_COST_ULPS * f64::EPSILON * (1.0 + makespan.abs());
                assert!(
                    makespan <= prev + tol,
                    "server {} raised the makespan: {prev} -> {makespan}",
                    ladder.len()
                );
                prev = makespan;
                ladder.push(rng.range(0.4, 0.9) + extra as f64 * 0.1);
            }
        });
    }

    /// The greedy + local-search path stays sane: never below the
    /// exhaustive optimum (minus tolerance), consistent decisions, a
    /// persisted in-range assignment, and scoring counters that fire.
    #[test]
    fn local_search_stays_sane_against_the_exhaustive_optimum() {
        seeded_case("local-search-sanity", 0x10CA1, |rng| {
            let spec = spec_for("lenet5", 4);
            let capacities = vec![rng.range(0.5, 1.0), rng.range(1.0, 2.0)];
            let requests = epoch_requests(&spec, rng);

            let mut exact = MultiServerPlanner::with_capacities(spec.clone(), capacities.clone());
            exact.plan(&requests);
            let optimum = exact.makespan().unwrap();

            let mut greedy = MultiServerPlanner::new(
                spec.clone(),
                MultiServerOptions {
                    exhaustive_assignments: 1, // force the local-search path
                    ..MultiServerOptions::with_capacities(capacities)
                },
            );
            let decisions = greedy.plan(&requests);
            let makespan = greedy.makespan().unwrap();
            let tol = CUT_COST_ULPS * f64::EPSILON * (1.0 + makespan.abs().max(optimum.abs()));
            assert!(
                makespan + tol >= optimum,
                "local search can be suboptimal but never beats brute force: \
                 {makespan} vs {optimum}"
            );
            assert!(makespan.is_finite());
            assert_eq!(decisions.len(), requests.len());
            for r in &requests {
                let s = greedy.assignment()[&r.device];
                assert!(s < greedy.num_servers());
            }
            let stats = greedy.stats();
            assert!(stats.inner_makespan_solves > 0);
            assert!(stats.flow_solves + stats.linear_scans > 0);
        });
    }

    /// An adversarial warm seed (everything on one congested server) must
    /// be repaired by the move sweep: accepted moves are counted and the
    /// result improves on the seed's makespan. Links are fixed and fast
    /// so the per-device optimum genuinely offloads to the server (W > 0)
    /// and piling four sessions onto one unit-capacity server congests it
    /// — the improving move provably exists.
    #[test]
    fn local_search_repairs_an_adversarial_seed_and_counts_moves() {
        let spec = spec_for("lenet5", 4);
        let capacities = vec![1.0, 1.0];
        let requests: Vec<PlanRequest> = (0..spec.num_devices())
            .map(|device| PlanRequest {
                device,
                tier: spec.tier_of(device),
                link: Link::symmetric(2e8 + device as f64 * 1e7),
            })
            .collect();

        let mut seeded = MultiServerPlanner::new(
            spec.clone(),
            MultiServerOptions {
                exhaustive_assignments: 1, // force the local-search path
                ..MultiServerOptions::with_capacities(capacities.clone())
            },
        );
        seeded.seed_assignment(requests.iter().map(|r| (r.device, 0)).collect());
        seeded.plan(&requests);
        let repaired = seeded.makespan().unwrap();
        assert!(
            seeded.stats().assignment_moves > 0,
            "an all-on-one-server seed over equal servers must admit an improving move"
        );
        // The repaired makespan must strictly improve on the seed's.
        let mut pinned = MultiServerPlanner::new(
            spec,
            MultiServerOptions {
                exhaustive_assignments: 1,
                search_rounds: 0, // evaluate the seed, search nothing
                ..MultiServerOptions::with_capacities(capacities)
            },
        );
        pinned.seed_assignment(requests.iter().map(|r| (r.device, 0)).collect());
        pinned.plan(&requests);
        let seed_makespan = pinned.makespan().unwrap();
        assert!(
            repaired < seed_makespan,
            "local search must improve on the congested seed: {repaired} vs {seed_makespan}"
        );
    }

    /// The exhaustive path on an engineered two-capacity fleet: the
    /// odometer's first candidate (everything on the starved server) must
    /// be replaced — `assignment_moves` fires — and the optimum matches
    /// the oracle.
    #[test]
    fn exhaustive_search_counts_adoptions_and_prefers_the_big_server() {
        seeded_case("exhaustive-adoptions", 0xB16, |rng| {
            let spec = spec_for("lenet5", 2);
            let capacities = vec![1e-3, 1e9]; // starved vs effectively dedicated
            let mut planner = MultiServerPlanner::with_capacities(spec.clone(), capacities.clone());
            let requests = epoch_requests(&spec, rng);
            planner.plan(&requests);
            let stats = planner.stats();
            assert!(
                stats.assignment_moves > 0,
                "the all-on-starved-server start must be beaten"
            );
            assert!(
                planner.assignment().values().all(|&s| s == 1),
                "every device belongs on the big server: {:?}",
                planner.assignment()
            );
            let tier_costs: Vec<&CostGraph> =
                requests.iter().map(|r| spec.tier_costs(r.tier)).collect();
            let problems: Vec<Problem<'_>> = requests
                .iter()
                .zip(&tier_costs)
                .map(|(r, c)| Problem::new(c, r.link))
                .collect();
            assert_fleet_cost_equal(
                planner.makespan().unwrap(),
                oracle_multi_server_makespan(&problems, &capacities),
                "engineered two-capacity fleet",
            );
        });
    }
}
