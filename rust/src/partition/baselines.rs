//! Baseline partitioning methods the paper compares against (Sec. VII):
//! brute force [10], regression [21], OSS [17], device-only, central.

use super::blocks::detect_blocks;
use super::blockwise::passes_intra_block_test;
use super::general::general_partition;
use super::types::{Link, Partition, Problem};
use crate::graph::enumerate_lower_sets;
use crate::util::stats::{polyfit, polyval};

/// Brute-force search [10]: enumerate every feasible cut (lower set of the
/// layer DAG) and evaluate Eq. (7) directly. Exponential; only viable for
/// the single-block networks (Fig. 7/9a).
pub fn brute_force_partition(problem: &Problem) -> Partition {
    let inputs: Vec<usize> = (0..problem.costs.len())
        .filter(|&v| problem.costs.dag.in_degree(v) == 0)
        .collect();
    let mut best: Option<(f64, Vec<bool>)> = None;
    enumerate_lower_sets(&problem.costs.dag, |mask| {
        if problem.pin_inputs && inputs.iter().any(|&v| !mask[v]) {
            return; // raw data must stay on the device
        }
        let delay = problem.delay(mask);
        if best.as_ref().map_or(true, |(d, _)| delay < *d) {
            best = Some((delay, mask.to_vec()));
        }
    });
    let (delay, device_set) = best.expect("at least one feasible cut exists");
    Partition { device_set, delay }
}

/// Theoretical operation count of brute force: `2^|V| (|V|+|E|)` (Sec. VI-D).
pub fn brute_force_complexity(problem: &Problem) -> f64 {
    let v = problem.costs.len() as f64;
    let e = problem.costs.dag.num_edges() as f64;
    2f64.powf(v) * (v + e)
}

/// Regression-based search [21]: linearize the model (block abstraction,
/// Sec. VII-A.1), fit low-degree polynomials to the cumulative compute /
/// parameter curves and the activation-size profile from a few anchor
/// cuts, minimize the fitted delay over a continuous cut position, and
/// round. Fast but suboptimal: the jagged activation-size profile is
/// exactly what the fit cannot capture (the paper's Fig. 7(b)).
pub fn regression_partition(problem: &Problem) -> Partition {
    let c = problem.costs;
    // Linearize: abstract every detected block that passes the Theorem 2
    // test, then require a chain; if still non-linear, fall back to treating
    // the topological order as a chain (the regression method's own
    // approximation for unsupported topologies).
    let order = c.dag.topo_order().expect("acyclic");
    let n = order.len();

    // Cumulative ground-truth curves over prefix cuts 0..=n.
    let mut cum_dev = vec![0.0f64; n + 1];
    let mut cum_srv = vec![0.0f64; n + 1];
    let mut cum_par = vec![0.0f64; n + 1];
    let mut act = vec![0.0f64; n + 1];
    for (i, &v) in order.iter().enumerate() {
        cum_dev[i + 1] = cum_dev[i] + c.xi_d[v];
        cum_srv[i + 1] = cum_srv[i] + c.xi_s[v];
        cum_par[i + 1] = cum_par[i] + c.param_bytes[v];
        act[i + 1] = if c.dag.out_degree(v) > 0 {
            c.act_bytes[v]
        } else {
            0.0
        };
    }
    let total_srv = cum_srv[n];

    // Anchor points: the regression method profiles only a handful of cuts.
    let anchors: Vec<usize> = {
        let k = 5.min(n);
        (0..=k).map(|i| i * n / k).collect()
    };
    let xs: Vec<f64> = anchors.iter().map(|&i| i as f64).collect();
    let fit = |ys: &[f64], deg: usize| -> Vec<f64> {
        let pts: Vec<f64> = anchors.iter().map(|&i| ys[i]).collect();
        polyfit(&xs, &pts, deg.min(xs.len() - 1))
    };
    let f_dev = fit(&cum_dev, 2);
    let f_srv = fit(&cum_srv, 2);
    let f_par = fit(&cum_par, 2);
    let f_act = fit(&act, 2);

    // Continuous objective; minimize over a fine grid (the continuous
    // optimization step of [21]).
    let inv = problem.link.sigma();
    let objective = |x: f64| -> f64 {
        let dev = polyval(&f_dev, x).max(0.0);
        let srv = (total_srv - polyval(&f_srv, x)).max(0.0);
        let a = polyval(&f_act, x).max(0.0);
        let k = polyval(&f_par, x).max(0.0);
        c.n_loc * (dev + srv + a * inv) + k * inv
    };
    let mut best_x = if problem.pin_inputs { 1.0 } else { 0.0 };
    let mut best_obj = f64::INFINITY;
    let grid = 512;
    let g_lo = if problem.pin_inputs {
        // The first (input) position must stay on the device.
        (grid as f64 / n as f64).ceil() as usize
    } else {
        0
    };
    for g in g_lo..=grid {
        let x = g as f64 * n as f64 / grid as f64;
        let o = objective(x);
        if o < best_obj {
            best_obj = o;
            best_x = x;
        }
    }
    let cut = (best_x.round() as usize).min(n);

    let mut device_set = vec![false; c.len()];
    for &v in order.iter().take(cut) {
        device_set[v] = true;
    }
    problem.partition(device_set)
}

/// Optimal static split (OSS) [17]: the best *fixed* cut for nominal link
/// rates, chosen once and never adapted (the proposed solution re-runs the
/// partition each epoch instead).
pub fn oss_partition(problem_nominal: &Problem) -> Partition {
    general_partition(problem_nominal)
}

/// Evaluate a fixed device set under different (current) link conditions —
/// how OSS is scored each epoch once the channel moved.
pub fn evaluate_static(problem_now: &Problem, fixed: &Partition) -> Partition {
    problem_now.partition(fixed.device_set.clone())
}

/// Convenience: all baseline names used in experiment tables.
pub const BASELINE_NAMES: &[&str] = &["proposed", "oss", "device-only", "regression", "central"];

/// Compute the partition for the named method under the given problem.
/// OSS requires the nominal-rate problem for its static choice.
pub fn partition_by_method(
    method: &str,
    problem_now: &Problem,
    nominal_link: Link,
) -> Partition {
    match method {
        "proposed" => super::blockwise::blockwise_partition(problem_now),
        "general" => general_partition(problem_now),
        "regression" => regression_partition(problem_now),
        "device-only" => problem_now.device_only(),
        "central" => problem_now.central(),
        "oss" => {
            let nominal = Problem::new(problem_now.costs, nominal_link);
            let fixed = oss_partition(&nominal);
            evaluate_static(problem_now, &fixed)
        }
        "brute-force" => brute_force_partition(problem_now),
        other => panic!("unknown method '{other}'"),
    }
}

/// Sanity helper used by multiple harnesses: does the block structure allow
/// full abstraction (all blocks pass Theorem 2)?
pub fn fully_abstractable(problem: &Problem) -> bool {
    let c = problem.costs;
    detect_blocks(&c.dag)
        .iter()
        .all(|b| passes_intra_block_test(c, b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::profiles::{CostGraph, DeviceProfile, TrainCfg};

    fn cg(model: &str) -> CostGraph {
        let m = models::by_name(model).unwrap();
        CostGraph::build(
            &m,
            &DeviceProfile::jetson_tx2(),
            &DeviceProfile::rtx_a6000(),
            &TrainCfg::default(),
        )
    }

    #[test]
    fn brute_force_is_a_lower_bound() {
        for model in ["block-residual", "block-inception"] {
            let c = cg(model);
            let p = Problem::new(&c, Link::symmetric(1e6));
            let bf = brute_force_partition(&p);
            // `central` is excluded: it ignores the data-locality pin.
            for method in ["regression", "device-only", "oss"] {
                let m = partition_by_method(method, &p, p.link);
                assert!(
                    bf.delay <= m.delay + 1e-9,
                    "{model}: brute force {} beaten by {method} {}",
                    bf.delay,
                    m.delay
                );
            }
        }
    }

    #[test]
    fn regression_returns_feasible_prefix() {
        for model in ["lenet5", "block-inception", "googlenet"] {
            let c = cg(model);
            let p = Problem::new(&c, Link::symmetric(1e6));
            let r = regression_partition(&p);
            assert!(p.is_feasible(&r.device_set), "{model}");
        }
    }

    #[test]
    fn regression_is_generally_suboptimal_on_nonlinear_models() {
        // Fig. 7(b): regression should miss the optimum on at least one of
        // the block nets across a range of rates.
        let mut misses = 0;
        for model in ["block-residual", "block-inception", "block-dense"] {
            let c = cg(model);
            for rate in [1e5, 5e5, 1e6, 5e6, 1e7] {
                let p = Problem::new(&c, Link::symmetric(rate));
                let bf = brute_force_partition(&p);
                let r = regression_partition(&p);
                if r.delay > bf.delay * (1.0 + 1e-9) {
                    misses += 1;
                }
            }
        }
        assert!(misses > 0, "regression matched brute force everywhere");
    }

    #[test]
    fn oss_adapts_nothing() {
        let c = cg("block-residual");
        let nominal = Link::symmetric(1e6);
        let now = Problem::new(&c, Link::symmetric(1e4)); // channel collapsed
        let oss = partition_by_method("oss", &now, nominal);
        // Same device set as the nominal-rate optimum.
        let fixed = general_partition(&Problem::new(&c, nominal));
        assert_eq!(oss.device_set, fixed.device_set);
        // But evaluated under the current (bad) channel.
        assert!((oss.delay - now.delay(&fixed.device_set)).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "unknown method")]
    fn unknown_method_panics() {
        let c = cg("lenet5");
        let p = Problem::new(&c, Link::symmetric(1e6));
        let _ = partition_by_method("nope", &p, p.link);
    }
}
