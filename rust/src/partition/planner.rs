//! Amortized re-partitioning: build the transformed flow network **once**,
//! re-solve per epoch with an O(E) capacity refresh.
//!
//! The coordinator's loop (Sec. III-A) re-makes the partition decision
//! every epoch as link rates fluctuate, but between epochs only the rates
//! change: the layer DAG, the auxiliary vertices of Fig. 3, and the
//! infinite closure edges are identical every time. The one-shot path
//! (`general::general_partition_with_options`) nevertheless used to rebuild
//! the whole network — including one heap allocation per vertex — on every
//! call.
//!
//! [`TransformedNet`] separates the two concerns. Every forward-edge
//! capacity of the transformed network is affine in the round-trip byte
//! cost `σ = 1/R_up + 1/R_down`:
//!
//! ```text
//!   cap(e) = base(e) + bw_scale(e) · σ          with, per edge class:
//!   server-exec  (s  → v')   base = N_loc·ξ_S(v)   scale = 0      (∞ if pinned input)
//!   device-exec  (v' → t)    base = N_loc·ξ_D(v)   scale = k_v
//!   propagation  (u  → v')   base = 0              scale = N_loc·a_u
//!   aux transmit (v' → v)    base = 0              scale = N_loc·a_v
//!   closure      (reverse)   base = ∞              scale = 0
//! ```
//!
//! so [`TransformedNet::refresh`] re-capacitates the frozen network for a
//! new link in one pass over the edge arrays — no allocation, no topology
//! work — and `FlowNetwork::set_edge_capacity` doubles as the between-solve
//! reset. Refreshing every edge leaves the network in exactly the state a
//! cold build would produce, which is why the warm solve is bit-identical
//! to the cold one (asserted by the property tests below across the whole
//! model zoo; PERF.md documents the invariants and the measured speedup).
//!
//! [`PartitionPlanner`] owns a `TransformedNet` plus reusable
//! [`DinicScratch`] buffers and is the type repeated-solve callers hold —
//! one per (model, device-tier): `blockwise::Planner` (on the reduced DAG),
//! the coordinator, the simulator, and the replan bench.

use super::general::linear_scan_partition;
use super::types::{Link, Partition, Problem};
use crate::maxflow::{dinic_with, DinicScratch, FlowNetwork, MinCut};
use crate::profiles::CostGraph;

/// The Alg. 2 transformed flow network with link-independent structure and
/// per-edge affine capacity models (see the module docs).
pub(crate) struct TransformedNet {
    net: FlowNetwork,
    /// Link-independent part of each forward edge's capacity.
    base: Vec<f64>,
    /// Coefficient of `σ = 1/R_up + 1/R_down` in each capacity.
    bw_scale: Vec<f64>,
    /// exec[v] = flow vertex carrying layer v's execution semantics.
    exec: Vec<usize>,
    source: usize,
    sink: usize,
}

impl TransformedNet {
    /// Build the transformed network (Alg. 1 weights + Fig. 3 auxiliary
    /// vertices + optional closure edges). Capacities are left at zero;
    /// call [`TransformedNet::refresh`] with a link before solving.
    ///
    /// Edge insertion order matches the historical one-shot construction in
    /// `general.rs` so solver traversal (and thus tie-breaking among equal
    /// minimum cuts) is unchanged.
    pub(crate) fn build(c: &CostGraph, pin_inputs: bool, closure_edges: bool) -> TransformedNet {
        let n = c.len();
        // Flow network layout: ids 0..n are layer vertices, n is source,
        // n+1 is sink, auxiliary vertices appended after.
        let mut exec: Vec<usize> = (0..n).collect();
        let source = n;
        let sink = n + 1;
        let mut next = n + 2;
        let split: Vec<bool> = (0..n).map(|v| c.dag.out_degree(v) > 1).collect();
        for v in 0..n {
            if split[v] {
                exec[v] = next;
                next += 1;
            }
        }
        let num_split = next - (n + 2);
        let dag_edges = c.dag.num_edges();
        let closure = if closure_edges { dag_edges + num_split } else { 0 };
        let num_edges = 2 * n + dag_edges + num_split + closure;

        let mut net = FlowNetwork::with_capacity(next, num_edges);
        let mut base = Vec::with_capacity(num_edges);
        let mut bw_scale = Vec::with_capacity(num_edges);

        for v in 0..n {
            // Server execution edge (s -> exec(v)), Eq. (10). Pinned inputs
            // (raw data) may never move to the server: infinite weight.
            let w = if pin_inputs && c.dag.in_degree(v) == 0 {
                f64::INFINITY
            } else {
                c.n_loc * c.xi_s[v]
            };
            net.add_edge(source, exec[v], 0.0);
            base.push(w);
            bw_scale.push(0.0);
            // Device execution edge (exec(v) -> t), Eq. (9) + the one-off
            // model up/download of the layer's parameters.
            net.add_edge(exec[v], sink, 0.0);
            base.push(c.n_loc * c.xi_d[v]);
            bw_scale.push(c.param_bytes[v]);
        }

        // Propagation edges + the auxiliary (exec -> transmit) edge of
        // Fig. 3. Incoming edges of a split child are redirected to its
        // auxiliary vertex, Eq. (13).
        for e in c.dag.edges() {
            let from = if split[e.from] { e.from } else { exec[e.from] };
            net.add_edge(from, exec[e.to], 0.0);
            base.push(0.0);
            bw_scale.push(c.n_loc * c.act_bytes[e.from]);
            if closure_edges {
                // Precedence: child on device => parent on device.
                net.add_edge(exec[e.to], exec[e.from], 0.0);
                base.push(f64::INFINITY);
                bw_scale.push(0.0);
            }
        }
        for v in 0..n {
            if split[v] {
                // (v' -> v) carries one propagation weight, Eq. (15).
                net.add_edge(exec[v], v, 0.0);
                base.push(0.0);
                bw_scale.push(c.n_loc * c.act_bytes[v]);
                if closure_edges {
                    // Transmit node on device while execution on server is
                    // physically meaningless; forbid for unambiguous
                    // extraction.
                    net.add_edge(v, exec[v], 0.0);
                    base.push(f64::INFINITY);
                    bw_scale.push(0.0);
                }
            }
        }
        debug_assert_eq!(net.num_edges(), num_edges);
        net.freeze();
        TransformedNet {
            net,
            base,
            bw_scale,
            exec,
            source,
            sink,
        }
    }

    /// Re-capacitate every edge for the given link and clear all routed
    /// flow: one O(E) pass, no allocation. Invariant: after this call the
    /// network state is indistinguishable from a cold
    /// [`TransformedNet::build`] + refresh — every forward arc holds its
    /// full capacity, every residual twin holds zero.
    pub(crate) fn refresh(&mut self, link: Link) {
        let sigma = 1.0 / link.up_bps + 1.0 / link.down_bps;
        for k in 0..self.base.len() {
            self.net.set_edge_capacity(k, self.base[k] + self.bw_scale[k] * sigma);
        }
    }

    /// Solve min s-t cut on the current capacities.
    pub(crate) fn min_cut(&mut self, scratch: &mut DinicScratch) -> MinCut {
        dinic_with(&mut self.net, self.source, self.sink, scratch)
    }

    /// Read the layer assignment off the execution vertices.
    pub(crate) fn device_set(&self, source_side: &[bool]) -> Vec<bool> {
        self.exec.iter().map(|&e| source_side[e]).collect()
    }

    pub(crate) fn num_vertices(&self) -> usize {
        self.net.len()
    }

    pub(crate) fn num_edges(&self) -> usize {
        self.net.num_edges()
    }
}

/// Amortized per-(model, device-tier) partition planner: the dynamic-edge
/// hot path. Construction does all structural work (transformed-network
/// build, CSR freeze); [`PartitionPlanner::partition`] per epoch is an
/// O(E) capacity refresh + a Dinic solve on reusable scratch.
///
/// Linear models (no parent with multiple children) keep the O(L) scan
/// fast path of Alg. 2 lines 2-4 — already allocation-light, and exactly
/// what the one-shot algorithm does.
pub struct PartitionPlanner {
    costs: CostGraph,
    pin_inputs: bool,
    closure_edges: bool,
    /// `None` for linear models (scan fast path).
    flow: Option<Box<FlowState>>,
    solves: u64,
}

struct FlowState {
    tnet: TransformedNet,
    scratch: DinicScratch,
}

impl PartitionPlanner {
    /// Plan for the default problem (pinned inputs, closure edges on).
    pub fn new(costs: &CostGraph) -> PartitionPlanner {
        PartitionPlanner::with_options(costs, true, true)
    }

    /// Explicit control over input pinning and closure edges (mirrors
    /// `general_partition_with_options`).
    pub fn with_options(
        costs: &CostGraph,
        pin_inputs: bool,
        closure_edges: bool,
    ) -> PartitionPlanner {
        let n = costs.len();
        let linear = !(0..n).any(|v| costs.dag.out_degree(v) > 1);
        let flow = if linear {
            None
        } else {
            Some(Box::new(FlowState {
                tnet: TransformedNet::build(costs, pin_inputs, closure_edges),
                scratch: DinicScratch::default(),
            }))
        };
        PartitionPlanner {
            costs: costs.clone(),
            pin_inputs,
            closure_edges,
            flow,
            solves: 0,
        }
    }

    /// Solve for the current link state (the per-epoch hot path). Bitwise
    /// identical to a cold `general_partition` on the same problem.
    pub fn partition(&mut self, link: Link) -> Partition {
        self.solves += 1;
        // Problem::new validates the link (positive rates), exactly like
        // the cold path — a dead uplink must panic, not produce NaN
        // capacities that solve to a silent garbage cut.
        let mut problem = Problem::new(&self.costs, link);
        problem.pin_inputs = self.pin_inputs;
        match &mut self.flow {
            None => linear_scan_partition(&problem),
            Some(state) => {
                state.tnet.refresh(link);
                let cut = state.tnet.min_cut(&mut state.scratch);
                let device_set = state.tnet.device_set(&cut.source_side);
                // Without closure edges the cut need not be a lower set
                // (that is the point of ablA), so only assert under the
                // default construction — mirrors general.rs.
                debug_assert!(
                    !self.closure_edges || problem.is_feasible(&device_set),
                    "planner produced an infeasible partition"
                );
                problem.partition(device_set)
            }
        }
    }

    /// Number of solves served since construction.
    pub fn solves(&self) -> u64 {
        self.solves
    }

    /// (vertices, edges) of the cached flow network; `None` on the linear
    /// fast path.
    pub fn flow_size(&self) -> Option<(usize, usize)> {
        self.flow
            .as_ref()
            .map(|s| (s.tnet.num_vertices(), s.tnet.num_edges()))
    }

    /// The cost graph this planner was built for.
    pub fn costs(&self) -> &CostGraph {
        &self.costs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::partition::general::{general_partition, general_partition_with_options};
    use crate::profiles::{DeviceProfile, TrainCfg};
    use crate::util::prop::{for_all, random_layer_dag};
    use crate::util::rng::Rng;
    use crate::graph::Dag;

    fn cg(model: &str) -> CostGraph {
        let m = models::by_name(model).unwrap();
        CostGraph::build(
            &m,
            &DeviceProfile::jetson_tx2(),
            &DeviceProfile::rtx_a6000(),
            &TrainCfg::default(),
        )
    }

    /// The ISSUE acceptance property: across the whole zoo, ≥50 random link
    /// samples each, the warm-started re-solve must return the same
    /// device_set and a delay within 1e-12 (relative) of a cold
    /// `general_partition` — closure edges enabled.
    #[test]
    fn warm_resolve_identical_to_cold_general_across_zoo() {
        for model in models::MODEL_NAMES {
            let c = cg(model);
            let mut planner = PartitionPlanner::new(&c);
            let mut rng = Rng::new(PROP_SEED ^ model.len() as u64);
            for case in 0..50 {
                let link = Link {
                    up_bps: rng.range(1e4, 1e9),
                    down_bps: rng.range(1e4, 1e9),
                };
                let p = Problem::new(&c, link);
                let cold = general_partition(&p);
                let warm = planner.partition(link);
                assert_eq!(
                    warm.device_set, cold.device_set,
                    "{model} case {case}: device sets diverged"
                );
                assert!(
                    (warm.delay - cold.delay).abs() <= 1e-12 * (1.0 + cold.delay.abs()),
                    "{model} case {case}: warm {} vs cold {}",
                    warm.delay,
                    cold.delay
                );
            }
            assert_eq!(planner.solves(), 50);
        }
    }

    /// Fixed seed so the zoo property is deterministic and replayable.
    const PROP_SEED: u64 = 0x9E37_79B9_7F4A_7C15;

    #[test]
    fn planner_uses_linear_fast_path_on_chains() {
        for model in ["lenet5", "alexnet", "vgg16"] {
            let c = cg(model);
            let mut planner = PartitionPlanner::new(&c);
            assert!(planner.flow_size().is_none(), "{model} should be linear");
            for rate in [1e4, 1e6, 1e9] {
                let link = Link::symmetric(rate);
                let cold = linear_scan_partition(&Problem::new(&c, link));
                let warm = planner.partition(link);
                assert_eq!(warm.device_set, cold.device_set, "{model}");
                assert_eq!(warm.delay, cold.delay, "{model}");
            }
        }
    }

    #[test]
    fn planner_respects_options() {
        let c = cg("block-residual");
        for (pin, closure) in [(true, true), (false, true), (true, false)] {
            let mut planner = PartitionPlanner::with_options(&c, pin, closure);
            for rate in [1e5, 1e7] {
                let link = Link::symmetric(rate);
                let mut p = Problem::new(&c, link);
                p.pin_inputs = pin;
                let cold = general_partition_with_options(&p, closure).partition;
                let warm = planner.partition(link);
                assert_eq!(warm.device_set, cold.device_set, "pin={pin} closure={closure}");
            }
        }
    }

    #[test]
    fn warm_resolve_matches_cold_on_random_dags() {
        for_all("planner-random-dags", 40, |rng| {
            let n = 2 + rng.index(24);
            let edges = random_layer_dag(rng, n, 0.3);
            let mut dag = Dag::new();
            for i in 0..n {
                dag.add_node(format!("v{i}"));
            }
            for (u, v) in edges {
                dag.add_edge(u, v, 0.0);
            }
            let xi_s: Vec<f64> = (0..n).map(|_| rng.range(1e-4, 5e-2)).collect();
            let c = CostGraph {
                xi_d: xi_s.iter().map(|&s| s * rng.range(0.5, 20.0)).collect(),
                xi_s,
                act_bytes: (0..n).map(|_| rng.range(1e3, 1e7)).collect(),
                param_bytes: (0..n).map(|_| rng.range(0.0, 1e6)).collect(),
                n_loc: rng.range(1.0, 20.0).round(),
                dag,
            };
            let mut planner = PartitionPlanner::new(&c);
            for _ in 0..8 {
                let link = Link {
                    up_bps: rng.range(1e4, 1e8),
                    down_bps: rng.range(1e4, 1e8),
                };
                let cold = general_partition(&Problem::new(&c, link));
                let warm = planner.partition(link);
                assert_eq!(warm.device_set, cold.device_set);
                assert_eq!(warm.delay, cold.delay);
            }
        });
    }

    #[test]
    fn flow_size_matches_instrumented_run() {
        let c = cg("googlenet");
        let planner = PartitionPlanner::new(&c);
        let run = crate::partition::general::general_partition_instrumented(&Problem::new(
            &c,
            Link::symmetric(1e6),
        ));
        assert_eq!(
            planner.flow_size(),
            Some((run.flow_vertices, run.flow_edges))
        );
    }
}
