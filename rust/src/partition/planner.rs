//! Amortized re-partitioning: build the transformed flow network **once**,
//! re-solve per epoch with an O(E) capacity refresh.
//!
//! The coordinator's loop (Sec. III-A) re-makes the partition decision
//! every epoch as link rates fluctuate, but between epochs only the rates
//! change: the layer DAG, the auxiliary vertices of Fig. 3, and the
//! infinite closure edges are identical every time. The engine that
//! exploits this lives in [`super::fleet`]: every forward-edge capacity is
//! affine in `σ = 1/R_up + 1/R_down`, so a warm re-solve is one O(E)
//! capacity refresh + a Dinic run on reusable scratch, bit-identical to a
//! cold build (PERF.md documents the invariants and layout).
//!
//! [`PartitionPlanner`] is the single-(model, device-tier) view of that
//! engine — a thin wrapper around a one-tier [`FleetPlanner`] with the
//! fleet-level block reduction disabled — and is the type repeated-solve
//! callers hold when they want full-DAG general-engine decisions (the
//! replan bench, the cost-equivalence reference). `blockwise::Planner` is
//! the sibling wrapper with the reduction enabled. Keeping both
//! wrapper-thin means PR-1's warm≡cold property tests below keep pinning
//! the exact arithmetic the fleet facade runs per tier.

use super::fleet::{FleetOptions, FleetPlanner, FleetSpec};
use super::types::{Link, Partition};
use crate::profiles::CostGraph;

/// Amortized per-(model, device-tier) partition planner: the dynamic-edge
/// hot path. Construction does all structural work (transformed-network
/// build, CSR freeze); [`PartitionPlanner::partition`] per epoch is an
/// O(E) capacity refresh + a Dinic solve on reusable scratch.
///
/// Linear models (no parent with multiple children) keep the O(L) scan
/// fast path of Alg. 2 lines 2-4 — already allocation-light, and exactly
/// what the one-shot algorithm does.
pub struct PartitionPlanner {
    fleet: FleetPlanner,
    solves: u64,
}

impl PartitionPlanner {
    /// Plan for the default problem (pinned inputs, closure edges on).
    pub fn new(costs: &CostGraph) -> PartitionPlanner {
        PartitionPlanner::with_options(costs, true, true)
    }

    /// Explicit control over input pinning and closure edges (mirrors
    /// `general_partition_with_options`). The fleet-level block reduction
    /// and the incremental flow-reusing re-solves both stay **off**: this
    /// wrapper's contract is bit-identity with the cold general engine
    /// (the PR-1 warm≡cold property), and it is the reference the fast
    /// paths' cost-equivalence suites diff against. Single-tier callers
    /// who want reduced-DAG solves use
    /// [`crate::partition::blockwise::Planner`], the one-tier wrapper over
    /// the reduction engine.
    pub fn with_options(
        costs: &CostGraph,
        pin_inputs: bool,
        closure_edges: bool,
    ) -> PartitionPlanner {
        PartitionPlanner {
            fleet: FleetPlanner::with_options(
                FleetSpec::single(costs.clone()),
                FleetOptions {
                    pin_inputs,
                    closure_edges,
                    ..FleetOptions::bit_identical()
                },
            ),
            solves: 0,
        }
    }

    /// Solve for the current link state (the per-epoch hot path). Bitwise
    /// identical to a cold `general_partition` on the same problem.
    ///
    /// Every call refreshes + re-solves, bypassing the fleet facade's tier
    /// cache entirely (`take_solve` moves the decision out instead of
    /// cloning it into a cache this wrapper would never read) — the PR-1
    /// contract, and what `solves()`/timing callers count on.
    pub fn partition(&mut self, link: Link) -> Partition {
        self.solves += 1;
        self.fleet.take_solve(0, link)
    }

    /// Number of solves served since construction.
    pub fn solves(&self) -> u64 {
        self.solves
    }

    /// (vertices, edges) of the cached flow network; `None` on the linear
    /// fast path.
    pub fn flow_size(&self) -> Option<(usize, usize)> {
        self.fleet.flow_size()
    }

    /// The cost graph this planner was built for.
    pub fn costs(&self) -> &CostGraph {
        self.fleet.spec().tier_costs(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Dag;
    use crate::models;
    use crate::partition::general::{
        general_partition, general_partition_with_options, linear_scan_partition,
    };
    use crate::partition::types::Problem;
    use crate::profiles::{DeviceProfile, TrainCfg};
    use crate::util::prop::{for_all, random_layer_dag, random_link, zoo_matrix};

    fn cg(model: &str) -> CostGraph {
        let m = models::by_name(model).unwrap();
        CostGraph::build(
            &m,
            &DeviceProfile::jetson_tx2(),
            &DeviceProfile::rtx_a6000(),
            &TrainCfg::default(),
        )
    }

    /// The warm≡cold acceptance property, run over the shared generator
    /// matrix (every zoo model × every Jetson tier, 13 random links per
    /// cell = 52 (tier, link) draws per model): the warm-started re-solve
    /// must return the same device_set and a delay within 1e-12 (relative)
    /// of a cold `general_partition` — closure edges enabled, block
    /// reduction off (this wrapper's bit-identity contract).
    #[test]
    fn warm_resolve_identical_to_cold_general_across_zoo() {
        zoo_matrix("planner-warm-vs-cold", |case, rng| {
            let mut planner = PartitionPlanner::new(&case.costs);
            for i in 0..13 {
                let link = random_link(rng);
                let p = Problem::new(&case.costs, link);
                let cold = general_partition(&p);
                let warm = planner.partition(link);
                assert_eq!(
                    warm.device_set, cold.device_set,
                    "{}/{} link {i}: device sets diverged",
                    case.model, case.tier
                );
                assert!(
                    (warm.delay - cold.delay).abs() <= 1e-12 * (1.0 + cold.delay.abs()),
                    "{}/{} link {i}: warm {} vs cold {}",
                    case.model,
                    case.tier,
                    warm.delay,
                    cold.delay
                );
            }
            assert_eq!(planner.solves(), 13);
        });
    }

    #[test]
    fn planner_uses_linear_fast_path_on_chains() {
        for model in ["lenet5", "alexnet", "vgg16"] {
            let c = cg(model);
            let mut planner = PartitionPlanner::new(&c);
            assert!(planner.flow_size().is_none(), "{model} should be linear");
            for rate in [1e4, 1e6, 1e9] {
                let link = Link::symmetric(rate);
                let cold = linear_scan_partition(&Problem::new(&c, link));
                let warm = planner.partition(link);
                assert_eq!(warm.device_set, cold.device_set, "{model}");
                assert_eq!(warm.delay, cold.delay, "{model}");
            }
        }
    }

    #[test]
    fn planner_respects_options() {
        let c = cg("block-residual");
        for (pin, closure) in [(true, true), (false, true), (true, false)] {
            let mut planner = PartitionPlanner::with_options(&c, pin, closure);
            for rate in [1e5, 1e7] {
                let link = Link::symmetric(rate);
                let mut p = Problem::new(&c, link);
                p.pin_inputs = pin;
                let cold = general_partition_with_options(&p, closure).partition;
                let warm = planner.partition(link);
                assert_eq!(warm.device_set, cold.device_set, "pin={pin} closure={closure}");
            }
        }
    }

    #[test]
    fn warm_resolve_matches_cold_on_random_dags() {
        for_all("planner-random-dags", 40, |rng| {
            let n = 2 + rng.index(24);
            let edges = random_layer_dag(rng, n, 0.3);
            let mut dag = Dag::new();
            for i in 0..n {
                dag.add_node(format!("v{i}"));
            }
            for (u, v) in edges {
                dag.add_edge(u, v, 0.0);
            }
            let xi_s: Vec<f64> = (0..n).map(|_| rng.range(1e-4, 5e-2)).collect();
            let c = CostGraph {
                xi_d: xi_s.iter().map(|&s| s * rng.range(0.5, 20.0)).collect(),
                xi_s,
                act_bytes: (0..n).map(|_| rng.range(1e3, 1e7)).collect(),
                param_bytes: (0..n).map(|_| rng.range(0.0, 1e6)).collect(),
                n_loc: rng.range(1.0, 20.0).round(),
                dag,
            };
            let mut planner = PartitionPlanner::new(&c);
            for _ in 0..8 {
                let link = Link {
                    up_bps: rng.range(1e4, 1e8),
                    down_bps: rng.range(1e4, 1e8),
                };
                let cold = general_partition(&Problem::new(&c, link));
                let warm = planner.partition(link);
                assert_eq!(warm.device_set, cold.device_set);
                assert_eq!(warm.delay, cold.delay);
            }
        });
    }

    #[test]
    fn flow_size_matches_instrumented_run() {
        let c = cg("googlenet");
        let planner = PartitionPlanner::new(&c);
        let run = crate::partition::general::general_partition_instrumented(&Problem::new(
            &c,
            Link::symmetric(1e6),
        ));
        assert_eq!(
            planner.flow_size(),
            Some((run.flow_vertices, run.flow_edges))
        );
    }
}
