//! Alg. 1: building the partition DAG with delay-encoding edge weights.
//!
//! Weight classes (Sec. IV-A.2):
//! * device execution  (v_i → v_S): `N_loc ξ_D,i + k_i/R_D + k_i/R_S`
//! * server execution  (v_D → v_i): `N_loc ξ_S,i`
//! * propagation       (v_i → v_j): `N_loc (a_i/R_D + a_i/R_S)`
//!
//! **Deviation from the paper's Eq. (10), documented in DESIGN.md:** the
//! paper assigns the model-download term `k_i/R_S` to the *server*
//! execution edge, but Eq. (3) sums the download delay over the layers
//! **on the device** (the updated device-side model is distributed to the
//! next device). Encoding it on the server edge would make the cut value
//! differ from Eq. (7) by a non-constant term and break the Theorem 1
//! equivalence (cf. Eq. (A.1), where moving a layer to the device adds
//! *both* k/R_D and k/R_S). We therefore place both model-transfer terms on
//! the device execution edge; with this correction the cut value equals
//! Eq. (7) exactly, which `equivalence_tests` verifies against brute force.

use super::types::Problem;
use crate::graph::{Dag, NodeId};

/// The partition DAG of Alg. 1 plus vertex bookkeeping.
#[derive(Clone, Debug)]
pub struct PartitionDag {
    pub dag: Dag,
    /// Source vertex id (virtual device v_D).
    pub source: NodeId,
    /// Sink vertex id (virtual server v_S).
    pub sink: NodeId,
    /// Graph vertex id of each layer (same order as the cost graph).
    pub layer_vertex: Vec<NodeId>,
}

/// Build the weighted DAG of Alg. 1 (source/sink + three weight classes).
pub fn build_partition_dag(problem: &Problem) -> PartitionDag {
    let c = problem.costs;
    let n = c.len();
    let mut dag = Dag::new();
    let layer_vertex: Vec<NodeId> = (0..n).map(|v| dag.add_node(c.dag.label(v))).collect();
    let source = dag.add_node("v_D");
    let sink = dag.add_node("v_S");

    for v in 0..n {
        // Server execution weight, Eq. (10) (corrected: compute only).
        dag.add_edge(source, layer_vertex[v], c.n_loc * c.xi_s[v]);
        // Device execution weight, Eq. (9) + download term (see module doc).
        let model_transfer =
            c.param_bytes[v] / problem.link.up_bps + c.param_bytes[v] / problem.link.down_bps;
        dag.add_edge(
            layer_vertex[v],
            sink,
            c.n_loc * c.xi_d[v] + model_transfer,
        );
    }
    // Propagation weights, Eq. (11).
    for e in c.dag.edges() {
        let w = c.n_loc
            * (c.act_bytes[e.from] / problem.link.up_bps
                + c.act_bytes[e.from] / problem.link.down_bps);
        dag.add_edge(layer_vertex[e.from], layer_vertex[e.to], w);
    }

    PartitionDag {
        dag,
        source,
        sink,
        layer_vertex,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::partition::types::Link;
    use crate::profiles::{CostGraph, DeviceProfile, TrainCfg};

    fn problem_fixture() -> CostGraph {
        let m = models::by_name("block-residual").unwrap();
        CostGraph::build(
            &m,
            &DeviceProfile::jetson_tx2(),
            &DeviceProfile::rtx_a6000(),
            &TrainCfg::default(),
        )
    }

    #[test]
    fn vertex_and_edge_counts() {
        let cg = problem_fixture();
        let p = Problem::new(&cg, Link::symmetric(1e6));
        let pd = build_partition_dag(&p);
        let n = cg.len();
        // n layers + source + sink.
        assert_eq!(pd.dag.len(), n + 2);
        // 2 edges per layer + one per model edge.
        assert_eq!(pd.dag.num_edges(), 2 * n + cg.dag.num_edges());
        assert!(pd.dag.is_acyclic());
    }

    #[test]
    fn weight_classes_match_equations() {
        let cg = problem_fixture();
        let up = 2e6;
        let down = 4e6;
        let p = Problem::new(&cg, Link { up_bps: up, down_bps: down });
        let pd = build_partition_dag(&p);
        // Check a specific layer's three weights.
        let v = 3; // a conv inside the block
        let sv = pd.layer_vertex[v];
        // Server execution: edge from source.
        let se = pd
            .dag
            .out_edges(pd.source)
            .iter()
            .map(|&e| pd.dag.edge(e))
            .find(|e| e.to == sv)
            .unwrap();
        assert!((se.weight - cg.n_loc * cg.xi_s[v]).abs() < 1e-12);
        // Device execution: edge to sink.
        let de = pd
            .dag
            .out_edges(sv)
            .iter()
            .map(|&e| pd.dag.edge(e))
            .find(|e| e.to == pd.sink)
            .unwrap();
        let expect =
            cg.n_loc * cg.xi_d[v] + cg.param_bytes[v] / up + cg.param_bytes[v] / down;
        assert!((de.weight - expect).abs() < 1e-12);
        // Propagation: any model edge.
        let me = cg.dag.edges()[0];
        let pe = pd
            .dag
            .out_edges(pd.layer_vertex[me.from])
            .iter()
            .map(|&e| pd.dag.edge(e))
            .find(|e| e.to == pd.layer_vertex[me.to])
            .unwrap();
        let expect_prop = cg.n_loc * (cg.act_bytes[me.from] / up + cg.act_bytes[me.from] / down);
        assert!((pe.weight - expect_prop).abs() < 1e-12);
    }
}
