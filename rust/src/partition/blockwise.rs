//! Alg. 4: the block-wise model partitioning algorithm.
//!
//! For every detected block, the intra-block cut test (Theorem 2) checks
//! whether the minimum intra-block transmission `a_B^min` is at least the
//! block-input transmission `a_B^in`; if so, the optimal cut provably never
//! enters the block, and the block collapses to a single vertex whose
//! execution weights are the sums of its members' (Eqs. 17-20). The general
//! algorithm then runs on the much smaller DAG.
//!
//! Generalization over the paper's Alg. 4 (documented in DESIGN.md): the
//! paper falls back to the full DAG if *the* block test fails; here the
//! test is applied per block and only passing blocks are abstracted, which
//! is exact in all cases and never slower than the full fallback.

use super::blocks::{detect_blocks, Block};
use super::fleet::{FleetPlanner, FleetSpec};
use super::general::{general_partition_instrumented, GeneralRun};
use super::types::{Partition, Problem};
use crate::graph::Dag;
use crate::maxflow::{dinic, FlowNetwork};
use crate::profiles::CostGraph;

/// The Theorem-2 reduction plan of one model: the detected blocks and which
/// of them pass the intra-block cut test. Detection reads only the layer
/// DAG and the activation bytes — both model properties shared by every
/// device tier — which is what lets `partition::fleet` compute the plan
/// **once per fleet** and [`Reduction::apply`] it to each tier's cost graph
/// (only the summed execution weights differ between tiers).
pub(crate) struct Reduction {
    blocks_detected: usize,
    abstractable: Vec<Block>,
}

impl Reduction {
    /// Run Alg. 3 detection + the Theorem 2 test on every block.
    pub(crate) fn detect(c: &CostGraph) -> Reduction {
        let blocks = detect_blocks(&c.dag);
        let blocks_detected = blocks.len();
        let abstractable = blocks
            .into_iter()
            .filter(|b| passes_intra_block_test(c, b))
            .collect();
        Reduction {
            blocks_detected,
            abstractable,
        }
    }

    pub(crate) fn blocks_detected(&self) -> usize {
        self.blocks_detected
    }

    pub(crate) fn blocks_abstracted(&self) -> usize {
        self.abstractable.len()
    }

    /// True iff at least one block passed the test, i.e. the reduced DAG is
    /// strictly smaller than the full one.
    pub(crate) fn reduces(&self) -> bool {
        !self.abstractable.is_empty()
    }

    /// Apply the plan to a cost graph sharing the model shape (Eqs. 17-20).
    /// Returns the reduced cost graph and the full→reduced vertex mapping
    /// (the mapping is identical for every tier of a fleet).
    pub(crate) fn apply(&self, c: &CostGraph) -> (CostGraph, Vec<usize>) {
        let refs: Vec<&Block> = self.abstractable.iter().collect();
        reduce(c, &refs)
    }
}

/// Instrumentation of a block-wise run.
#[derive(Clone, Debug)]
pub struct BlockwiseRun {
    pub partition: Partition,
    /// Vertices/edges of the reduced flow network actually solved.
    pub flow_vertices: usize,
    pub flow_edges: usize,
    /// Dinic complexity estimate O(V^2 E) on the reduced network.
    pub complexity: f64,
    pub blocks_detected: usize,
    pub blocks_abstracted: usize,
}

/// Solve the partitioning problem with the block-wise algorithm (Alg. 4).
pub fn blockwise_partition(problem: &Problem) -> Partition {
    blockwise_partition_instrumented(problem).partition
}

/// Alg. 4 with instrumentation.
pub fn blockwise_partition_instrumented(problem: &Problem) -> BlockwiseRun {
    let c = problem.costs;
    let red = Reduction::detect(c);

    if !red.reduces() {
        let run = general_partition_instrumented(problem);
        return BlockwiseRun {
            partition: run.partition,
            flow_vertices: run.flow_vertices,
            flow_edges: run.flow_edges,
            complexity: run.complexity,
            blocks_detected: red.blocks_detected(),
            blocks_abstracted: 0,
        };
    }

    let (reduced, to_reduced) = red.apply(c);
    let reduced_problem = Problem::with_pin(&reduced, problem.link, problem.pin_inputs);
    let run: GeneralRun = general_partition_instrumented(&reduced_problem);

    // Expand the reduced assignment back to the full layer set.
    let device_set: Vec<bool> = to_reduced
        .iter()
        .map(|&r| run.partition.device_set[r])
        .collect();
    debug_assert!(problem.is_feasible(&device_set));
    let partition = problem.partition(device_set);
    debug_assert!(
        (partition.delay - run.partition.delay).abs()
            <= 1e-6 * (1.0 + partition.delay.abs()),
        "reduced delay {} != expanded delay {}",
        run.partition.delay,
        partition.delay
    );

    BlockwiseRun {
        partition,
        flow_vertices: run.flow_vertices,
        flow_edges: run.flow_edges,
        complexity: run.complexity,
        blocks_detected: red.blocks_detected(),
        blocks_abstracted: red.blocks_abstracted(),
    }
}

/// Amortized block-wise planner: the structural work of Alg. 3/4 — block
/// detection, the Theorem 2 tests, the reduction mapping, **and** the
/// transformed flow network of the (reduced) DAG — depends only on the
/// model's DAG and activation sizes, **not** on the link state. The
/// coordinator re-partitions every epoch as rates change (Sec. III-A), so
/// construction does all of that once and each [`Planner::partition`] call
/// is a warm re-solve: an O(E) capacity refresh + a Dinic run on reusable
/// scratch (or the O(L) scan when the reduced DAG is a chain), with no
/// allocation and no topology work. PERF.md quantifies the speedup over
/// the one-shot Alg. 4.
///
/// Since the fleet-level block reduction, this is a thin **one-tier
/// wrapper over the same reduction engine** the fleet facade runs —
/// exactly as [`PartitionPlanner`](super::PartitionPlanner) wraps the
/// unreduced engine — so single-tier and fleet callers cannot drift apart.
pub struct Planner {
    /// Single-tier fleet engine with block reduction enabled.
    fleet: FleetPlanner,
}

impl Planner {
    /// Run detection + Theorem 2 tests + reduction + network build once.
    /// Uses the engine's full fast configuration (reduction + incremental
    /// re-solves), matching what the fleet facade runs per tier.
    pub fn new(costs: &CostGraph) -> Planner {
        Planner {
            fleet: FleetPlanner::with_options(
                FleetSpec::single(costs.clone()),
                crate::partition::fleet::FleetOptions::default(),
            ),
        }
    }

    pub fn blocks_detected(&self) -> usize {
        self.fleet.stats().blocks_detected
    }

    pub fn blocks_abstracted(&self) -> usize {
        self.fleet.stats().blocks_abstracted
    }

    /// Solve for the current link state (the per-epoch hot path). Every
    /// call refreshes + re-solves on the reduced DAG and expands the
    /// decision to the full layer set (evaluated via Eq. (7) on the full
    /// cost graph).
    pub fn partition(&mut self, link: crate::partition::Link) -> Partition {
        self.fleet.take_solve(0, link)
    }
}

/// Theorem 2 test: true iff `a_B^min >= a_B^in`, i.e. the optimal cut
/// cannot profitably enter the block.
pub fn passes_intra_block_test(c: &CostGraph, block: &Block) -> bool {
    let a_in = c.act_bytes[block.input];
    let a_min = intra_block_min_cut(&c.dag, &c.act_bytes, block);
    a_min >= a_in - 1e-9 * a_in.abs()
}

/// Minimum smashed-data transmission of any feasible cut that places the
/// block input on the device and the block output on the server
/// (Sec. VI-A.2's `a_B^min`). Uses the same auxiliary-vertex dedup and
/// precedence edges as the general algorithm, with activation sizes as the
/// only weights.
pub fn intra_block_min_cut(dag: &Dag, act_bytes: &[f64], block: &Block) -> f64 {
    // Local vertex set: block input + members.
    let mut local: Vec<usize> = Vec::with_capacity(block.members.len() + 1);
    local.push(block.input);
    local.extend_from_slice(&block.members);
    let mut index_of = std::collections::HashMap::new();
    for (i, &v) in local.iter().enumerate() {
        index_of.insert(v, i);
    }
    let n = local.len();

    // Internal out-degree decides which vertices get split.
    let mut internal_children: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, &v) in local.iter().enumerate() {
        for ch in dag.children(v) {
            if let Some(&j) = index_of.get(&ch) {
                internal_children[i].push(j);
            }
        }
    }
    let split: Vec<bool> = internal_children.iter().map(|ch| ch.len() > 1).collect();
    let mut exec: Vec<usize> = (0..n).collect();
    let mut next = n;
    for i in 0..n {
        if split[i] {
            exec[i] = next;
            next += 1;
        }
    }
    let mut net = FlowNetwork::new(next);
    for i in 0..n {
        for &j in &internal_children[i] {
            let from = if split[i] { i } else { exec[i] };
            net.add_edge(from, exec[j], act_bytes[local[i]]);
            net.add_edge(exec[j], exec[i], f64::INFINITY);
        }
        if split[i] {
            net.add_edge(exec[i], i, act_bytes[local[i]]);
            net.add_edge(i, exec[i], f64::INFINITY);
        }
    }
    let source = exec[0]; // block input's execution vertex
    let sink = exec[*index_of.get(&block.output).expect("output in block")];
    dinic(&mut net, source, sink).value
}

/// Replace each abstractable block with a single super vertex (Eqs. 17-20).
/// Returns the reduced cost graph and the full→reduced vertex mapping.
fn reduce(c: &CostGraph, blocks: &[&Block]) -> (CostGraph, Vec<usize>) {
    let n = c.len();
    // group[v] = block index if v is a member of an abstracted block.
    let mut group: Vec<Option<usize>> = vec![None; n];
    for (bi, b) in blocks.iter().enumerate() {
        for &v in &b.members {
            debug_assert!(group[v].is_none(), "blocks must not overlap");
            group[v] = Some(bi);
        }
    }

    let mut dag = Dag::new();
    let mut to_reduced = vec![usize::MAX; n];
    let mut xi_d = Vec::new();
    let mut xi_s = Vec::new();
    let mut act_bytes = Vec::new();
    let mut param_bytes = Vec::new();
    let mut block_vertex: Vec<Option<usize>> = vec![None; blocks.len()];

    let order = c.dag.topo_order().expect("acyclic");
    for &v in &order {
        match group[v] {
            None => {
                let id = dag.add_node(c.dag.label(v));
                to_reduced[v] = id;
                xi_d.push(c.xi_d[v]);
                xi_s.push(c.xi_s[v]);
                act_bytes.push(c.act_bytes[v]);
                param_bytes.push(c.param_bytes[v]);
            }
            Some(bi) => {
                let id = *block_vertex[bi].get_or_insert_with(|| {
                    let id = dag.add_node(format!("block_{bi}"));
                    // Eqs. (17)/(18): summed execution weights; activation
                    // of the super vertex is the block output's (the only
                    // member visible to the outside, by closedness).
                    xi_d.push(blocks[bi].members.iter().map(|&u| c.xi_d[u]).sum());
                    xi_s.push(blocks[bi].members.iter().map(|&u| c.xi_s[u]).sum());
                    act_bytes.push(c.act_bytes[blocks[bi].output]);
                    param_bytes.push(
                        blocks[bi].members.iter().map(|&u| c.param_bytes[u]).sum(),
                    );
                    id
                });
                to_reduced[v] = id;
            }
        }
    }

    // Rebuild edges through the mapping, dropping internal and duplicate
    // edges (Eq. (19): one edge from a block parent suffices).
    let mut seen = std::collections::HashSet::new();
    for e in c.dag.edges() {
        let from = to_reduced[e.from];
        let to = to_reduced[e.to];
        if from == to {
            continue; // intra-block edge
        }
        if seen.insert((from, to)) {
            dag.add_edge(from, to, 0.0);
        }
    }

    let reduced = CostGraph {
        dag,
        xi_d,
        xi_s,
        act_bytes,
        param_bytes,
        n_loc: c.n_loc,
    };
    (reduced, to_reduced)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::partition::general::general_partition;
    use crate::partition::types::Link;
    use crate::profiles::{DeviceProfile, TrainCfg};

    fn cg(model: &str) -> CostGraph {
        let m = models::by_name(model).unwrap();
        CostGraph::build(
            &m,
            &DeviceProfile::jetson_tx2(),
            &DeviceProfile::rtx_a6000(),
            &TrainCfg::default(),
        )
    }

    #[test]
    fn residual_block_passes_theorem2_test() {
        // Identity residual: every *internal* cut crosses the skip too and
        // costs 2 a_in; the overall minimum is the input cut itself, so
        // a_min == a_in and the Theorem 2 condition holds with equality.
        let c = cg("block-residual");
        let blocks = detect_blocks(&c.dag);
        assert_eq!(blocks.len(), 1);
        assert!(passes_intra_block_test(&c, &blocks[0]));
        let a_min = intra_block_min_cut(&c.dag, &c.act_bytes, &blocks[0]);
        let a_in = c.act_bytes[blocks[0].input];
        assert!((a_min - a_in).abs() < 1e-6 * a_in, "a_min={a_min} a_in={a_in}");
    }

    #[test]
    fn blockwise_matches_general_on_blocknets() {
        for model in ["block-residual", "block-inception", "block-dense"] {
            let c = cg(model);
            for rate in [1e5, 1e6, 1e7, 1e9] {
                let p = Problem::new(&c, Link::symmetric(rate));
                let g = general_partition(&p);
                let b = blockwise_partition(&p);
                assert!(
                    (g.delay - b.delay).abs() <= 1e-9 * (1.0 + g.delay),
                    "{model} rate={rate}: general {} vs blockwise {}",
                    g.delay,
                    b.delay
                );
            }
        }
    }

    #[test]
    fn blockwise_matches_general_on_full_models() {
        for model in ["resnet18", "googlenet", "resnet50", "densenet121", "gpt2"] {
            let c = cg(model);
            let p = Problem::new(&c, Link::symmetric(2e6));
            let g = general_partition(&p);
            let b = blockwise_partition(&p);
            assert!(
                (g.delay - b.delay).abs() <= 1e-9 * (1.0 + g.delay),
                "{model}: general {} vs blockwise {}",
                g.delay,
                b.delay
            );
        }
    }

    #[test]
    fn blockwise_shrinks_the_flow_network() {
        // ResNet/DenseNet blocks all pass the Theorem 2 test (skip paths
        // make internal cuts at least as wide as the input), so the graph
        // collapses dramatically. On GoogLeNet several mid-network
        // inception blocks genuinely fail the test on our profile (the sum
        // of branch bottleneck widths is smaller than the block input, e.g.
        // i4a: 192+96+16+64 = 368 < 480 channels) and stay expanded — the
        // reduction is real but smaller (see EXPERIMENTS.md fig7/fig8
        // notes).
        for (model, min_shrink) in
            [("resnet18", 2.0), ("densenet121", 2.0), ("googlenet", 1.3)]
        {
            let c = cg(model);
            let p = Problem::new(&c, Link::symmetric(2e6));
            let g = general_partition_instrumented(&p);
            let b = blockwise_partition_instrumented(&p);
            assert!(
                (b.flow_vertices as f64) < g.flow_vertices as f64 / min_shrink,
                "{model}: {} vs {}",
                b.flow_vertices,
                g.flow_vertices
            );
            assert!(b.complexity < g.complexity, "{model}");
            assert!(b.blocks_abstracted > 0, "{model}");
        }
    }

    #[test]
    fn planner_matches_one_shot_blockwise_across_links() {
        for model in ["resnet18", "googlenet", "gpt2", "lenet5"] {
            let c = cg(model);
            let mut planner = Planner::new(&c);
            for rate in [1e4, 1e6, 1e8] {
                let link = Link::symmetric(rate);
                let p = Problem::new(&c, link);
                let one_shot = blockwise_partition(&p);
                let planned = planner.partition(link);
                assert!(
                    (one_shot.delay - planned.delay).abs() <= 1e-9 * (1.0 + one_shot.delay),
                    "{model} rate={rate}: {} vs {}",
                    one_shot.delay,
                    planned.delay
                );
            }
        }
    }

    #[test]
    fn linear_model_falls_through_to_general() {
        let c = cg("lenet5");
        let p = Problem::new(&c, Link::symmetric(1e6));
        let b = blockwise_partition_instrumented(&p);
        assert_eq!(b.blocks_detected, 0);
        let g = general_partition(&p);
        assert!((g.delay - b.partition.delay).abs() < 1e-12);
    }
}
