//! Churn-tolerant planning as a service: the degraded-mode epoch loop
//! around [`JointPlanner`] (PR 6).
//!
//! The engines below this layer are exact and infallible *given* their
//! inputs; a real edge deployment does not get that luxury. Link reports
//! arrive late or not at all, the fleet churns mid-training
//! ([`SpecDelta`]), and an epoch's decision must ship by a deadline even
//! when the solver would want more time. [`PlannerService`] absorbs all
//! three without ever emitting an infeasible decision:
//!
//! * **Simulated clock.** Every input carries a caller tick
//!   ([`PlannerService::report`]) and every epoch names its own
//!   ([`PlannerService::plan_epoch`]); no wall-clock is read anywhere, so
//!   every degraded-path behavior is deterministic and replayable in
//!   tests (the `ChurnScript` harness in `util::prop`).
//! * **Staleness policy.** A device whose newest link report is older
//!   than [`ServiceOptions::staleness_bound`] ticks is not re-planned
//!   against that lie; it is served its last-good decision marked
//!   [`DecisionProvenance::Degraded`]`(`[`DegradedReason::StaleLink`]`)`.
//!   The fallback is always *feasible*: cut feasibility (lower-set +
//!   pinned inputs) is link-independent, only the cost moves — and the
//!   cost error is bounded by the stale-σ envelope (PERF.md PR 6: delay
//!   is affine in σ for a fixed cut, so serving the σ-stale optimum costs
//!   at most `(B_served + B_opt)·|Δσ|` over the true optimum, with `B`
//!   the cut's transmitted bytes). A device that has *never* been planned
//!   is bootstrapped with its stale link instead (a decision must exist),
//!   still marked degraded. Recovery is automatic: the next fresh report
//!   re-plans.
//! * **Solve-budget deadline.** [`ServiceOptions::solve_budget`] caps the
//!   dirty `(tier, link)` groups an epoch may re-solve (the unit of
//!   planner work — the batched-refresh invariant of `partition::fleet`).
//!   Cache-clean groups are free; groups containing a never-planned
//!   device are exempt (a first decision cannot be deferred); everything
//!   past the cap is served last-good marked
//!   [`DegradedReason::BudgetExceeded`]. The walk order is the canonical
//!   `(tier, link)` sort, so budget exhaustion is deterministic too.
//! * **No cache poisoning.** Degraded serving never touches the planner:
//!   warm flows, tier decision caches and counters only move when a
//!   fresh solve is actually admitted — pinned by the churn suite's
//!   replay-equivalence property (RESILIENCE.md): after any event
//!   stream ending in spec S, a full fresh-report epoch produces
//!   decisions bit-identical to a planner built cold at S.
//!
//! All provenance is accounted in one place: the wrapped planner's
//! [`FleetStats`] (`degraded_decisions`, `retired_decisions`,
//! `spec_deltas`) plus the service's own per-reason counters.

use super::fleet::{
    DecisionProvenance, DegradedReason, FleetSpec, FleetStats, PlanDecision, PlanRequest,
    SpecDelta, SpecError,
};
use super::joint::{JointOptions, JointPlanner};
use super::types::Link;

/// A non-monotone epoch tick: the caller asked to plan at `now`, but the
/// service clock already advanced to `latest`. A long-lived daemon treats
/// this as a degradable input fault (serve last-good for the epoch), not
/// a panic — see the `daemon` module.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ClockError {
    /// The tick the rejected `plan_epoch` call named.
    pub now: u64,
    /// The newest tick the service has already planned at.
    pub latest: u64,
}

impl std::fmt::Display for ClockError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "epoch tick {} is behind the service clock {}",
            self.now, self.latest
        )
    }
}

impl std::error::Error for ClockError {}

/// A malformed link report, refused by [`PlannerService::try_report`]
/// with the inbox untouched — the same contract the daemon's `Coalescer`
/// already gives these inputs (`daemon::ingest::IngestError`), now
/// uniform across both entry points: a bad report through the direct
/// service path is counted and dropped, not a crashed epoch loop. The
/// panicking [`PlannerService::report`] wrapper remains for test callers
/// that treat a bad report as a bug.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReportError {
    /// A non-finite or non-positive rate ([`Link::is_valid`]).
    NonPositiveRate { device: usize },
    /// The report names a device slot outside the fleet.
    UnknownDevice { device: usize },
}

impl std::fmt::Display for ReportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ReportError::NonPositiveRate { device } => {
                write!(f, "rates must be positive and finite (device {device})")
            }
            ReportError::UnknownDevice { device } => {
                write!(f, "report for unknown device slot {device}")
            }
        }
    }
}

impl std::error::Error for ReportError {}

/// Construction-time policy of the service layer. The default is the
/// transparent configuration — no staleness bound, no budget — under
/// which [`PlannerService::plan_epoch`] is a pass-through batch plan.
#[derive(Clone, Copy, Debug)]
pub struct ServiceOptions {
    /// A link report older than this many ticks (strictly) is stale.
    /// `0` means only reports from the current tick are trusted;
    /// `u64::MAX` (default) trusts any report forever.
    pub staleness_bound: u64,
    /// Dirty `(tier, link)` solve groups an epoch may admit before
    /// degrading the rest to last-good. `u64::MAX` (default) = no
    /// deadline.
    pub solve_budget: u64,
    /// Switches of the wrapped [`JointPlanner`].
    pub joint: JointOptions,
}

impl Default for ServiceOptions {
    fn default() -> ServiceOptions {
        ServiceOptions {
            staleness_bound: u64::MAX,
            solve_budget: u64::MAX,
            joint: JointOptions::default(),
        }
    }
}

/// The per-device lane an epoch sorts each slot into (see
/// [`PlannerService::plan_epoch`]).
enum Lane {
    /// Fresh report (or stale-bootstrap): goes into the planner batch.
    /// `stale` marks the bootstrap case — solved now, but against a
    /// stale link, so the emitted provenance is degraded.
    Plan { link: Link, stale: bool },
    /// Stale report with a cached decision: served last-good.
    Serve,
    /// Budget-denied solve group member: served last-good.
    Deferred,
    /// Departed, or no report ever received: no decision this epoch.
    Silent,
}

/// The churn-tolerant planning service: a [`JointPlanner`] behind a
/// report inbox, a staleness/deadline policy, and per-device last-good
/// decision caches. See the module docs for the contracts.
pub struct PlannerService {
    planner: JointPlanner,
    options: ServiceOptions,
    /// Newest link report per device slot: `(link, tick)`.
    reports: Vec<Option<(Link, u64)>>,
    /// Last decision the planner produced per device slot — the degraded
    /// fallback. Cleared when the device departs or migrates tiers.
    last_good: Vec<Option<PlanDecision>>,
    /// Per-slot forced-staleness flag: set by [`PlannerService::
    /// expire_report`] (the daemon's lease-expiry hook), cleared by the
    /// next accepted report. A flagged device is treated as stale this
    /// epoch regardless of the staleness bound.
    forced_stale: Vec<bool>,
    /// The service's simulated clock (the newest `plan_epoch` tick).
    now: u64,
    degraded_stale: u64,
    degraded_budget: u64,
    refused_reports: u64,
}

impl PlannerService {
    /// A service over a fresh planner for `spec`.
    pub fn new(spec: FleetSpec, options: ServiceOptions) -> PlannerService {
        let n = spec.num_devices();
        PlannerService {
            planner: JointPlanner::new(spec, options.joint),
            options,
            reports: vec![None; n],
            last_good: vec![None; n],
            forced_stale: vec![false; n],
            now: 0,
            degraded_stale: 0,
            degraded_budget: 0,
            refused_reports: 0,
        }
    }

    /// Record a device's link report at caller tick `tick`. Newer reports
    /// replace older ones; an out-of-order (older-tick) report is dropped
    /// — the inbox keeps the freshest fact only. A malformed report (bad
    /// rates, unknown slot) is refused with a typed [`ReportError`],
    /// counted in [`PlannerService::refused_reports`], and leaves the
    /// inbox untouched.
    ///
    /// An equal-tick re-delivery may refresh the stored link but does
    /// **not** clear a forced-stale lease ([`PlannerService::
    /// expire_report`]): only a strictly newer tick carries the new
    /// information recovery requires — a replayed report must not
    /// silently un-degrade a lease-expired device.
    pub fn try_report(&mut self, device: usize, link: Link, tick: u64) -> Result<(), ReportError> {
        if !link.is_valid() {
            self.refused_reports += 1;
            return Err(ReportError::NonPositiveRate { device });
        }
        if device >= self.reports.len() {
            self.refused_reports += 1;
            return Err(ReportError::UnknownDevice { device });
        }
        match self.reports[device] {
            Some((_, have)) if tick < have => {} // out-of-order: drop
            Some((_, have)) => {
                self.reports[device] = Some((link, tick));
                if tick > have {
                    self.forced_stale[device] = false;
                }
            }
            None => {
                self.reports[device] = Some((link, tick));
                self.forced_stale[device] = false;
            }
        }
        Ok(())
    }

    /// Panicking convenience over [`PlannerService::try_report`] for
    /// callers that treat a malformed report as a bug.
    pub fn report(&mut self, device: usize, link: Link, tick: u64) {
        if let Err(e) = self.try_report(device, link, tick) {
            panic!("{e}");
        }
    }

    /// Force a device's report stale *now*, ahead of the staleness bound:
    /// the daemon's report-lease expiry hook (`daemon::timeq`). The next
    /// epoch serves the device last-good marked
    /// [`DegradedReason::StaleLink`] (or bootstrap-solves, still marked
    /// degraded, if it was never planned); the next accepted report
    /// clears the flag. A no-op on out-of-range slots.
    pub fn expire_report(&mut self, device: usize) {
        if let Some(f) = self.forced_stale.get_mut(device) {
            *f = true;
        }
    }

    /// Apply one churn event: forwarded to the planner (spec + SoA state)
    /// and mirrored onto the service's per-device caches — departing
    /// devices lose their report and last-good entries (a re-join must
    /// not inherit a predecessor's state), a migrated device keeps its
    /// report (the link is the device's, not the tier's) but drops its
    /// last-good decision (that belonged to the old tier). A malformed
    /// delta is rejected with a typed [`SpecError`] before anything —
    /// planner or service caches — moves.
    pub fn try_apply_delta(&mut self, delta: &SpecDelta) -> Result<(), SpecError> {
        self.planner.spec().validate(delta)?;
        // Devices a retirement detaches, snapshotted before the spec moves.
        let clear: Vec<usize> = match delta {
            SpecDelta::RetireTier { tier } => (0..self.planner.spec().num_devices())
                .filter(|&d| self.planner.spec().tier_of_opt(d) == Some(*tier))
                .collect(),
            SpecDelta::RemoveDevice { device } => vec![*device],
            _ => Vec::new(),
        };
        self.planner
            .try_apply_delta(delta)
            .expect("validated above against the same spec");
        let n = self.planner.spec().num_devices();
        self.reports.resize(n, None);
        self.last_good.resize(n, None);
        self.forced_stale.resize(n, false);
        for d in clear {
            self.reports[d] = None;
            self.last_good[d] = None;
            self.forced_stale[d] = false;
        }
        if let SpecDelta::MigrateDevice { device, .. } = delta {
            self.last_good[*device] = None;
        }
        Ok(())
    }

    /// Panicking convenience over [`PlannerService::try_apply_delta`] for
    /// callers that treat a malformed delta as a bug.
    pub fn apply_delta(&mut self, delta: &SpecDelta) {
        if let Err(e) = self.try_apply_delta(delta) {
            panic!("malformed churn event: {e}");
        }
    }

    /// Immediately expire a retired tier's archived decision (see
    /// [`super::fleet::FleetPlanner::expire_retired`] — the daemon's
    /// retire-TTL hook).
    pub fn expire_retired(&mut self, tier: usize) {
        self.planner.expire_retired(tier);
    }

    /// Serve one epoch at service tick `now` (monotone): one decision per
    /// active, ever-reported device, in device-slot order. Fresh-reported
    /// devices are batched through one [`JointPlanner::plan`] call (the
    /// joint coupling sees the whole epoch at once); stale or
    /// budget-denied devices are served their last-good decision with a
    /// [`DecisionProvenance::Degraded`] marking and zero planner traffic.
    ///
    /// A tick behind the service clock is rejected with a typed
    /// [`ClockError`] and **no state change** — a misbehaving producer
    /// degrades one epoch, it does not panic the daemon (the old
    /// monotone-clock `assert!`).
    pub fn plan_epoch(&mut self, now: u64) -> Result<Vec<PlanDecision>, ClockError> {
        if now < self.now {
            return Err(ClockError {
                now,
                latest: self.now,
            });
        }
        self.now = now;

        // Lane classification, device-slot order.
        let n = self.planner.spec().num_devices();
        debug_assert_eq!(self.reports.len(), n);
        let mut lanes: Vec<Lane> = Vec::with_capacity(n);
        for d in 0..n {
            let lane = match (self.planner.spec().tier_of_opt(d), self.reports[d]) {
                (None, _) | (Some(_), None) => Lane::Silent,
                (Some(_), Some((link, tick))) => {
                    let stale = self.forced_stale[d]
                        || now.saturating_sub(tick) > self.options.staleness_bound;
                    if !stale {
                        Lane::Plan { link, stale: false }
                    } else if self.last_good[d].is_some() {
                        Lane::Serve
                    } else {
                        // Stale but never decided: a decision must exist,
                        // so bootstrap-solve against the stale link.
                        Lane::Plan { link, stale: true }
                    }
                }
            };
            lanes.push(lane);
        }

        // σ-quantization precedes the deadline walk: the walk compares
        // links against the tier caches, so bucket siblings must already
        // sit on their canonical representative or they would be
        // misclassified as dirty. The planner's own re-quantization of
        // the admitted batch below is then the identity (each rewrite
        // counts once).
        let snap_reqs: Vec<PlanRequest> = lanes
            .iter()
            .enumerate()
            .filter_map(|(d, lane)| match lane {
                Lane::Plan { link, .. } => Some(PlanRequest {
                    device: d,
                    tier: self.planner.spec().tier_of(d),
                    link: *link,
                }),
                _ => None,
            })
            .collect();
        if let Some(snapped) = self.planner.quantize_requests(&snap_reqs) {
            for r in &snapped {
                if let Lane::Plan { link, .. } = &mut lanes[r.device] {
                    *link = r.link;
                }
            }
        }

        // Deadline walk: charge one budget unit per dirty (tier, link)
        // group, in canonical group order. Cache-clean groups are free;
        // groups carrying a first-ever decision are exempt from denial
        // (but still charged).
        let mut groups: Vec<((usize, u64, u64), Link, Vec<usize>, bool)> = Vec::new();
        let mut group_of: std::collections::HashMap<(usize, u64, u64), usize> =
            std::collections::HashMap::new();
        for (d, lane) in lanes.iter().enumerate() {
            if let Lane::Plan { link, .. } = lane {
                let tier = self.planner.spec().tier_of(d);
                let key = (tier, link.up_bps.to_bits(), link.down_bps.to_bits());
                let g = *group_of.entry(key).or_insert_with(|| {
                    groups.push((key, *link, Vec::new(), false));
                    groups.len() - 1
                });
                groups[g].2.push(d);
                if self.last_good[d].is_none() {
                    groups[g].3 = true;
                }
            }
        }
        groups.sort_by_key(|(key, ..)| *key);
        let mut used = 0u64;
        for (key, link, members, exempt) in &groups {
            let cost: u64 = if self.planner.cached_link(key.0) == Some(*link) {
                0
            } else {
                1
            };
            if cost == 0 || *exempt || used.saturating_add(cost) <= self.options.solve_budget {
                used = used.saturating_add(cost);
            } else {
                for &d in members {
                    lanes[d] = Lane::Deferred;
                }
            }
        }

        // One batched plan call for every admitted device, slot order.
        let mut reqs: Vec<PlanRequest> = Vec::new();
        for (d, lane) in lanes.iter().enumerate() {
            if let Lane::Plan { link, .. } = lane {
                reqs.push(PlanRequest {
                    device: d,
                    tier: self.planner.spec().tier_of(d),
                    link: *link,
                });
            }
        }
        let planned = if reqs.is_empty() {
            Vec::new()
        } else {
            self.planner.plan(&reqs)
        };

        // Assemble the epoch's answers in device-slot order; degraded
        // lanes clone last-good and never touch the planner.
        let mut degraded = 0u64;
        let mut out: Vec<PlanDecision> = Vec::with_capacity(reqs.len());
        let mut planned_iter = planned.into_iter();
        for (d, lane) in lanes.iter().enumerate() {
            match lane {
                Lane::Silent => {}
                Lane::Plan { stale, .. } => {
                    let decision = planned_iter.next().expect("one decision per request");
                    debug_assert_eq!(decision.device, d);
                    self.last_good[d] = Some(decision.clone());
                    let mut decision = decision;
                    if *stale {
                        decision.provenance =
                            DecisionProvenance::Degraded(DegradedReason::StaleLink);
                        degraded += 1;
                        self.degraded_stale += 1;
                    }
                    out.push(decision);
                }
                Lane::Serve => {
                    let mut decision = self.last_good[d]
                        .clone()
                        .expect("Serve lane requires a cached decision");
                    decision.stats.refreshed = false;
                    decision.provenance = DecisionProvenance::Degraded(DegradedReason::StaleLink);
                    degraded += 1;
                    self.degraded_stale += 1;
                    out.push(decision);
                }
                Lane::Deferred => {
                    let mut decision = self.last_good[d]
                        .clone()
                        .expect("budget deferral requires a cached decision");
                    decision.stats.refreshed = false;
                    decision.provenance =
                        DecisionProvenance::Degraded(DegradedReason::BudgetExceeded);
                    degraded += 1;
                    self.degraded_budget += 1;
                    out.push(decision);
                }
            }
        }
        self.planner.note_degraded(degraded);
        Ok(out)
    }

    /// The service's simulated clock: the newest `plan_epoch` tick.
    pub fn now(&self) -> u64 {
        self.now
    }

    /// The wrapped planner (read access: makespan, congestion, spec).
    pub fn planner(&self) -> &JointPlanner {
        &self.planner
    }

    /// Direct mutable access to the wrapped planner — the pass-through
    /// path for callers that manage their own epoch loop (e.g. the
    /// simulator's non-churn scenarios) and only want the service for
    /// churn bookkeeping. Bypasses every policy above.
    pub fn planner_mut(&mut self) -> &mut JointPlanner {
        &mut self.planner
    }

    /// The fleet this service plans for.
    pub fn spec(&self) -> &FleetSpec {
        self.planner.spec()
    }

    /// The wrapped planner's counters (degraded/retired decisions and
    /// spec deltas included — see [`FleetStats`]).
    pub fn stats(&self) -> FleetStats {
        self.planner.stats()
    }

    /// The policy this service was built with.
    pub fn options(&self) -> ServiceOptions {
        self.options
    }

    /// Decisions degraded for staleness so far.
    pub fn degraded_stale(&self) -> u64 {
        self.degraded_stale
    }

    /// Decisions degraded for budget exhaustion so far.
    pub fn degraded_budget(&self) -> u64 {
        self.degraded_budget
    }

    /// Malformed reports refused by [`PlannerService::try_report`] so far
    /// (surfaced as `fastsplit_report_refusals_total` in the daemon's
    /// metrics).
    pub fn refused_reports(&self) -> u64 {
        self.refused_reports
    }

    /// The last planner decision cached for a device, if any.
    pub fn last_good(&self, device: usize) -> Option<&PlanDecision> {
        self.last_good.get(device).and_then(|d| d.as_ref())
    }

    /// Export the crash-surviving state of this service (see
    /// [`ServiceImage`]); the byte codec lives in `daemon::snapshot`.
    pub(crate) fn export_image(&self) -> ServiceImage {
        ServiceImage {
            options: self.options,
            joint: self.planner.export_image(),
            reports: self.reports.clone(),
            last_good: self.last_good.clone(),
            forced_stale: self.forced_stale.clone(),
            now: self.now,
            degraded_stale: self.degraded_stale,
            degraded_budget: self.degraded_budget,
            refused_reports: self.refused_reports,
        }
    }

    /// Rebuild a service from a recovered image. The policy comes out of
    /// the image itself (recovery is self-contained — no caller-side
    /// config has to survive the crash), the planner is rebuilt through
    /// [`JointPlanner::from_image`], and the inbox / last-good / lease
    /// state continues verbatim.
    pub(crate) fn from_image(img: ServiceImage) -> PlannerService {
        let n = img.reports.len();
        assert_eq!(n, img.last_good.len(), "one last-good slot per device");
        assert_eq!(n, img.forced_stale.len(), "one lease flag per device");
        PlannerService {
            planner: JointPlanner::from_image(img.joint),
            options: img.options,
            reports: img.reports,
            last_good: img.last_good,
            forced_stale: img.forced_stale,
            now: img.now,
            degraded_stale: img.degraded_stale,
            degraded_budget: img.degraded_budget,
            refused_reports: img.refused_reports,
        }
    }
}

/// Plain-data image of a [`PlannerService`] for the daemon's crash
/// snapshots: the policy, the wrapped [`JointPlanner`]'s image, and every
/// per-device table (report inbox, last-good decisions, forced-stale
/// lease flags) plus the service clock and degradation counters. The byte
/// codec lives in `daemon::snapshot`.
pub(crate) struct ServiceImage {
    pub(crate) options: ServiceOptions,
    pub(crate) joint: super::joint::JointImage,
    pub(crate) reports: Vec<Option<(Link, u64)>>,
    pub(crate) last_good: Vec<Option<PlanDecision>>,
    pub(crate) forced_stale: Vec<bool>,
    pub(crate) now: u64,
    pub(crate) degraded_stale: u64,
    pub(crate) degraded_budget: u64,
    pub(crate) refused_reports: u64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::partition::fleet::{FleetOptions, FleetPlanner};
    use crate::partition::general::general_partition;
    use crate::partition::types::Problem;
    use crate::profiles::{CostGraph, DeviceProfile, TrainCfg};
    use crate::util::prop::{assert_cut_cost_equal, assert_stale_sigma_envelope, churn_script};
    use crate::util::rng::Rng;

    const REPLAY_MODELS: [&str; 3] = ["googlenet", "block-residual", "block-inception"];

    fn spec_for(model: &str, devices: usize) -> FleetSpec {
        let m = models::by_name(model).unwrap();
        FleetSpec::from_fleet(&DeviceProfile::fleet_of(devices), |d| {
            CostGraph::build(&m, d, &DeviceProfile::rtx_a6000(), &TrainCfg::default())
        })
    }

    fn assert_decisions_bit_identical(a: &[PlanDecision], b: &[PlanDecision], context: &str) {
        assert_eq!(a.len(), b.len(), "{context}: decision counts differ");
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.device, y.device, "{context}");
            assert_eq!(x.tier, y.tier, "{context}");
            assert_eq!(x.cut_layer, y.cut_layer, "{context}");
            assert_eq!(x.partition.device_set, y.partition.device_set, "{context}");
            assert_eq!(
                x.partition.delay.to_bits(),
                y.partition.delay.to_bits(),
                "{context}"
            );
        }
    }

    /// The planner-side solve accounting the replay pin checks against: in
    /// one epoch the fleet solves each dirty `(tier, link)` group once, in
    /// canonical `(tier, link-bits)` order, and leaves the tier's warm
    /// cache at the group processed last. Returns the epoch's solve count
    /// and updates `tier_cache` exactly as the planner would.
    fn expected_epoch_solves(
        spec: &FleetSpec,
        latest: &[Option<Link>],
        tier_cache: &mut [Option<Link>],
    ) -> u64 {
        let mut groups: Vec<(usize, u64, u64, Link)> = (0..spec.num_devices())
            .filter_map(|d| {
                let tier = spec.tier_of_opt(d)?;
                let link = latest[d]?;
                Some((tier, link.up_bps.to_bits(), link.down_bps.to_bits(), link))
            })
            .collect();
        groups.sort_by_key(|&(t, u, dn, _)| (t, u, dn));
        groups.dedup_by_key(|&mut (t, u, dn, _)| (t, u, dn));
        let mut solves = 0;
        for &(tier, _, _, link) in &groups {
            if tier_cache[tier] != Some(link) {
                solves += 1;
                tier_cache[tier] = Some(link);
            }
        }
        solves
    }

    /// With the default (transparent) options the service is a
    /// pass-through: every epoch's decisions are bit-identical to calling
    /// the planner directly with the same batch.
    #[test]
    fn churn_transparent_service_is_a_pass_through() {
        let spec = spec_for("googlenet", 6);
        let mut service = PlannerService::new(spec.clone(), ServiceOptions::default());
        let mut direct = JointPlanner::new(spec, JointOptions::default());
        for epoch in 0..4u64 {
            let reqs = direct.spec().requests(|t| Link {
                up_bps: 2e5 * (1.0 + t as f64) * (1.0 + 0.31 * epoch as f64),
                down_bps: 8e5 * (1.0 + t as f64) * (1.0 + 0.17 * epoch as f64),
            });
            for r in &reqs {
                service.report(r.device, r.link, epoch);
            }
            let got = service.plan_epoch(epoch).unwrap();
            let want = direct.plan(&reqs);
            assert_decisions_bit_identical(&got, &want, "pass-through epoch");
            assert!(got
                .iter()
                .all(|d| !matches!(d.provenance, DecisionProvenance::Degraded(_))));
        }
        assert_eq!(service.stats().degraded_decisions, 0);
        assert_eq!(service.degraded_stale() + service.degraded_budget(), 0);
    }

    /// Staleness policy: a withheld report degrades the device to its
    /// last-good decision (feasible, zero planner traffic); the next
    /// fresh report recovers it. The degraded epoch must not poison the
    /// warm caches — recovery solves exactly like an uninterrupted run.
    #[test]
    fn churn_stale_reports_degrade_then_recover() {
        let spec = spec_for("googlenet", 4);
        let mut service = PlannerService::new(
            spec,
            ServiceOptions {
                staleness_bound: 0,
                ..ServiceOptions::default()
            },
        );
        let fresh = Link::symmetric(5e5);
        for d in 0..4 {
            service.report(d, fresh, 0);
        }
        let e0 = service.plan_epoch(0).unwrap();
        assert_eq!(e0.len(), 4);
        let solves_after_e0 = service.stats().solves();

        // Epoch 1: device 2's report is withheld → degraded last-good.
        let drifted = Link::symmetric(3e5);
        for d in [0usize, 1, 3] {
            service.report(d, drifted, 1);
        }
        let e1 = service.plan_epoch(1).unwrap();
        assert_eq!(e1.len(), 4);
        let stale_d = e1.iter().find(|d| d.device == 2).unwrap();
        assert_eq!(
            stale_d.provenance,
            DecisionProvenance::Degraded(DegradedReason::StaleLink)
        );
        assert_eq!(
            stale_d.partition.device_set,
            e0.iter()
                .find(|d| d.device == 2)
                .unwrap()
                .partition
                .device_set,
            "the degraded decision is the cached one"
        );
        let tier = service.spec().tier_of(2);
        let costs = service.spec().tier_costs(tier).clone();
        let problem = Problem::new(&costs, drifted);
        assert!(
            problem.is_feasible(&stale_d.partition.device_set),
            "degraded decisions stay feasible under the true link"
        );
        assert_eq!(service.stats().degraded_decisions, 1);
        assert_eq!(service.degraded_stale(), 1);

        // Epoch 2: the report returns → fresh re-plan, no residue: the
        // recovered cost matches a cold reference solve.
        for d in 0..4 {
            service.report(d, drifted, 2);
        }
        let e2 = service.plan_epoch(2).unwrap();
        assert!(e2
            .iter()
            .all(|d| !matches!(d.provenance, DecisionProvenance::Degraded(_))));
        let rec = e2.iter().find(|d| d.device == 2).unwrap();
        let cold = general_partition(&problem);
        assert_cut_cost_equal(&problem, &rec.partition, &cold);
        assert!(
            service.stats().solves() > solves_after_e0,
            "recovery re-plans on the fresh report"
        );
    }

    /// Deadline policy: with a one-group budget, the canonical walk
    /// admits the first dirty group and degrades the rest to last-good,
    /// marked `BudgetExceeded`; a later epoch catches the deferred tiers
    /// up while clean tiers stay free.
    #[test]
    fn churn_budget_exhaustion_degrades_deterministically() {
        let spec = spec_for("googlenet", 4);
        assert!(spec.num_tiers() >= 2, "needs several tiers to starve");
        let mut service = PlannerService::new(
            spec,
            ServiceOptions {
                solve_budget: 1,
                ..ServiceOptions::default()
            },
        );
        // Epoch 0: every tier's first decision is bootstrap-exempt, so
        // all solve even past the budget.
        let l0 = Link::symmetric(4e5);
        for d in 0..4 {
            service.report(d, l0, 0);
        }
        let e0 = service.plan_epoch(0).unwrap();
        assert_eq!(e0.len(), 4);
        assert!(e0
            .iter()
            .all(|d| !matches!(d.provenance, DecisionProvenance::Degraded(_))));

        // Epoch 1: every tier dirty again; only tier 0's group fits the
        // budget — the rest serve last-good.
        let l1 = Link::symmetric(7e5);
        for d in 0..4 {
            service.report(d, l1, 1);
        }
        let e1 = service.plan_epoch(1).unwrap();
        for d in &e1 {
            if d.tier == 0 {
                assert!(!matches!(d.provenance, DecisionProvenance::Degraded(_)));
            } else {
                assert_eq!(
                    d.provenance,
                    DecisionProvenance::Degraded(DegradedReason::BudgetExceeded)
                );
                let cached = e0.iter().find(|p| p.device == d.device).unwrap();
                assert_eq!(d.partition.device_set, cached.partition.device_set);
            }
        }
        assert_eq!(service.degraded_budget(), 3);

        // Epoch 2: same reports — tier 0 is cache-clean (free) and the
        // budget admits the next deferred tier.
        let e2 = service.plan_epoch(2).unwrap();
        let fresh_tiers: Vec<usize> = e2
            .iter()
            .filter(|d| !matches!(d.provenance, DecisionProvenance::Degraded(_)))
            .map(|d| d.tier)
            .collect();
        assert!(fresh_tiers.contains(&0), "clean tier 0 serves for free");
        assert!(fresh_tiers.contains(&1), "the budget admits tier 1 next");
    }

    /// The headline replay-equivalence pin (RESILIENCE.md), bit-identity
    /// lane: replay a seeded churn script through the service under
    /// `FleetOptions::bit_identical()`; after a final full fresh-report
    /// epoch, decisions must be bit-identical to a planner built cold at
    /// the final spec S, and the planner must have solved exactly the
    /// dirty (tier, link) transitions the replay implies — untouched
    /// (tier, link) pairs contribute zero extra solves.
    #[test]
    fn churn_replay_is_bit_identical_to_a_fresh_planner() {
        let base = crate::util::rng::test_seed();
        for (i, model) in REPLAY_MODELS.iter().enumerate() {
            let mut rng = Rng::new(base ^ (0xC1A0 + ((i as u64 + 1) << 40)));
            let spec = spec_for(model, 6);
            let num_tiers = spec.num_tiers();
            let script = churn_script(&mut rng, num_tiers, 6, 10, 0.35, 0.3);
            let options = ServiceOptions {
                // Bit-identity lane: no reduction, no incremental reuse
                // (both are only cost-equivalent), dedicated server.
                joint: JointOptions {
                    fleet: FleetOptions::bit_identical(),
                    ..JointOptions::default()
                },
                ..ServiceOptions::default()
            };
            let mut service = PlannerService::new(spec, options);
            // Mirror of the service's lane model: the latest report per
            // slot and the per-tier warm-cache link, driving the exact
            // solve-count pin via `expected_epoch_solves`.
            let mut latest: Vec<Option<Link>> = vec![None; 6];
            let mut tier_cache: Vec<Option<Link>> = vec![None; num_tiers];
            let mut expected_solves = 0u64;
            for (tick, step) in script.ticks.iter().enumerate() {
                for ev in &step.events {
                    let delta = ev.to_delta();
                    if let SpecDelta::RemoveDevice { device } = &delta {
                        latest[*device] = None;
                    }
                    service.apply_delta(&delta);
                }
                for &(d, link) in &step.reports {
                    service.report(d, link, tick as u64);
                    latest[d] = Some(link);
                }
                let decisions = service.plan_epoch(tick as u64).unwrap();
                expected_solves += expected_epoch_solves(service.spec(), &latest, &mut tier_cache);
                // The transparent policy never degrades, and every
                // decision stays feasible mid-churn.
                for d in &decisions {
                    assert!(
                        !matches!(d.provenance, DecisionProvenance::Degraded(_)),
                        "{model}: transparent lane must not degrade"
                    );
                    let problem =
                        Problem::new(service.spec().tier_costs(d.tier), step.true_links[d.device]);
                    assert!(
                        problem.is_feasible(&d.partition.device_set),
                        "{model}: infeasible decision under churn"
                    );
                }
            }

            // Final full fresh-report epoch at the end-state spec S.
            let final_tick = script.ticks.len() as u64;
            let last_true = &script.ticks.last().unwrap().true_links;
            let mut reqs: Vec<PlanRequest> = Vec::new();
            for d in 0..service.spec().num_devices() {
                if let Some(tier) = service.spec().tier_of_opt(d) {
                    service.report(d, last_true[d], final_tick);
                    latest[d] = Some(last_true[d]);
                    reqs.push(PlanRequest {
                        device: d,
                        tier,
                        link: last_true[d],
                    });
                }
            }
            let replayed = service.plan_epoch(final_tick).unwrap();
            expected_solves += expected_epoch_solves(service.spec(), &latest, &mut tier_cache);
            assert_eq!(
                service.stats().solves(),
                expected_solves,
                "{model}: untouched (tier, link) pairs must not re-solve"
            );

            // A planner built cold at S answers the same epoch
            // bit-identically.
            let mut fresh =
                FleetPlanner::with_options(service.spec().clone(), FleetOptions::bit_identical());
            let want = fresh.plan(&reqs);
            assert_decisions_bit_identical(&replayed, &want, model);
        }
    }

    /// The cost lane of the replay pin: under the full fast configuration
    /// (reduction + incremental on) every degraded decision stays
    /// feasible and its cost against the *true* link is within the
    /// stale-σ envelope of the true optimum.
    #[test]
    fn churn_degraded_costs_stay_within_the_stale_sigma_envelope() {
        let base = crate::util::rng::test_seed();
        for (i, model) in REPLAY_MODELS.iter().enumerate() {
            let mut rng = Rng::new(base ^ (0x57A1E + ((i as u64 + 1) << 40)));
            let spec = spec_for(model, 6);
            let num_tiers = spec.num_tiers();
            let script = churn_script(&mut rng, num_tiers, 6, 12, 0.2, 0.45);
            let mut service = PlannerService::new(
                spec,
                ServiceOptions {
                    staleness_bound: 0,
                    ..ServiceOptions::default()
                },
            );
            // The link each device's cached decision was solved at — the
            // σ_stale of its envelope. Migrations drop the cache (new
            // tier), departures drop everything.
            let mut solved_at: Vec<Option<Link>> = vec![None; 6];
            let mut last_report: Vec<Option<Link>> = vec![None; 6];
            for (tick, step) in script.ticks.iter().enumerate() {
                for ev in &step.events {
                    let delta = ev.to_delta();
                    match &delta {
                        SpecDelta::RemoveDevice { device } => {
                            solved_at[*device] = None;
                            last_report[*device] = None;
                        }
                        SpecDelta::MigrateDevice { device, .. } => solved_at[*device] = None,
                        _ => {}
                    }
                    service.apply_delta(&delta);
                }
                for &(d, link) in &step.reports {
                    service.report(d, link, tick as u64);
                    last_report[d] = Some(link);
                }
                let decisions = service.plan_epoch(tick as u64).unwrap();
                for d in &decisions {
                    let true_link = step.true_links[d.device];
                    let costs = service.spec().tier_costs(d.tier);
                    let problem = Problem::new(costs, true_link);
                    assert!(
                        problem.is_feasible(&d.partition.device_set),
                        "{model}: decision infeasible under churn"
                    );
                    if matches!(d.provenance, DecisionProvenance::Degraded(_)) {
                        // A stale bootstrap solves this epoch at the old
                        // report; a served cache was solved earlier.
                        if solved_at[d.device].is_none() {
                            solved_at[d.device] = last_report[d.device];
                        }
                        let stale = solved_at[d.device].expect("degraded implies a prior solve");
                        assert_stale_sigma_envelope(
                            costs,
                            true,
                            true_link,
                            stale,
                            &d.partition.device_set,
                        );
                    } else {
                        solved_at[d.device] = last_report[d.device];
                    }
                }
            }
            let s = service.stats();
            assert_eq!(
                s.degraded_decisions,
                service.degraded_stale() + service.degraded_budget(),
                "{model}: provenance accounting is consistent"
            );
            assert!(
                service.degraded_stale() > 0,
                "{model}: the script must exercise staleness"
            );
        }
    }

    /// Churn events flow through the service into the planner: a leave
    /// silences the device, a re-join on another tier plans on that tier
    /// without inheriting the old incarnation's caches.
    #[test]
    fn churn_deltas_route_through_the_service() {
        let spec = spec_for("block-residual", 4);
        let mut service = PlannerService::new(spec, ServiceOptions::default());
        let link = Link::symmetric(5e5);
        for d in 0..4 {
            service.report(d, link, 0);
        }
        assert_eq!(service.plan_epoch(0).unwrap().len(), 4);

        service.apply_delta(&SpecDelta::RemoveDevice { device: 1 });
        let e1 = service.plan_epoch(1).unwrap();
        assert_eq!(e1.len(), 3, "a departed device gets no decision");
        assert!(e1.iter().all(|d| d.device != 1));

        // Re-join on a different tier (device 1 lived on tier 1 before).
        service.apply_delta(&SpecDelta::AddDevice { device: 1, tier: 2 });
        assert!(
            service.last_good(1).is_none(),
            "a re-join must not inherit the old incarnation's cache"
        );
        let e2 = service.plan_epoch(2).unwrap();
        assert!(
            e2.iter().all(|d| d.device != 1),
            "re-joined but not yet reported → silent"
        );
        service.report(1, link, 3);
        let e3 = service.plan_epoch(3).unwrap();
        let rejoined = e3.iter().find(|d| d.device == 1).unwrap();
        assert_eq!(rejoined.tier, 2);
        let problem = Problem::new(service.spec().tier_costs(2), link);
        let cold = general_partition(&problem);
        assert_cut_cost_equal(&problem, &rejoined.partition, &cold);
    }

    /// A tick behind the service clock is a typed [`ClockError`], not a
    /// panic — and it leaves no residue: the clock does not move, no
    /// counter ticks, and a correct re-plan at the current tick is
    /// bit-identical to the decisions served before the bad call.
    #[test]
    fn churn_non_monotone_tick_is_a_typed_error_without_residue() {
        let spec = spec_for("googlenet", 4);
        let mut service = PlannerService::new(spec, ServiceOptions::default());
        let link = Link::symmetric(5e5);
        for d in 0..4 {
            service.report(d, link, 5);
        }
        let e5 = service.plan_epoch(5).unwrap();
        assert_eq!(e5.len(), 4);
        let solves = service.stats().solves();

        let err = service.plan_epoch(3).unwrap_err();
        assert_eq!(err, ClockError { now: 3, latest: 5 });
        assert_eq!(err.to_string(), "epoch tick 3 is behind the service clock 5");
        assert_eq!(service.now(), 5, "a rejected tick must not move the clock");
        assert_eq!(service.stats().solves(), solves, "no planner traffic on Err");
        assert_eq!(service.degraded_stale() + service.degraded_budget(), 0);

        let again = service.plan_epoch(5).unwrap();
        assert_decisions_bit_identical(&e5, &again, "replan after rejected tick");
    }

    /// Lease semantics: [`PlannerService::expire_report`] degrades a
    /// device *before* the staleness bound would, and the next accepted
    /// report clears the flag — lease expiry takes precedence over the
    /// bound, recovery is report-driven.
    #[test]
    fn churn_expired_report_degrades_ahead_of_the_staleness_bound() {
        let spec = spec_for("googlenet", 4);
        // An infinite staleness bound: only the lease can degrade.
        let mut service = PlannerService::new(spec, ServiceOptions::default());
        let link = Link::symmetric(5e5);
        for d in 0..4 {
            service.report(d, link, 0);
        }
        let e0 = service.plan_epoch(0).unwrap();
        assert_eq!(e0.len(), 4);

        service.expire_report(2);
        let e1 = service.plan_epoch(1).unwrap();
        let leased = e1.iter().find(|d| d.device == 2).unwrap();
        assert_eq!(
            leased.provenance,
            DecisionProvenance::Degraded(DegradedReason::StaleLink)
        );
        assert!(!leased.stats.refreshed, "served last-good, not re-solved");
        assert!(
            e1.iter()
                .filter(|d| d.device != 2)
                .all(|d| !matches!(d.provenance, DecisionProvenance::Degraded(_))),
            "the lease is per-device"
        );
        assert_eq!(service.degraded_stale(), 1);

        // Still flagged next epoch — the flag outlives the expiry tick.
        let e2 = service.plan_epoch(2).unwrap();
        let leased = e2.iter().find(|d| d.device == 2).unwrap();
        assert!(matches!(leased.provenance, DecisionProvenance::Degraded(_)));

        // A fresh report clears the lease.
        service.report(2, link, 3);
        let e3 = service.plan_epoch(3).unwrap();
        assert!(e3
            .iter()
            .all(|d| !matches!(d.provenance, DecisionProvenance::Degraded(_))));

        // Out-of-range expiry is a no-op, not a panic.
        service.expire_report(99);
    }

    /// The NaN-rate round-trip regression: a malformed report through the
    /// service path is refused with a typed error and counted — matching
    /// the daemon's `IngestError` contract — and the epoch loop keeps
    /// planning from the good reports as if the bad ones never arrived.
    #[test]
    fn report_refusals_are_typed_and_counted_not_panics() {
        let spec = spec_for("googlenet", 4);
        let mut service = PlannerService::new(spec, ServiceOptions::default());
        let good = Link::symmetric(5e5);
        for d in 0..4 {
            service.report(d, good, 0);
        }
        assert_eq!(
            service.try_report(2, Link::symmetric(f64::NAN), 1),
            Err(ReportError::NonPositiveRate { device: 2 })
        );
        assert_eq!(
            service.try_report(
                2,
                Link {
                    up_bps: 1e6,
                    down_bps: f64::INFINITY,
                },
                1
            ),
            Err(ReportError::NonPositiveRate { device: 2 })
        );
        assert_eq!(
            service.try_report(2, Link::symmetric(0.0), 1),
            Err(ReportError::NonPositiveRate { device: 2 })
        );
        assert_eq!(
            service.try_report(99, good, 1),
            Err(ReportError::UnknownDevice { device: 99 })
        );
        assert_eq!(service.refused_reports(), 4);
        assert_eq!(
            ReportError::NonPositiveRate { device: 2 }.to_string(),
            "rates must be positive and finite (device 2)"
        );
        assert_eq!(
            ReportError::UnknownDevice { device: 99 }.to_string(),
            "report for unknown device slot 99"
        );

        // The refused reports left the inbox untouched: the epoch still
        // plans all four devices from their good tick-0 reports.
        let decisions = service.plan_epoch(1).unwrap();
        assert_eq!(decisions.len(), 4);
        assert!(decisions
            .iter()
            .all(|d| !matches!(d.provenance, DecisionProvenance::Degraded(_))));
    }

    #[test]
    #[should_panic(expected = "rates must be positive")]
    fn report_panicking_wrapper_keeps_the_historical_message() {
        let spec = spec_for("googlenet", 4);
        let mut service = PlannerService::new(spec, ServiceOptions::default());
        service.report(0, Link::symmetric(f64::NAN), 0);
    }

    /// The lease-expiry-then-replay regression: an equal-tick re-delivery
    /// carries no newer information, so it must not clear the
    /// forced-stale lease — only a strictly newer report recovers the
    /// device.
    #[test]
    fn equal_tick_replay_does_not_clear_the_lease() {
        let spec = spec_for("googlenet", 4);
        let mut service = PlannerService::new(spec, ServiceOptions::default());
        let link = Link::symmetric(5e5);
        for d in 0..4 {
            service.report(d, link, 0);
        }
        let e0 = service.plan_epoch(0).unwrap();
        assert_eq!(e0.len(), 4);

        service.expire_report(2);
        // Replay the tick-0 report verbatim (e.g. a duplicated delivery):
        // the lease must hold — the epoch still degrades device 2.
        service.report(2, link, 0);
        let e1 = service.plan_epoch(1).unwrap();
        let leased = e1.iter().find(|d| d.device == 2).unwrap();
        assert_eq!(
            leased.provenance,
            DecisionProvenance::Degraded(DegradedReason::StaleLink),
            "an equal-tick replay must not silently un-degrade the lease"
        );
        assert_eq!(service.degraded_stale(), 1);

        // A strictly newer report clears the lease.
        service.report(2, link, 2);
        let e2 = service.plan_epoch(2).unwrap();
        assert!(e2
            .iter()
            .all(|d| !matches!(d.provenance, DecisionProvenance::Degraded(_))));
    }
}
