//! Sharded epoch planning for million-device fleets (PR 8).
//!
//! A [`super::fleet::FleetPlanner`] already collapses a million devices
//! to `tiers × distinct links` solve groups, and σ-quantization
//! ([`super::fleet::SigmaQuantizer`]) collapses the links to buckets —
//! but one engine still sweeps every tier's solve in a single job list.
//! [`ShardedFleetPlanner`] partitions the *tiers* across worker shards:
//! shard `s` of `K` owns every global tier `t` with `t % K == s` as its
//! local tier `t / K`, and each shard is a complete [`FleetPlanner`]
//! owning its tiers' SoA slices, warm flows and decision caches. An
//! epoch routes each request to its tier's shard, runs one `plan` per
//! shard — serially, or through rayon's `par_iter_mut` behind the
//! `parallel` cargo feature, the same `TierJob` discipline the fleet
//! engine uses internally — and fans the per-shard answers back into
//! request order.
//!
//! Two contracts pin the decomposition:
//!
//! * **Bit-identity (quantization off).** Tiers are solver-independent
//!   (each `TierState` owns all its mutable state), and the modulo
//!   layout keeps every tier's whole history inside one shard, so a
//!   sharded epoch performs exactly the flat engine's refreshes and
//!   solves and serves bit-identical decisions — including full
//!   [`FleetStats`] equality (facade counters report epochs and
//!   requests; solver counters sum over shards). Churn preserves the
//!   layout: a new global tier `T` joins shard `T % K` at local index
//!   `T / K`, which is precisely that shard's next slot.
//! * **Shared-capacity coupling.** Under a finite server capacity the
//!   shards cannot price the server independently — the congestion level
//!   couples every group. The facade therefore mirrors
//!   [`super::joint::JointPlanner`]'s makespan bisection exactly: the
//!   λ=1 base pass runs sharded, then the group probes walk the same
//!   canonical `(tier, link-bits)` order through each group's owning
//!   shard (each shard holding its own lazily built unreduced λ-probe
//!   sibling). The probe sequence per tier is identical to the
//!   unsharded planner's, so the coupled decisions agree with
//!   [`super::joint::JointPlanner`] as well.
//!
//! With quantization **on**, shard-local snapping equals global
//! snapping — a σ-bucket never spans tiers, and a tier never spans
//! shards — so the bucket grid (and the `quantized_requests` account)
//! is deterministic across shard counts, pinned by the tests below.

use super::fleet::{
    DecisionProvenance, DecisionStats, FleetOptions, FleetPlanner, FleetSpec, FleetStats,
    PlanDecision, PlanRequest, SpecDelta, SpecError,
};
use super::joint::{congestion_level, min_share_ratio, Group, JointOptions, ProbeResult};
use super::types::{Partition, Problem};
use crate::profiles::CostGraph;

/// One joint-coupled solve group with its shard routing: `g.tier` holds
/// the owning shard's **local** tier index (what its probes need);
/// `global_tier` keeps the facade's canonical ordering and decisions.
struct SGroup {
    shard: usize,
    global_tier: usize,
    g: Group,
}

/// The sharded planning facade — see the module docs for the layout and
/// the pinned contracts. Construction clamps the shard count to the tier
/// count (an empty shard could never own work).
pub struct ShardedFleetPlanner {
    /// The global facade spec: request validation + device routing. Tier
    /// and device churn is mirrored here and forwarded tier-wise to the
    /// owning shard (shard specs hold no devices — routing is global).
    spec: FleetSpec,
    options: JointOptions,
    shards: Vec<FleetPlanner>,
    /// Per-shard unreduced λ-probe siblings, lazily built on the first
    /// congested epoch (mirrors [`super::joint::JointPlanner`]'s single
    /// probe engine, shard-wise).
    probes: Vec<Option<FleetPlanner>>,
    plans: u64,
    requests: u64,
    spec_deltas: u64,
    price_iterations: u64,
    joint_resolves: u64,
    last_makespan: Option<f64>,
    last_congestion: Option<f64>,
}

/// One shard's slice of an epoch: its planner, its routed sub-batch, and
/// the decisions it produced — the unit the sweep runs serially or hands
/// to rayon (mirrors the fleet engine's `TierJob`).
struct ShardJob<'a> {
    planner: &'a mut FleetPlanner,
    batch: &'a [PlanRequest],
    out: Vec<PlanDecision>,
}

impl ShardedFleetPlanner {
    /// Build for a fleet, a worker shard count (clamped to the tier
    /// count) and explicit joint options.
    pub fn new(spec: FleetSpec, num_shards: usize, options: JointOptions) -> ShardedFleetPlanner {
        assert!(
            options.server_capacity > 0.0,
            "server capacity must be positive"
        );
        assert!(num_shards >= 1, "at least one worker shard is required");
        let k = num_shards.min(spec.num_tiers());
        let shards: Vec<FleetPlanner> = (0..k)
            .map(|s| {
                let tiers: Vec<(&'static str, CostGraph)> = (s..spec.num_tiers())
                    .step_by(k)
                    .map(|t| (spec.tier_name(t), spec.tier_costs(t).clone()))
                    .collect();
                FleetPlanner::with_options(FleetSpec::new(tiers, Vec::new()), options.fleet)
            })
            .collect();
        let probes = (0..k).map(|_| None).collect();
        ShardedFleetPlanner {
            spec,
            options,
            shards,
            probes,
            plans: 0,
            requests: 0,
            spec_deltas: 0,
            price_iterations: 0,
            joint_resolves: 0,
            last_makespan: None,
            last_congestion: None,
        }
    }

    /// Worker shards actually in use (post-clamp).
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Serve one epoch: one decision per request, in request order —
    /// the [`FleetPlanner::plan`] contract, swept shard-parallel. Every
    /// shard plans every epoch (an empty sub-batch is a no-op plan), so
    /// retire-TTL clocks advance exactly as on the flat engine.
    pub fn plan(&mut self, requests: &[PlanRequest]) -> Vec<PlanDecision> {
        let k = self.shards.len();
        for r in requests {
            assert!(
                r.tier < self.spec.num_tiers(),
                "plan request for unknown tier {}",
                r.tier
            );
            assert!(r.link.is_valid(), "rates must be positive and finite");
        }
        self.plans += 1;
        self.requests += requests.len() as u64;

        // Route each request to its tier's owning shard, tier index
        // rewritten local. Relative order within a shard follows request
        // order, so the fan-in below can pull per-shard answers in order.
        let mut sub: Vec<Vec<PlanRequest>> = vec![Vec::new(); k];
        for r in requests {
            sub[r.tier % k].push(PlanRequest {
                device: r.device,
                tier: r.tier / k,
                link: r.link,
            });
        }

        let capacity = self.options.server_capacity;
        if capacity.is_infinite() {
            // Dedicated server per device: the sharded sweep alone is the
            // epoch (each shard quantizes its own sub-batch — shard-local
            // snapping equals global snapping, see the module docs).
            let outs = self.sweep(&sub);
            let decisions = self.fan_in(requests, outs);
            self.last_makespan = decisions
                .iter()
                .map(|d| d.partition.delay)
                .fold(None, |m: Option<f64>, d| Some(m.map_or(d, |m| m.max(d))));
            self.last_congestion = None;
            return decisions;
        }

        // Finite capacity: σ-quantization must precede the joint grouping
        // (the keys below must see canonical links), so snap each shard's
        // sub-batch now; the sweep's inner re-quantization is then the
        // identity.
        for (s, batch) in sub.iter_mut().enumerate() {
            if let Some(snapped) = self.shards[s].quantize_requests(batch) {
                *batch = snapped;
            }
        }
        // Rebuild the epoch's (possibly snapped) requests in facade
        // order: the grouping and the decisions must use the links the
        // shards actually planned.
        let snapped_requests: Vec<PlanRequest> = {
            let mut iters: Vec<_> = sub.iter().map(|b| b.iter()).collect();
            requests
                .iter()
                .map(|r| {
                    let q = iters[r.tier % k].next().expect("routed above");
                    PlanRequest {
                        device: r.device,
                        tier: r.tier,
                        link: q.link,
                    }
                })
                .collect()
        };
        let requests: &[PlanRequest] = &snapped_requests;

        // λ=1 base pass, sharded.
        let outs = self.sweep(&sub);
        let base = self.fan_in(requests, outs);
        if requests.is_empty() {
            self.last_makespan = None;
            self.last_congestion = None;
            return base;
        }

        // Joint grouping per distinct (tier, link), exactly as
        // `JointPlanner::plan` — retired tiers never join the coupling.
        let pin_inputs = self.options.fleet.pin_inputs;
        let mut groups: Vec<SGroup> = Vec::new();
        let mut group_of: std::collections::HashMap<(usize, u64, u64), usize> =
            std::collections::HashMap::new();
        for (i, r) in requests.iter().enumerate() {
            if self.spec.tier_retired(r.tier) {
                continue;
            }
            let key = (r.tier, r.link.up_bps.to_bits(), r.link.down_bps.to_bits());
            let g = *group_of.entry(key).or_insert_with(|| {
                let costs = self.spec.tier_costs(r.tier);
                let problem = Problem::with_pin(costs, r.link, pin_inputs);
                let (a, w) = problem.delay_terms(&base[i].partition.device_set);
                let all_on_device = vec![true; costs.len()];
                let device_only_a = problem.delay_terms(&all_on_device).0;
                groups.push(SGroup {
                    shard: r.tier % k,
                    global_tier: r.tier,
                    g: Group {
                        tier: r.tier / k,
                        link: r.link,
                        members: Vec::new(),
                        base: (a, w),
                        device_only_a,
                        probe: ProbeResult {
                            ratio: f64::INFINITY,
                            a: 0.0,
                            w: 0.0,
                            cut: None,
                        },
                    },
                });
                groups.len() - 1
            });
            groups[g].g.members.push(i);
        }
        // The canonical probe order of the unsharded planner: global
        // (tier, link-bits). Probes are group-local, so walking the
        // canonical order through per-shard engines reproduces the
        // unsharded iterate sequences tier for tier.
        groups.sort_by_key(|sg| {
            (
                sg.global_tier,
                sg.g.link.up_bps.to_bits(),
                sg.g.link.down_bps.to_bits(),
            )
        });

        // Uncongested epoch: the dedicated decisions stand.
        let dedicated_shares: f64 = groups
            .iter()
            .filter(|sg| sg.g.base.1 > 0.0)
            .map(|sg| sg.g.members.len() as f64)
            .sum();
        if dedicated_shares <= capacity {
            self.last_makespan = Some(
                base.iter()
                    .map(|d| d.partition.delay)
                    .fold(0.0, f64::max),
            );
            self.last_congestion = None;
            return base;
        }

        // Congested epoch ahead: each reduced shard gets its unreduced
        // λ-probe sibling (built once, shard-wise — see `JointPlanner`).
        for s in 0..self.shards.len() {
            if self.probes[s].is_none() && self.shards[s].is_reduced() {
                self.probes[s] = Some(FleetPlanner::with_options(
                    self.shards[s].spec().clone(),
                    FleetOptions {
                        block_reduction: false,
                        ..self.options.fleet
                    },
                ));
            }
        }

        // Makespan bisection — brackets and loop verbatim from
        // `JointPlanner::plan`.
        let t_lo = groups
            .iter()
            .map(|sg| sg.g.base.0 + sg.g.base.1)
            .fold(0.0, f64::max);
        let t_hi = groups
            .iter()
            .map(|sg| sg.g.device_only_a)
            .fold(t_lo, f64::max);
        let mut lo = t_lo;
        let mut hi = t_hi;
        let mut probes_at_hi = false;
        if self.probe_feasible(&mut groups, t_lo) {
            hi = t_lo;
            probes_at_hi = true;
        } else {
            for _ in 0..120 {
                let mid = 0.5 * (lo + hi);
                if mid <= lo || mid >= hi {
                    break;
                }
                if self.probe_feasible(&mut groups, mid) {
                    hi = mid;
                    probes_at_hi = true;
                } else {
                    lo = mid;
                    probes_at_hi = false;
                }
            }
        }
        if !probes_at_hi {
            let still_feasible = self.probe_feasible(&mut groups, hi);
            debug_assert!(still_feasible, "bisection kept `hi` feasible throughout");
            let _ = still_feasible;
        }

        // Fix cuts, set shares at the minimal congestion level, report
        // load-dependent delays (the group-local selection trade of
        // `JointPlanner::plan` applies unchanged).
        let terms: Vec<(f64, f64, usize)> = groups
            .iter()
            .map(|sg| (sg.g.probe.a, sg.g.probe.w, sg.g.members.len()))
            .collect();
        let t_c = congestion_level(&terms, capacity);
        let dedicated = terms.iter().map(|&(a, w, _)| a + w).fold(0.0, f64::max);
        self.last_makespan = Some(dedicated.max(t_c));
        self.last_congestion = Some(t_c);

        let mut decisions: Vec<Option<PlanDecision>> = (0..requests.len()).map(|_| None).collect();
        for sg in &groups {
            let (a, w) = (sg.g.probe.a, sg.g.probe.w);
            let device_set = sg
                .g
                .probe
                .cut
                .clone()
                .unwrap_or_else(|| base[sg.g.members[0]].partition.device_set.clone());
            let delay = if w <= 0.0 { a } else { (a + w).max(t_c) };
            for (j, &i) in sg.g.members.iter().enumerate() {
                let partition = Partition {
                    device_set: device_set.clone(),
                    delay,
                };
                decisions[i] = Some(PlanDecision {
                    device: requests[i].device,
                    tier: requests[i].tier,
                    cut_layer: partition.cut_layer(),
                    partition,
                    stats: DecisionStats { refreshed: j == 0 },
                    provenance: if j == 0 {
                        DecisionProvenance::Fresh
                    } else {
                        DecisionProvenance::Cached
                    },
                });
            }
        }
        decisions
            .into_iter()
            .enumerate()
            .map(|(i, d)| d.unwrap_or_else(|| base[i].clone()))
            .collect()
    }

    /// One epoch sweep: every shard plans its sub-batch — all shards,
    /// every epoch, empty batches included (retire-TTL parity with the
    /// flat engine). Serial, or rayon `par_iter_mut` behind the
    /// `parallel` feature; shards are fully independent, so decisions and
    /// counters are bit-identical across the two modes.
    fn sweep(&mut self, sub: &[Vec<PlanRequest>]) -> Vec<Vec<PlanDecision>> {
        let mut jobs: Vec<ShardJob> = self
            .shards
            .iter_mut()
            .zip(sub)
            .map(|(planner, batch)| ShardJob {
                planner,
                batch,
                out: Vec::new(),
            })
            .collect();
        let run = |job: &mut ShardJob| {
            job.out = job.planner.plan(job.batch);
        };
        #[cfg(not(feature = "parallel"))]
        jobs.iter_mut().for_each(run);
        #[cfg(feature = "parallel")]
        {
            use rayon::prelude::*;
            jobs.par_iter_mut().for_each(run);
        }
        jobs.into_iter().map(|j| j.out).collect()
    }

    /// Fan the per-shard decision streams back into facade request
    /// order, tier indices rewritten global. Routing preserved relative
    /// order, so each stream is consumed front to back.
    fn fan_in(&self, requests: &[PlanRequest], outs: Vec<Vec<PlanDecision>>) -> Vec<PlanDecision> {
        let k = self.shards.len();
        let mut iters: Vec<_> = outs.into_iter().map(|o| o.into_iter()).collect();
        requests
            .iter()
            .map(|r| {
                let mut d = iters[r.tier % k]
                    .next()
                    .expect("one decision per routed request");
                debug_assert_eq!(d.device, r.device);
                d.tier = r.tier;
                d
            })
            .collect()
    }

    /// One feasibility probe of the makespan bisection, each group
    /// routed to its owning shard's probe engine (or the shard itself
    /// when unreduced) — the sharded mirror of
    /// `JointPlanner::probe_feasible`.
    fn probe_feasible(&mut self, groups: &mut [SGroup], t: f64) -> bool {
        self.price_iterations += 1;
        let pin_inputs = self.options.fleet.pin_inputs;
        let capacity = self.options.server_capacity;
        let ShardedFleetPlanner {
            shards,
            probes,
            joint_resolves,
            ..
        } = &mut *self;
        let mut demand = 0.0;
        for sg in groups.iter_mut() {
            let engine = match &mut probes[sg.shard] {
                Some(p) => p,
                None => &mut shards[sg.shard],
            };
            let ratio = min_share_ratio(engine, pin_inputs, &mut sg.g, t, joint_resolves);
            demand += sg.g.members.len() as f64 * ratio;
        }
        demand <= capacity
    }

    /// Aggregate counters across the facade and every shard: epoch and
    /// request counts (and `spec_deltas`) are facade-level — one sharded
    /// epoch is one plan, exactly as on the flat engine — solver and
    /// provenance counters sum over shards (plus λ-probe siblings'
    /// solver traffic), and the DAG-shape fields report the shared model
    /// template (identical across shards by construction).
    pub fn stats(&self) -> FleetStats {
        let t0 = self.shards[0].stats();
        let mut s = FleetStats {
            plans: self.plans,
            requests: self.requests,
            spec_deltas: self.spec_deltas,
            full_vertices: t0.full_vertices,
            full_edges: t0.full_edges,
            reduced_vertices: t0.reduced_vertices,
            reduced_edges: t0.reduced_edges,
            blocks_detected: t0.blocks_detected,
            blocks_abstracted: t0.blocks_abstracted,
            ..FleetStats::default()
        };
        for shard in &self.shards {
            let ss = shard.stats();
            s.refreshes += ss.refreshes;
            s.flow_solves += ss.flow_solves;
            s.linear_scans += ss.linear_scans;
            s.incremental_solves += ss.incremental_solves;
            s.repair_pushes += ss.repair_pushes;
            s.augment_rounds += ss.augment_rounds;
            s.fallback_cold_solves += ss.fallback_cold_solves;
            s.retired_decisions += ss.retired_decisions;
            s.degraded_decisions += ss.degraded_decisions;
            s.quantized_requests += ss.quantized_requests;
        }
        for p in self.probes.iter().flatten() {
            let ps = p.stats();
            s.refreshes += ps.refreshes;
            s.flow_solves += ps.flow_solves;
            s.linear_scans += ps.linear_scans;
            s.incremental_solves += ps.incremental_solves;
            s.repair_pushes += ps.repair_pushes;
            s.augment_rounds += ps.augment_rounds;
            s.fallback_cold_solves += ps.fallback_cold_solves;
        }
        s.price_iterations = self.price_iterations;
        s.joint_resolves = self.joint_resolves;
        s
    }

    /// Apply one churn event: validated against the facade spec, tier
    /// deltas forwarded to the owning shard (indices rewritten local) and
    /// its λ-probe sibling, device deltas mirrored on the facade spec
    /// only (shard specs hold no devices — routing is global). A
    /// malformed delta is rejected with a typed [`SpecError`] before
    /// anything moves.
    pub fn try_apply_delta(&mut self, delta: &SpecDelta) -> Result<(), SpecError> {
        self.spec.validate(delta)?;
        let k = self.shards.len();
        match delta {
            SpecDelta::AddTier { name, costs } => {
                // The new global tier T joins shard T % K at local index
                // T / K — which is exactly that shard's next slot, so the
                // modulo layout survives churn (see the module docs).
                let t = self.spec.num_tiers();
                let fwd = SpecDelta::AddTier {
                    name,
                    costs: costs.clone(),
                };
                self.shards[t % k]
                    .try_apply(&fwd)
                    .expect("validated against the facade spec");
                if let Some(p) = &mut self.probes[t % k] {
                    p.try_apply(&fwd).expect("probe sibling shares the shard spec");
                }
            }
            SpecDelta::RetireTier { tier } => {
                let fwd = SpecDelta::RetireTier { tier: tier / k };
                self.shards[tier % k]
                    .try_apply(&fwd)
                    .expect("validated against the facade spec");
                if let Some(p) = &mut self.probes[tier % k] {
                    p.try_apply(&fwd).expect("probe sibling shares the shard spec");
                }
            }
            // Device membership is facade routing only.
            SpecDelta::AddDevice { .. }
            | SpecDelta::RemoveDevice { .. }
            | SpecDelta::MigrateDevice { .. } => {}
        }
        self.spec
            .try_apply(delta)
            .expect("validated above against the same spec");
        self.spec_deltas += 1;
        Ok(())
    }

    /// Panicking convenience over [`ShardedFleetPlanner::try_apply_delta`]
    /// for callers that treat a malformed delta as a bug.
    pub fn apply_delta(&mut self, delta: &SpecDelta) {
        if let Err(e) = self.try_apply_delta(delta) {
            panic!("malformed churn event: {e}");
        }
    }

    /// Immediately expire a retired tier's archived decision on its
    /// owning shard (and λ-probe sibling). A no-op on live or
    /// out-of-range tiers, as on the flat engine.
    pub fn expire_retired(&mut self, tier: usize) {
        let k = self.shards.len();
        if tier >= self.spec.num_tiers() {
            return;
        }
        self.shards[tier % k].expire_retired(tier / k);
        if let Some(p) = &mut self.probes[tier % k] {
            p.expire_retired(tier / k);
        }
    }

    /// Update the shared server capacity for subsequent epochs (see
    /// [`super::joint::JointPlanner::set_server_capacity`]).
    pub fn set_server_capacity(&mut self, server_capacity: f64) {
        assert!(server_capacity > 0.0, "server capacity must be positive");
        self.options.server_capacity = server_capacity;
    }

    /// Fleet makespan of the latest non-empty epoch.
    pub fn makespan(&self) -> Option<f64> {
        self.last_makespan
    }

    /// Congestion level `T_c` of the latest epoch, `None` when every
    /// session got a dedicated share.
    pub fn congestion(&self) -> Option<f64> {
        self.last_congestion
    }

    /// The switches this planner was built with.
    pub fn options(&self) -> JointOptions {
        self.options
    }

    /// The global fleet this facade plans for.
    pub fn spec(&self) -> &FleetSpec {
        &self.spec
    }

    /// Drop every shard's cached λ=1 decisions (see
    /// [`FleetPlanner::invalidate`]).
    pub fn invalidate(&mut self) {
        for shard in &mut self.shards {
            shard.invalidate();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::partition::joint::JointPlanner;
    use crate::partition::types::Link;
    use crate::profiles::{DeviceProfile, TrainCfg};
    use crate::util::prop::{assert_cut_cost_within, assert_fleet_cost_equal, random_link};
    use crate::util::rng::Rng;

    fn spec_for(model: &str, devices: usize) -> FleetSpec {
        let m = models::by_name(model).unwrap();
        FleetSpec::from_fleet(&DeviceProfile::fleet_of(devices), |d| {
            CostGraph::build(&m, d, &DeviceProfile::rtx_a6000(), &TrainCfg::default())
        })
    }

    fn assert_bit_identical(a: &[PlanDecision], b: &[PlanDecision], context: &str) {
        assert_eq!(a.len(), b.len(), "{context}: decision counts differ");
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.device, y.device, "{context}");
            assert_eq!(x.tier, y.tier, "{context}");
            assert_eq!(x.cut_layer, y.cut_layer, "{context}");
            assert_eq!(x.partition.device_set, y.partition.device_set, "{context}");
            assert_eq!(
                x.partition.delay.to_bits(),
                y.partition.delay.to_bits(),
                "{context}"
            );
            assert_eq!(x.stats.refreshed, y.stats.refreshed, "{context}");
            assert_eq!(x.provenance, y.provenance, "{context}");
        }
    }

    /// Per-epoch random request batch over the active devices, shared by
    /// both planners under comparison.
    fn random_batch(spec: &FleetSpec, rng: &mut Rng) -> Vec<PlanRequest> {
        (0..spec.num_devices())
            .filter_map(|d| {
                spec.tier_of_opt(d).map(|tier| PlanRequest {
                    device: d,
                    tier,
                    link: random_link(rng),
                })
            })
            .collect()
    }

    /// The tentpole acceptance pin: with quantization off, sharded
    /// planning is bit-identical to the flat engine across shard counts
    /// — decisions AND the full `FleetStats` struct — through random
    /// epochs, tier churn and retired-tier serving.
    #[test]
    fn sharded_plan_is_bit_identical_to_unsharded_with_full_stats_equality() {
        let base_seed = crate::util::rng::test_seed();
        for k in [1usize, 2, 3, 8] {
            let spec = spec_for("googlenet", 12);
            let mut flat = FleetPlanner::new(spec.clone());
            let mut sharded = ShardedFleetPlanner::new(spec, k, JointOptions::default());
            assert_eq!(sharded.num_shards(), k.min(4), "shards clamp to tiers");
            let mut rng = Rng::new(base_seed ^ ((k as u64) << 8));
            for epoch in 0..3 {
                let reqs = random_batch(flat.spec(), &mut rng);
                let a = sharded.plan(&reqs);
                let b = flat.plan(&reqs);
                assert_bit_identical(&a, &b, &format!("k={k} epoch {epoch}"));
            }

            // Tier churn: a tier joins mid-run (the modulo layout must
            // absorb it), a tier retires, and a late request for the
            // retired tier is served from the archive on both planners.
            let extra = CostGraph::build(
                &models::by_name("googlenet").unwrap(),
                &DeviceProfile::jetson_tx2(),
                &DeviceProfile::rtx_a6000(),
                &TrainCfg::default(),
            );
            let add = SpecDelta::AddTier {
                name: "extra-tier",
                costs: extra,
            };
            sharded.apply_delta(&add);
            flat.apply(&add);
            let join = SpecDelta::AddDevice {
                device: 12,
                tier: 4,
            };
            sharded.apply_delta(&join);
            flat.apply(&join);
            for epoch in 0..2 {
                let reqs = random_batch(flat.spec(), &mut rng);
                let a = sharded.plan(&reqs);
                let b = flat.plan(&reqs);
                assert_bit_identical(&a, &b, &format!("k={k} post-churn epoch {epoch}"));
            }
            let retire = SpecDelta::RetireTier { tier: 1 };
            sharded.apply_delta(&retire);
            flat.apply(&retire);
            let mut reqs = random_batch(flat.spec(), &mut rng);
            reqs.push(PlanRequest {
                device: 1,
                tier: 1,
                link: Link::symmetric(6e5),
            });
            let a = sharded.plan(&reqs);
            let b = flat.plan(&reqs);
            assert_bit_identical(&a, &b, &format!("k={k} retired epoch"));
            assert_eq!(
                a.last().unwrap().provenance,
                DecisionProvenance::Retired,
                "k={k}: the late request must serve from the archive"
            );

            assert_eq!(
                sharded.stats(),
                flat.stats(),
                "k={k}: full FleetStats equality"
            );
        }
    }

    /// Shared-capacity coupling: under a finite server capacity the
    /// sharded facade's makespan bisection must agree with
    /// [`JointPlanner`] — same makespan, same congestion level, same
    /// per-decision load-dependent delays — across a capacity ladder
    /// from heavily congested to nearly dedicated.
    #[test]
    fn sharded_joint_capacity_matches_the_joint_planner() {
        let base_seed = crate::util::rng::test_seed();
        for capacity in [0.5, 1.0, 2.0, 6.0] {
            let spec = spec_for("googlenet", 8);
            let options = JointOptions::with_capacity(capacity);
            let mut joint = JointPlanner::new(spec.clone(), options);
            let mut sharded = ShardedFleetPlanner::new(spec, 2, options);
            let mut rng = Rng::new(base_seed ^ capacity.to_bits());
            for epoch in 0..3 {
                let reqs = random_batch(joint.spec(), &mut rng);
                let a = sharded.plan(&reqs);
                let b = joint.plan(&reqs);
                let context = format!("capacity {capacity} epoch {epoch}");
                assert_eq!(a.len(), b.len(), "{context}");
                for (x, y) in a.iter().zip(&b) {
                    assert_eq!(x.device, y.device, "{context}");
                    assert_eq!(x.tier, y.tier, "{context}");
                    let (dx, dy) = (x.partition.delay, y.partition.delay);
                    assert!(
                        (dx - dy).abs() <= 1e-9 * (1.0 + dx.abs().max(dy.abs())),
                        "{context}: delays diverge ({dx} vs {dy})"
                    );
                }
                match (sharded.makespan(), joint.makespan()) {
                    (Some(ms), Some(mj)) => assert_fleet_cost_equal(ms, mj, &context),
                    (ms, mj) => panic!("{context}: makespans {ms:?} vs {mj:?}"),
                }
                assert_eq!(
                    sharded.congestion().is_some(),
                    joint.congestion().is_some(),
                    "{context}: congestion classification diverged"
                );
            }
        }
    }

    /// Bucket-grid determinism across shard counts: with quantization on,
    /// every shard count serves bit-identical decisions and accounts the
    /// same `quantized_requests` — a σ-bucket never spans tiers and a
    /// tier never spans shards, so shard-local snapping IS the global
    /// snap (seeded under `PALLAS_TEST_SEED`).
    #[test]
    fn sharded_quantized_grid_is_deterministic_across_shard_counts() {
        let base_seed = crate::util::rng::test_seed();
        let options = JointOptions {
            fleet: FleetOptions {
                sigma_buckets_per_decade: 4,
                ..FleetOptions::default()
            },
            ..JointOptions::default()
        };
        let spec = spec_for("googlenet", 8);
        let mut planners: Vec<ShardedFleetPlanner> = [1usize, 2, 3]
            .iter()
            .map(|&k| ShardedFleetPlanner::new(spec.clone(), k, options))
            .collect();
        let mut rng = Rng::new(base_seed ^ 0x58A2D);
        for epoch in 0..4 {
            // Clusters of nearby links (factors within one bucket ratio)
            // so the grid actually collapses members.
            let base_links: Vec<Link> = (0..spec.num_tiers()).map(|_| random_link(&mut rng)).collect();
            let reqs: Vec<PlanRequest> = (0..spec.num_devices())
                .map(|d| {
                    let tier = spec.tier_of(d);
                    let f = 1.0 - 0.01 * (d / spec.num_tiers()) as f64;
                    PlanRequest {
                        device: d,
                        tier,
                        link: Link {
                            up_bps: base_links[tier].up_bps * f,
                            down_bps: base_links[tier].down_bps * f,
                        },
                    }
                })
                .collect();
            let decisions: Vec<Vec<PlanDecision>> =
                planners.iter_mut().map(|p| p.plan(&reqs)).collect();
            for d in &decisions[1..] {
                assert_bit_identical(d, &decisions[0], &format!("epoch {epoch}"));
            }
        }
        let counts: Vec<u64> = planners.iter().map(|p| p.stats().quantized_requests).collect();
        assert!(
            counts.iter().all(|&c| c == counts[0]),
            "quantized_requests diverged across shard counts: {counts:?}"
        );
        assert!(counts[0] > 0, "the clusters must actually collapse");
    }

    /// Sharded + quantized planning stays within the analytic per-bucket
    /// bound of the flat unquantized optimum (the tentpole's cost-within-
    /// eps lane): delay is affine in σ for a fixed cut, so the served
    /// cost differs from the optimum by at most
    /// `(B_served + B_opt)·σ-width` (see `SigmaQuantizer`).
    #[test]
    fn sharded_quantized_decisions_stay_within_the_bucket_bound() {
        let base_seed = crate::util::rng::test_seed();
        let spec = spec_for("googlenet", 10);
        let buckets = 2u32;
        let q = crate::partition::fleet::SigmaQuantizer::new(buckets).unwrap();
        let mut sharded = ShardedFleetPlanner::new(
            spec.clone(),
            3,
            JointOptions {
                fleet: FleetOptions {
                    sigma_buckets_per_decade: buckets,
                    ..FleetOptions::default()
                },
                ..JointOptions::default()
            },
        );
        let mut flat = FleetPlanner::new(spec.clone());
        let bw_mass = |tier: usize, device_set: &[bool]| {
            let costs = spec.tier_costs(tier);
            let (l1, l2) = (Link::symmetric(1e6), Link::symmetric(2e6));
            let t1 = Problem::new(costs, l1).delay(device_set);
            let t2 = Problem::new(costs, l2).delay(device_set);
            (t1 - t2) / (l1.sigma() - l2.sigma())
        };
        let mut rng = Rng::new(base_seed ^ 0xB0D4D);
        for _ in 0..4 {
            let base_links: Vec<Link> = (0..spec.num_tiers()).map(|_| random_link(&mut rng)).collect();
            let reqs: Vec<PlanRequest> = (0..spec.num_devices())
                .map(|d| {
                    let tier = spec.tier_of(d);
                    let f = 1.0 - 0.02 * (d / spec.num_tiers()) as f64;
                    PlanRequest {
                        device: d,
                        tier,
                        link: Link {
                            up_bps: base_links[tier].up_bps * f,
                            down_bps: base_links[tier].down_bps * f,
                        },
                    }
                })
                .collect();
            let served = sharded.plan(&reqs);
            let want = flat.plan(&reqs);
            for (r, (s, w)) in reqs.iter().zip(served.iter().zip(&want)) {
                let problem = Problem::new(spec.tier_costs(r.tier), r.link);
                let eps = (bw_mass(r.tier, &s.partition.device_set)
                    + bw_mass(r.tier, &w.partition.device_set))
                    * q.sigma_width(r.link);
                assert_cut_cost_within(&problem, &s.partition, &w.partition, eps);
            }
        }
        assert!(sharded.stats().quantized_requests > 0);
    }
}
