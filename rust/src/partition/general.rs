//! Alg. 2: the general model partitioning algorithm.
//!
//! 1. Build the weighted partition DAG (Alg. 1).
//! 2. For every parent vertex with multiple children, insert an auxiliary
//!    vertex (Fig. 3) so its propagation weight is paid once however many
//!    outgoing edges the cut crosses.
//! 3. Add infinite-capacity precedence edges enforcing problem (12)'s
//!    feasibility constraint (the paper leaves this to Assumption 1; the
//!    closure edges make optimality unconditional — see DESIGN.md, ablation
//!    `ablA` quantifies that they never change the result under
//!    Assumption 1, as Theorem 1 predicts).
//! 4. Solve minimum s-t cut by max flow (Dinic) and read the layer
//!    assignment off the *execution* vertices (the auxiliary vertex carries
//!    the execution semantics of a split layer; the original vertex becomes
//!    a pure transmission node).
//!
//! For linear models (every layer has at most one child) the paper uses a
//! brute-force scan; [`linear_scan_partition`] evaluates all `L+1` prefix
//! cuts in O(L) total via running sums.

use super::fleet::TransformedNet;
use super::types::{Partition, Problem};
use crate::maxflow::DinicScratch;

/// Instrumentation of a general-algorithm run (for Fig. 7/8 complexity and
/// Table I/Fig. 9 timing harnesses).
#[derive(Clone, Debug)]
pub struct GeneralRun {
    pub partition: Partition,
    /// Vertices in the transformed flow network.
    pub flow_vertices: usize,
    /// Edges in the transformed flow network.
    pub flow_edges: usize,
    /// Dinic complexity estimate O(V^2 E).
    pub complexity: f64,
}

/// Solve the partitioning problem with the general algorithm (Alg. 2).
pub fn general_partition(problem: &Problem) -> Partition {
    general_partition_instrumented(problem).partition
}

/// Alg. 2 with instrumentation (closure edges enabled, the default).
pub fn general_partition_instrumented(problem: &Problem) -> GeneralRun {
    general_partition_with_options(problem, true)
}

/// Alg. 2 with explicit control over the precedence (closure) edges — the
/// paper's literal construction omits them and relies on Assumption 1;
/// `experiments::ablations` quantifies the difference.
pub fn general_partition_with_options(problem: &Problem, closure_edges: bool) -> GeneralRun {
    let c = problem.costs;
    let n = c.len();

    // Linear fast path (Alg. 2 line 2-4): no parent has multiple children.
    let has_multi_child_parent = (0..n).any(|v| c.dag.out_degree(v) > 1);
    if !has_multi_child_parent {
        let partition = linear_scan_partition(problem);
        return GeneralRun {
            partition,
            flow_vertices: n + 2,
            flow_edges: 2 * n + c.dag.num_edges(),
            complexity: (n + 1) as f64, // O(L) scan
        };
    }

    // The transformed network (Alg. 1's Eqs. (9)-(11) weights, Fig. 3
    // auxiliary vertices, optional closure edges) is built by the shared
    // `partition::fleet::TransformedNet` — the same construction the
    // amortized planners cache across epochs, so a cold one-shot solve
    // here and a warm planner re-solve are bit-identical. (The labelled
    // `build_partition_dag` in weights.rs remains the inspectable/
    // DOT-export construction.)
    let mut tnet = TransformedNet::build(c, problem.pin_inputs, closure_edges);
    tnet.refresh(problem.link);
    let mut scratch = DinicScratch::default();
    let flow_vertices = tnet.num_vertices();
    let flow_edges = tnet.num_edges();
    let cut = tnet.min_cut(&mut scratch);
    let device_set = tnet.device_set(&cut.source_side);
    debug_assert!(
        !closure_edges || problem.is_feasible(&device_set),
        "min-cut produced an infeasible partition"
    );
    let partition = problem.partition(device_set);
    debug_assert!(
        !closure_edges
            || (partition.delay - cut.value).abs() <= 1e-6 * (1.0 + cut.value.abs()),
        "cut value {} != Eq.(7) delay {}",
        cut.value,
        partition.delay
    );
    GeneralRun {
        partition,
        flow_vertices,
        flow_edges,
        complexity: (flow_vertices as f64).powi(2) * flow_edges as f64,
    }
}

/// O(L) optimal scan for linear (chain) models: prefix cuts only.
pub fn linear_scan_partition(problem: &Problem) -> Partition {
    linear_scan_partition_priced(problem, 1.0)
}

/// [`linear_scan_partition`] under a server congestion price `lambda`:
/// picks the prefix minimizing `A(cut) + λ·W(cut)` — Eq. (7) with the
/// server-compute term scaled by λ, the chain-model half of the joint
/// planner's priced probe (the flow half scales the server-exec
/// capacities, see `partition::fleet`). At `lambda == 1.0` the scanned
/// objective is bit-identical to the unpriced scan (`λ·x = x` exactly),
/// so the plain entry point above is a zero-cost wrapper. The returned
/// [`Partition`] always carries the *unpriced* Eq. (7) delay of the
/// chosen prefix.
pub fn linear_scan_partition_priced(problem: &Problem, lambda: f64) -> Partition {
    let c = problem.costs;
    let order = c.dag.topo_order().expect("acyclic");
    let n = c.len();
    let sigma = problem.link.sigma();

    // Running totals while moving the cut from "all server" to "all device".
    let mut device_compute = 0.0;
    let mut server_compute: f64 = c.xi_s.iter().sum();
    let mut device_params = 0.0;
    // The empty device set is only admissible without input pinning.
    let mut best_delay = if problem.pin_inputs {
        f64::INFINITY
    } else {
        c.n_loc * (lambda * server_compute)
    };
    let mut best_prefix = if problem.pin_inputs { 1 } else { 0 };

    for (i, &v) in order.iter().enumerate() {
        device_compute += c.xi_d[v];
        server_compute -= c.xi_s[v];
        device_params += c.param_bytes[v];
        // Boundary after taking prefix 0..=i: v's activation crosses unless
        // v is the final layer (no children).
        let boundary = if c.dag.out_degree(v) > 0 {
            c.act_bytes[v]
        } else {
            0.0
        };
        let delay = c.n_loc * (device_compute + lambda * server_compute + boundary * sigma)
            + device_params * sigma;
        if delay < best_delay {
            best_delay = delay;
            best_prefix = i + 1;
        }
    }

    let mut device_set = vec![false; n];
    for &v in order.iter().take(best_prefix) {
        device_set[v] = true;
    }
    problem.partition(device_set)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::partition::types::Link;
    use crate::profiles::{CostGraph, DeviceProfile, TrainCfg};

    fn cg(model: &str) -> CostGraph {
        let m = models::by_name(model).unwrap();
        CostGraph::build(
            &m,
            &DeviceProfile::jetson_tx2(),
            &DeviceProfile::rtx_a6000(),
            &TrainCfg::default(),
        )
    }

    #[test]
    fn linear_scan_matches_exhaustive_prefixes() {
        let cg = cg("lenet5");
        let p = Problem::new(&cg, Link::symmetric(1e6));
        let best = linear_scan_partition(&p);
        // Exhaustive prefix check (prefix 0 excluded: the input is pinned).
        let order = cg.dag.topo_order().unwrap();
        let mut best_manual = f64::INFINITY;
        for k in 1..=order.len() {
            let mut mask = vec![false; cg.len()];
            for &v in order.iter().take(k) {
                mask[v] = true;
            }
            best_manual = best_manual.min(p.delay(&mask));
        }
        assert!((best.delay - best_manual).abs() < 1e-9);
    }

    #[test]
    fn general_on_linear_model_uses_fast_path() {
        let cg = cg("lenet5");
        let p = Problem::new(&cg, Link::symmetric(1e6));
        let run = general_partition_instrumented(&p);
        assert_eq!(run.complexity, (cg.len() + 1) as f64);
        assert!(p.is_feasible(&run.partition.device_set));
    }

    #[test]
    fn general_on_blocknet_is_feasible_and_consistent() {
        for model in ["block-residual", "block-inception", "block-dense"] {
            let cg = cg(model);
            let p = Problem::new(&cg, Link::symmetric(2e6));
            let run = general_partition_instrumented(&p);
            assert!(p.is_feasible(&run.partition.device_set), "{model}");
            // Delay must beat or match every feasible trivial choice.
            assert!(run.partition.delay <= p.device_only().delay + 1e-9, "{model}");
            let mut input_only = vec![false; cg.len()];
            input_only[0] = true;
            assert!(run.partition.delay <= p.delay(&input_only) + 1e-9, "{model}");
        }
    }

    #[test]
    fn fast_link_pushes_layers_to_server() {
        let cg = cg("block-residual");
        // Infinite-ish bandwidth: transmission is free and the server is
        // faster, so only the pinned input (the raw data) stays on the
        // device.
        let p = Problem::new(&cg, Link::symmetric(1e15));
        let run = general_partition(&p);
        assert_eq!(run.device_layers(), 1, "only the input layer");
        assert!(run.device_set[0], "the input must stay pinned");
        // The unpinned problem may do strictly better (central, free data).
        let unpinned = Problem::unpinned(&cg, Link::symmetric(1e15));
        assert!(general_partition(&unpinned).delay <= run.delay + 1e-12);
    }

    #[test]
    fn slow_link_keeps_everything_on_device() {
        let cg = cg("block-residual");
        // Pathologically slow link: per-iteration raw-data upload (input is
        // pinned to the device) dwarfs everything; device-only pays only
        // the one-off model exchange and wins.
        let p = Problem::new(&cg, Link::symmetric(10.0));
        let run = general_partition(&p);
        assert_eq!(run.device_layers(), cg.len());
        assert!((run.delay - p.device_only().delay).abs() < 1e-6 * run.delay);
    }

    /// The priced scan: λ = 1 is bit-identical to the unpriced scan, and
    /// growing congestion prices only ever move the chain cut device-ward
    /// (the joint planner's monotonicity relies on this).
    #[test]
    fn priced_scan_is_unpriced_at_unit_price_and_shifts_deviceward() {
        let cg = cg("lenet5");
        let p = Problem::new(&cg, Link::symmetric(2e6));
        let unpriced = linear_scan_partition(&p);
        let unit = linear_scan_partition_priced(&p, 1.0);
        assert_eq!(unpriced.device_set, unit.device_set);
        assert_eq!(unpriced.delay.to_bits(), unit.delay.to_bits());
        let mut prev = unit.device_layers();
        for lambda in [1.5, 3.0, 10.0, 1e4, 1e12] {
            let priced = linear_scan_partition_priced(&p, lambda);
            assert!(p.is_feasible(&priced.device_set));
            assert!(
                priced.device_layers() >= prev,
                "λ={lambda} moved the cut server-ward"
            );
            prev = priced.device_layers();
            // The reported delay stays the unpriced Eq. (7) value.
            assert_eq!(
                priced.delay.to_bits(),
                p.delay(&priced.device_set).to_bits()
            );
        }
    }

    #[test]
    fn full_models_partition_in_reasonable_time() {
        for model in ["resnet18", "googlenet"] {
            let cg = cg(model);
            let p = Problem::new(&cg, Link::symmetric(5e6));
            let run = general_partition_instrumented(&p);
            assert!(p.is_feasible(&run.partition.device_set), "{model}");
        }
    }
}
