//! Fleet-scale planning: one facade, batched struct-of-arrays solves.
//!
//! The paper's decision loop (Sec. III-A) is per-device, but an edge fleet
//! makes one *epoch* decision over many devices at once, and heterogeneous
//! fleets deduplicate into a handful of device tiers (four Jetson tiers in
//! the Sec. VII-B prototype). [`FleetPlanner`] is the one planning surface
//! for that setting: constructed once from a [`FleetSpec`] (deduplicated
//! tiers sharing one model), it owns every per-tier transformed network and
//! serves an epoch as a single request/response call —
//! [`FleetPlanner::plan`] takes `&[PlanRequest]` and returns one
//! [`PlanDecision`] per request.
//!
//! # Struct-of-arrays capacity layout
//!
//! Every forward-edge capacity of the Alg. 2 transformed network is affine
//! in the round-trip byte cost `σ = 1/R_up + 1/R_down`
//! ([`crate::partition::Link::sigma`]) and in the joint planner's server
//! congestion price `λ` (1 = dedicated server; see `partition::joint`):
//!
//! ```text
//!   cap(e) = base(e) + bw_scale(e)·σ + srv_base(e)·λ   with, per edge class:
//!   server-exec  (s  → v')   srv_base = N_loc·ξ_S(v)  scale = 0  (base = ∞ if pinned input)
//!   device-exec  (v' → t)    base = N_loc·ξ_D(v)      scale = k_v
//!   propagation  (u  → v')   base = 0                 scale = N_loc·a_u
//!   aux transmit (v' → v)    base = 0                 scale = N_loc·a_v
//!   closure      (reverse)   base = ∞                 scale = 0
//! ```
//!
//! Only the device-exec `base` term depends on the tier (ξ_D varies with the
//! device; the DAG, activation/parameter bytes, server costs, and N_loc are
//! the model's and the server's). The fleet layout therefore splits the
//! arrays ([`NetShape`]): one shared `base[]` + `bw_scale[]` for the whole
//! fleet, and per tier only an `exec_base[]` vector (`N_loc·ξ_D`, one entry
//! per layer) plus a clone of the frozen CSR network and reusable Dinic
//! scratch. Refreshing a tier for a new link is one O(E) pass
//! (`base[k] + bw_scale[k]·σ`, then the O(L) device-exec overwrite) — no
//! allocation, no topology work, bit-identical to a cold build (the cold
//! path in `partition::general` runs through the same [`TransformedNet`]).
//!
//! # Batched-refresh invariant
//!
//! Within [`FleetPlanner::plan`], a tier is **dirty** iff a request carries
//! a link different from the tier's cached solve. Each dirty (tier, link)
//! performs exactly one refresh pass + one solve; every other request for
//! that (tier, link) — in the same batch or a later epoch — reuses the
//! cached [`Partition`] (the solve is deterministic, so the reuse is
//! bit-exact; [`FleetStats`] exposes the counters the property tests pin).
//! Tiers are solved independently — each [`TierState`] owns its network and
//! scratch and only reads the shared [`NetShape`] — so a future `rayon`
//! feature flag can parallelize the per-tier loop without any API change.
//!
//! # Fleet-level block reduction
//!
//! The Theorem 2 reduction (intra-block min-cut over **activation bytes**)
//! depends only on the model DAG — not on any tier's compute profile — so
//! the facade computes one [`blockwise::Reduction`](super::blockwise) plan
//! per [`FleetSpec`] and applies it to every tier's cost graph: block
//! detection and the intra-block min-cuts run **once per fleet**, and the
//! shared/per-tier SoA capacity split above hangs off the *reduced* DAG.
//! Block-structured models (ResNet, DenseNet, GPT-2) therefore pay
//! blockwise-scale warm solves per dirty tier instead of full-DAG ones;
//! each decision is expanded back to the full layer set and evaluated via
//! Eq. (7) on the full cost graph before it leaves the planner.
//!
//! Reduced-DAG solves may tie-break among **co-optimal** cuts differently
//! than the full general engine, so the pinned equivalence property is
//! *cost equality* — equal T(cut) under Eq. (7), see
//! [`crate::util::prop::assert_cut_cost_equal`] — not bit-identity.
//! [`FleetStats`] carries the reduced-vs-full DAG sizes so tests can
//! assert the smaller solves actually happen. Reduction is **off** for
//! [`crate::partition::PartitionPlanner`], the thin single-tier wrapper
//! over this engine: its contract (and PR-1's warm≡cold property tests)
//! is bit-identity with the cold general engine, which is also what the
//! cost-equivalence suites diff the reduced path against.
//!
//! # Incremental (flow-reusing) re-solves
//!
//! Between two solves of one tier only σ — and, for the joint planner's
//! price probes, λ — changes (the spec — DAG, bytes, server costs, ξ_D —
//! is fixed at construction), so consecutive flow networks differ only in
//! capacities. With [`FleetOptions::incremental`] on (the default), a
//! tier that already holds a solved flow re-solves through
//! [`crate::maxflow::incremental`]: the refresh keeps the carried flow
//! per edge ([`FlowNetwork::update_edge_capacity`]), conservation is
//! repaired at the few arcs whose new capacity undercut their flow, and
//! Dinic merely augments the repaired residual — typically zero or one
//! BFS phase on a small σ drift instead of a from-scratch run. The
//! per-tier `has_flow` flag marks whether the network carries a
//! reusable flow; any repair failure falls back to the cold refresh +
//! solve, so correctness never depends on the repair pass. Like the block
//! reduction, the incremental path is pinned **cost-equivalent** (a
//! different maximum flow may expose a different co-optimal cut);
//! incremental **off** keeps the engine bit-identical to the PR-1 cold
//! refresh path, which is what [`crate::partition::PartitionPlanner`]
//! wraps. [`FleetStats`] counts `incremental_solves`, `repair_pushes`
//! and `augment_rounds` so tests and benches can prove the fast path ran.
//!
//! # Parallel dirty-tier sweep (`parallel` feature)
//!
//! The per-tier solve loop in [`FleetPlanner::plan`] iterates explicit
//! [`TierJob`]s — each owns `&mut TierState` plus that tier's request
//! groups and only reads the shared spec/shape — and runs them through
//! `rayon::par_iter_mut` when the `parallel` cargo feature is enabled
//! (a vendored `std::thread::scope`-backed rayon stand-in offline).
//! Tiers are solved in index order within a job and jobs are mutually
//! independent, so feature-on and feature-off produce **bit-identical**
//! decisions and stats — pinned by the determinism test below.

use super::blockwise::Reduction;
use super::general::linear_scan_partition_priced;
use super::types::{Link, Partition, Problem};
use crate::maxflow::{dinic_with, DinicScratch, FlowNetwork, IncrementalScratch, MinCut};
use crate::profiles::{CostGraph, DeviceProfile};

/// Link-independent, tier-independent structure of the transformed flow
/// network: the shared half of the struct-of-arrays capacity layout (see
/// the module docs).
pub(crate) struct NetShape {
    /// Tier-independent part of each forward edge's capacity. Device-exec
    /// edges (ids `2v+1`) hold `0.0` here; their tier term lives in the
    /// per-tier `exec_base` vector. Server-exec edges (ids `2v`) hold
    /// `0.0` too (or `∞` for pinned inputs); their load-dependent term
    /// lives in `srv_base`.
    base: Vec<f64>,
    /// Coefficient of `σ = 1/R_up + 1/R_down` in each capacity.
    bw_scale: Vec<f64>,
    /// Coefficient of the server congestion price `λ` (the joint planner's
    /// load multiplier on server FLOPs): `N_loc·ξ_S(v)` on layer v's
    /// server-exec edge, `0.0` everywhere else. At the dedicated-server
    /// price `λ = 1` the three-term capacity
    /// `base + bw_scale·σ + srv_base·λ` is bit-identical to the historical
    /// two-term form (`x·1.0 = x` and `y + 0.0 = y` exactly, all terms
    /// non-negative), which is what keeps every λ=1 engine configuration
    /// byte-for-byte unchanged.
    srv_base: Vec<f64>,
    /// exec[v] = flow vertex carrying layer v's execution semantics.
    exec: Vec<usize>,
    source: usize,
    sink: usize,
    vertices: usize,
    edges: usize,
}

impl NetShape {
    /// Build the transformed network structure (Alg. 1 weights + Fig. 3
    /// auxiliary vertices + optional closure edges) and its frozen
    /// prototype [`FlowNetwork`] with all capacities at zero. Edge
    /// insertion order matches the historical one-shot construction so
    /// solver traversal (and thus tie-breaking among equal minimum cuts)
    /// is unchanged; in particular layer v's server-exec edge is id `2v`
    /// and its device-exec edge id `2v+1`.
    pub(crate) fn build(
        c: &CostGraph,
        pin_inputs: bool,
        closure_edges: bool,
    ) -> (NetShape, FlowNetwork) {
        let n = c.len();
        // Flow network layout: ids 0..n are layer vertices, n is source,
        // n+1 is sink, auxiliary vertices appended after.
        let mut exec: Vec<usize> = (0..n).collect();
        let source = n;
        let sink = n + 1;
        let mut next = n + 2;
        let split: Vec<bool> = (0..n).map(|v| c.dag.out_degree(v) > 1).collect();
        for v in 0..n {
            if split[v] {
                exec[v] = next;
                next += 1;
            }
        }
        let num_split = next - (n + 2);
        let dag_edges = c.dag.num_edges();
        let closure = if closure_edges { dag_edges + num_split } else { 0 };
        let num_edges = 2 * n + dag_edges + num_split + closure;

        let mut net = FlowNetwork::with_capacity(next, num_edges);
        let mut base = Vec::with_capacity(num_edges);
        let mut bw_scale = Vec::with_capacity(num_edges);
        let mut srv_base = vec![0.0; num_edges];

        for v in 0..n {
            // Server execution edge (s -> exec(v)), Eq. (10). Pinned inputs
            // (raw data) may never move to the server: infinite weight
            // (price-independent — `srv_base` stays 0 so no finite λ can
            // alter it). The finite N_loc·ξ_S weight goes into `srv_base`
            // so the joint planner's congestion price scales it.
            net.add_edge(source, exec[v], 0.0);
            if pin_inputs && c.dag.in_degree(v) == 0 {
                base.push(f64::INFINITY);
            } else {
                base.push(0.0);
                srv_base[2 * v] = c.n_loc * c.xi_s[v];
            }
            bw_scale.push(0.0);
            // Device execution edge (exec(v) -> t), Eq. (9) + the one-off
            // model up/download of the layer's parameters. The N_loc·ξ_D
            // base term is the tier-dependent half of the SoA layout.
            net.add_edge(exec[v], sink, 0.0);
            base.push(0.0);
            bw_scale.push(c.param_bytes[v]);
        }

        // Propagation edges + the auxiliary (exec -> transmit) edge of
        // Fig. 3. Incoming edges of a split child are redirected to its
        // auxiliary vertex, Eq. (13).
        for e in c.dag.edges() {
            let from = if split[e.from] { e.from } else { exec[e.from] };
            net.add_edge(from, exec[e.to], 0.0);
            base.push(0.0);
            bw_scale.push(c.n_loc * c.act_bytes[e.from]);
            if closure_edges {
                // Precedence: child on device => parent on device.
                net.add_edge(exec[e.to], exec[e.from], 0.0);
                base.push(f64::INFINITY);
                bw_scale.push(0.0);
            }
        }
        for v in 0..n {
            if split[v] {
                // (v' -> v) carries one propagation weight, Eq. (15).
                net.add_edge(exec[v], v, 0.0);
                base.push(0.0);
                bw_scale.push(c.n_loc * c.act_bytes[v]);
                if closure_edges {
                    // Transmit node on device while execution on server is
                    // physically meaningless; forbid for unambiguous
                    // extraction.
                    net.add_edge(v, exec[v], 0.0);
                    base.push(f64::INFINITY);
                    bw_scale.push(0.0);
                }
            }
        }
        debug_assert_eq!(net.num_edges(), num_edges);
        net.freeze();
        let shape = NetShape {
            base,
            bw_scale,
            srv_base,
            exec,
            source,
            sink,
            vertices: net.len(),
            edges: net.num_edges(),
        };
        (shape, net)
    }

    /// The per-tier half of the capacity model: `exec_base[v] = N_loc·ξ_D(v)`.
    pub(crate) fn exec_base(c: &CostGraph) -> Vec<f64> {
        c.xi_d.iter().map(|&x| c.n_loc * x).collect()
    }
}

/// Re-capacitate every edge of `net` for round-trip cost `sigma`, server
/// congestion price `lambda` (1.0 = dedicated server, the non-joint
/// engines' fixed value) and tier compute `exec_base`, clearing all routed
/// flow: one O(E) pass + the O(L) device-exec overwrite, no allocation.
/// Invariant: after this call the network state is indistinguishable from a
/// cold build — every forward arc holds its full capacity, every residual
/// twin holds zero. At `lambda == 1.0` the written capacities are
/// bit-identical to the historical σ-only refresh (see [`NetShape`]).
fn refresh_capacities(
    net: &mut FlowNetwork,
    shape: &NetShape,
    exec_base: &[f64],
    sigma: f64,
    lambda: f64,
) {
    for k in 0..shape.base.len() {
        net.set_edge_capacity(
            k,
            shape.base[k] + shape.bw_scale[k] * sigma + shape.srv_base[k] * lambda,
        );
    }
    // Device-exec edges (ids 2v+1) carry the only tier-dependent term.
    for (v, &xd) in exec_base.iter().enumerate() {
        let e = 2 * v + 1;
        net.set_edge_capacity(e, xd + shape.bw_scale[e] * sigma);
    }
}

/// Flow-preserving variant of [`refresh_capacities`]: writes the exact
/// same target capacities (bit-for-bit — the device-exec override is
/// folded into the single pass) but keeps each edge's carried flow,
/// recording in `inc` every edge whose new capacity undercuts it. The
/// incremental re-solve path's refresh half; must be followed by
/// [`IncrementalScratch::resolve`] (or a cold refresh on fallback) before
/// the network state is a feasible flow again.
fn refresh_capacities_preserving(
    net: &mut FlowNetwork,
    shape: &NetShape,
    exec_base: &[f64],
    sigma: f64,
    lambda: f64,
    inc: &mut IncrementalScratch,
) {
    inc.begin();
    let layer_pairs = 2 * exec_base.len();
    for k in 0..shape.base.len() {
        // Edges 0..2L are the per-layer (server, device) exec pairs, in
        // that order; device-exec edges (odd ids) take their base from the
        // tier's exec_base instead of the shared shape.
        let target = if k < layer_pairs && k & 1 == 1 {
            exec_base[k / 2] + shape.bw_scale[k] * sigma
        } else {
            shape.base[k] + shape.bw_scale[k] * sigma + shape.srv_base[k] * lambda
        };
        let violated = net.update_edge_capacity(k, target);
        inc.record(k, violated);
    }
}

/// The Alg. 2 transformed network for a single (model, device-tier) pair:
/// a [`NetShape`] plus its working network and tier base — the cold-path
/// unit `partition::general` builds per call and the fleet engine
/// replicates per tier.
pub(crate) struct TransformedNet {
    shape: NetShape,
    net: FlowNetwork,
    exec_base: Vec<f64>,
}

impl TransformedNet {
    /// Build for one cost graph. Capacities are left at zero; call
    /// [`TransformedNet::refresh`] with a link before solving.
    pub(crate) fn build(c: &CostGraph, pin_inputs: bool, closure_edges: bool) -> TransformedNet {
        let (shape, net) = NetShape::build(c, pin_inputs, closure_edges);
        TransformedNet {
            exec_base: NetShape::exec_base(c),
            shape,
            net,
        }
    }

    /// One O(E) capacity refresh for the given link (see
    /// [`refresh_capacities`]), at the dedicated-server price λ = 1.
    pub(crate) fn refresh(&mut self, link: Link) {
        refresh_capacities(&mut self.net, &self.shape, &self.exec_base, link.sigma(), 1.0);
    }

    /// Solve min s-t cut on the current capacities.
    pub(crate) fn min_cut(&mut self, scratch: &mut DinicScratch) -> MinCut {
        dinic_with(&mut self.net, self.shape.source, self.shape.sink, scratch)
    }

    /// Solve min s-t cut with the push-relabel oracle instead of Dinic —
    /// the cross-solver parity suites' entry point onto the *transformed*
    /// (Alg. 2) networks the fleet path actually solves. Call
    /// [`TransformedNet::refresh`] first; the run leaves routed flow
    /// behind, so refresh again before any subsequent solve.
    #[cfg(test)]
    pub(crate) fn min_cut_push_relabel(&mut self) -> MinCut {
        crate::maxflow::push_relabel(&mut self.net, self.shape.source, self.shape.sink)
    }

    /// Read the layer assignment off the execution vertices.
    pub(crate) fn device_set(&self, source_side: &[bool]) -> Vec<bool> {
        self.shape.exec.iter().map(|&e| source_side[e]).collect()
    }

    pub(crate) fn num_vertices(&self) -> usize {
        self.shape.vertices
    }

    pub(crate) fn num_edges(&self) -> usize {
        self.shape.edges
    }
}

/// The fleet-wide Theorem 2 reduction: one detection + intra-block min-cut
/// pass (activation bytes are tier-independent), one full→reduced vertex
/// mapping shared by every tier, and the per-tier *reduced* cost graphs the
/// solver actually runs on. The reduced graphs preserve the SoA invariant
/// of the full ones — identical DAG/bytes/server costs, only the summed
/// ξ_D differs — so [`NetShape`] and `assert_shared_shape` apply unchanged.
struct FleetReduction {
    /// Full vertex → reduced vertex (identical for every tier).
    to_reduced: Vec<usize>,
    /// Per-tier reduced cost graphs, in the spec's tier order.
    reduced: Vec<CostGraph>,
}

/// A tier's reduced cost graph differs from the (already-reduced) template
/// only in ξ_D — `assert_shared_shape` guarantees everything else is
/// identical — so it is rebuilt by accumulating the tier's per-layer device
/// costs through the shared full→reduced mapping instead of re-running the
/// whole reduction per tier. The accumulation visits a block's members in
/// vertex-id order while `reduce` sums them in topo-position order; when
/// those differ the ξ_D sums may differ from a direct `Reduction::apply`
/// in the last ULPs, which is below the cost-equivalence tolerance that
/// pins every reduced decision (reduced tiers carry no bit-identity
/// contract — that belongs to the unreduced path only).
fn retarget_xi_d(template: &CostGraph, to_reduced: &[usize], tier: &CostGraph) -> CostGraph {
    let mut xi_d = vec![0.0; template.len()];
    for (v, &r) in to_reduced.iter().enumerate() {
        xi_d[r] += tier.xi_d[v];
    }
    CostGraph {
        dag: template.dag.clone(),
        xi_d,
        xi_s: template.xi_s.clone(),
        act_bytes: template.act_bytes.clone(),
        param_bytes: template.param_bytes.clone(),
        n_loc: template.n_loc,
    }
}

/// (costs the tier's solver runs on, expansion input for [`solve_tier`]):
/// the reduced graph plus the mapping back to the tier's full graph when
/// the reduction is active, the full graph alone otherwise. Free function
/// over split borrows so `plan`'s per-tier loop can hold `tiers` mutably.
fn tier_inputs<'a>(
    reduction: &'a Option<FleetReduction>,
    spec: &'a FleetSpec,
    tier: usize,
) -> (&'a CostGraph, Option<(&'a [usize], &'a CostGraph)>) {
    match reduction {
        None => (&spec.tiers[tier].1, None),
        Some(r) => (
            &r.reduced[tier],
            Some((r.to_reduced.as_slice(), &spec.tiers[tier].1)),
        ),
    }
}

/// One churn event against a live fleet: the planner-as-a-service delta
/// vocabulary (PR 6). Deltas patch the [`FleetSpec`] — and, through
/// [`FleetPlanner::apply`], the planner's per-tier SoA state — in place:
/// untouched tiers keep their warm flows and cached decisions, a retired
/// tier's state is archived behind a TTL (see [`FleetOptions::retire_ttl`])
/// so late requests get a deterministic [`DecisionProvenance::Retired`]
/// answer instead of a panic.
#[derive(Clone, Debug)]
pub enum SpecDelta {
    /// A new device tier joins the fleet. The cost graph must share the
    /// fleet's SoA shape (same model + server; only ξ_D may differ) —
    /// checked by the same `assert_shared_shape` as construction.
    AddTier {
        name: &'static str,
        costs: CostGraph,
    },
    /// A tier leaves the fleet. Its devices are detached (become departed)
    /// and the planner archives the tier's last-good decision behind a TTL.
    /// Tier indices are stable: the slot stays, marked retired.
    RetireTier { tier: usize },
    /// A device joins (or re-joins) the fleet on an active tier. `device`
    /// is the caller-scoped slot: out-of-range slots grow the mapping,
    /// in-range slots must currently be departed.
    AddDevice { device: usize, tier: usize },
    /// A device leaves the fleet; its slot stays (stable indices) but maps
    /// to no tier until a re-join.
    RemoveDevice { device: usize },
    /// A device moves between two active tiers (e.g. a hardware swap or a
    /// profile re-measurement reassigning it).
    MigrateDevice { device: usize, tier: usize },
}

/// A malformed churn event, rejected by [`FleetSpec::try_apply`] before
/// any state moved (validation precedes every patch, so a rejected delta
/// leaves the spec — and, through [`FleetPlanner::try_apply`], the
/// planner — exactly as it was). The panicking [`FleetSpec::apply`] wraps
/// this; daemon-facing callers route through the `try_` form so a
/// misbehaving producer is counted and dropped instead of crashing the
/// planning loop (see `crate::daemon::ingest`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpecError {
    /// The delta names a tier index the spec does not have.
    UnknownTier { tier: usize },
    /// An `AddDevice`/`MigrateDevice` targets a tier that has retired.
    RetiredTier { tier: usize },
    /// A `RetireTier` names a tier that already retired.
    AlreadyRetired { tier: usize },
    /// A `RemoveDevice`/`MigrateDevice` names a slot that is not
    /// currently in the fleet (out of range, or departed).
    UnknownDevice { device: usize },
    /// An `AddDevice` names a slot that is already live.
    DeviceAlreadyPresent { device: usize },
}

impl std::fmt::Display for SpecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SpecError::UnknownTier { tier } => write!(f, "unknown tier {tier}"),
            SpecError::RetiredTier { tier } => write!(f, "tier {tier} has retired"),
            SpecError::AlreadyRetired { tier } => write!(f, "tier {tier} already retired"),
            SpecError::UnknownDevice { device } => {
                write!(f, "device {device} is not in the fleet")
            }
            SpecError::DeviceAlreadyPresent { device } => {
                write!(f, "device {device} is already in the fleet")
            }
        }
    }
}

impl std::error::Error for SpecError {}

/// Where a served decision came from — the churn-tolerant service layer's
/// provenance contract (PR 6). Every decision is *feasible* regardless of
/// provenance (cut feasibility is link-independent; see RESILIENCE.md);
/// provenance tells the caller how fresh its cost is.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecisionProvenance {
    /// Solved this epoch against the request's link.
    Fresh,
    /// Served bit-exact from the tier's warm cache (same link as the
    /// cached solve — earlier in the batch or a previous epoch).
    Cached,
    /// Served by the degraded-mode policy of `partition::service`: the
    /// last-good decision, because the input was stale or the solve
    /// budget ran out. Cost is within the stale-σ envelope (PERF.md PR 6).
    Degraded(DegradedReason),
    /// The request named a retired tier; the answer is the tier's archived
    /// last-good cut (within the retire TTL) or the device-only fallback.
    Retired,
}

/// Why the service degraded a decision instead of re-planning.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DegradedReason {
    /// The device's link report was older than the staleness bound.
    StaleLink,
    /// The per-epoch solve budget was exhausted before this device's
    /// group could be re-planned.
    BudgetExceeded,
}

/// A fleet of devices deduplicated into tiers: one [`CostGraph`] per tier
/// (same model + server, per-tier device compute) and the device → tier
/// mapping. This is the construction-time input of [`FleetPlanner`]; the
/// coordinator and the simulator both build it with
/// [`FleetSpec::from_fleet`], which replaces their previously duplicated
/// dedup loops. Post-construction the spec is live: [`FleetSpec::apply`]
/// patches it with churn events ([`SpecDelta`]) under two stability
/// invariants — tier indices never move (a retired tier keeps its slot)
/// and device slots never move (a departed device keeps its slot, mapped
/// to no tier).
#[derive(Clone)]
pub struct FleetSpec {
    tiers: Vec<(&'static str, CostGraph)>,
    /// Per tier: true once the tier left the fleet (slot retained).
    retired: Vec<bool>,
    /// Per device slot: `Some(tier)` while the device is in the fleet,
    /// `None` after it departs (slot retained for stable ids).
    tier_of_device: Vec<Option<usize>>,
}

impl FleetSpec {
    /// Explicit construction from per-tier cost graphs + device mapping.
    pub fn new(tiers: Vec<(&'static str, CostGraph)>, tier_of_device: Vec<usize>) -> FleetSpec {
        assert!(!tiers.is_empty(), "a fleet needs at least one tier");
        assert!(
            tier_of_device.iter().all(|&t| t < tiers.len()),
            "device mapped to unknown tier"
        );
        FleetSpec {
            retired: vec![false; tiers.len()],
            tiers,
            tier_of_device: tier_of_device.into_iter().map(Some).collect(),
        }
    }

    /// Deduplicate a device fleet by tier name, building each tier's cost
    /// graph exactly once. Tier indices follow first-seen device order.
    pub fn from_fleet(
        fleet: &[DeviceProfile],
        mut build: impl FnMut(&DeviceProfile) -> CostGraph,
    ) -> FleetSpec {
        let mut tiers: Vec<(&'static str, CostGraph)> = Vec::new();
        let mut tier_of_device = Vec::with_capacity(fleet.len());
        for d in fleet {
            let idx = match tiers.iter().position(|(n, _)| *n == d.name) {
                Some(i) => i,
                None => {
                    tiers.push((d.name, build(d)));
                    tiers.len() - 1
                }
            };
            tier_of_device.push(idx);
        }
        FleetSpec::new(tiers, tier_of_device)
    }

    /// A one-tier, one-device fleet (the [`super::PartitionPlanner`] case).
    pub fn single(costs: CostGraph) -> FleetSpec {
        FleetSpec::new(vec![("single", costs)], vec![0])
    }

    pub fn num_tiers(&self) -> usize {
        self.tiers.len()
    }

    /// Device *slots*, including departed devices (slots are stable ids —
    /// see [`FleetSpec::active_devices`] for the live count).
    pub fn num_devices(&self) -> usize {
        self.tier_of_device.len()
    }

    /// Devices currently in the fleet.
    pub fn active_devices(&self) -> usize {
        self.tier_of_device.iter().filter(|t| t.is_some()).count()
    }

    /// Tier index of a device; panics if the device has departed (use
    /// [`FleetSpec::tier_of_opt`] when churn is in play).
    pub fn tier_of(&self, device: usize) -> usize {
        self.tier_of_device[device]
            .unwrap_or_else(|| panic!("device {device} has departed the fleet"))
    }

    /// Tier index of a device, `None` once it departed.
    pub fn tier_of_opt(&self, device: usize) -> Option<usize> {
        self.tier_of_device.get(device).copied().flatten()
    }

    /// True once `tier` left the fleet (its slot is retained).
    pub fn tier_retired(&self, tier: usize) -> bool {
        self.retired[tier]
    }

    pub fn tier_name(&self, tier: usize) -> &'static str {
        self.tiers[tier].0
    }

    pub fn tier_costs(&self, tier: usize) -> &CostGraph {
        &self.tiers[tier].1
    }

    /// One [`PlanRequest`] per *active* device of the fleet, each carrying
    /// its tier's link — the per-tier broadcast channel-state pattern of a
    /// fleet epoch (shared by the coordinator demo, the Table I fleet
    /// column, and `benches/fleet.rs`). Departed device slots are skipped.
    pub fn requests(&self, link_of_tier: impl Fn(usize) -> Link) -> Vec<PlanRequest> {
        self.tier_of_device
            .iter()
            .enumerate()
            .filter_map(|(device, &tier)| {
                tier.map(|tier| PlanRequest {
                    device,
                    tier,
                    link: link_of_tier(tier),
                })
            })
            .collect()
    }

    /// Check one churn event against the current spec without applying
    /// it: the shared gate of [`FleetSpec::try_apply`] and
    /// [`FleetPlanner::try_apply`] (the planner must validate *before*
    /// touching its per-tier state, so a rejected delta leaves the whole
    /// stack untouched).
    pub fn validate(&self, delta: &SpecDelta) -> Result<(), SpecError> {
        let tier_ok = |tier: usize| {
            if tier >= self.tiers.len() {
                Err(SpecError::UnknownTier { tier })
            } else if self.retired[tier] {
                Err(SpecError::RetiredTier { tier })
            } else {
                Ok(())
            }
        };
        match delta {
            SpecDelta::AddTier { .. } => Ok(()),
            SpecDelta::RetireTier { tier } => {
                if *tier >= self.tiers.len() {
                    Err(SpecError::UnknownTier { tier: *tier })
                } else if self.retired[*tier] {
                    Err(SpecError::AlreadyRetired { tier: *tier })
                } else {
                    Ok(())
                }
            }
            SpecDelta::AddDevice { device, tier } => {
                tier_ok(*tier)?;
                if self.tier_of_opt(*device).is_some() {
                    Err(SpecError::DeviceAlreadyPresent { device: *device })
                } else {
                    Ok(())
                }
            }
            SpecDelta::RemoveDevice { device } => {
                if self.tier_of_opt(*device).is_none() {
                    Err(SpecError::UnknownDevice { device: *device })
                } else {
                    Ok(())
                }
            }
            SpecDelta::MigrateDevice { device, tier } => {
                tier_ok(*tier)?;
                if self.tier_of_opt(*device).is_none() {
                    Err(SpecError::UnknownDevice { device: *device })
                } else {
                    Ok(())
                }
            }
        }
    }

    /// Patch the spec with one churn event, rejecting malformed deltas
    /// (unknown tier or device, double-retire, adding over a live slot,
    /// migrating a departed device or onto a retired tier) with a typed
    /// [`SpecError`] *before* any state moves — a rejected delta is a
    /// no-op.
    pub fn try_apply(&mut self, delta: &SpecDelta) -> Result<(), SpecError> {
        self.validate(delta)?;
        match delta {
            SpecDelta::AddTier { name, costs } => {
                self.tiers.push((name, costs.clone()));
                self.retired.push(false);
            }
            SpecDelta::RetireTier { tier } => {
                self.retired[*tier] = true;
                // Detach the tier's devices: they depart with their tier.
                for slot in &mut self.tier_of_device {
                    if *slot == Some(*tier) {
                        *slot = None;
                    }
                }
            }
            SpecDelta::AddDevice { device, tier } => {
                if *device >= self.tier_of_device.len() {
                    self.tier_of_device.resize(*device + 1, None);
                }
                self.tier_of_device[*device] = Some(*tier);
            }
            SpecDelta::RemoveDevice { device } => {
                self.tier_of_device[*device] = None;
            }
            SpecDelta::MigrateDevice { device, tier } => {
                self.tier_of_device[*device] = Some(*tier);
            }
        }
        Ok(())
    }

    /// [`FleetSpec::try_apply`] for callers that treat churn as a stream
    /// of facts about the fleet: a contradictory fact is a caller bug, so
    /// this panics where `try_apply` returns the typed error.
    pub fn apply(&mut self, delta: &SpecDelta) {
        if let Err(e) = self.try_apply(delta) {
            panic!("malformed churn event: {e}");
        }
    }

    /// A stable 64-bit fingerprint of the *model/server shape* this fleet
    /// plans for: an FNV-1a fold over exactly the fields
    /// `assert_shared_shape` proves identical across every tier — layer
    /// count, DAG topology (edge endpoints), activation/parameter bytes,
    /// server compute costs, and N_loc. Fleet membership (device slots,
    /// tier count, retirement flags) and per-tier ξ_D deliberately do
    /// **not** enter the hash: churn events recorded in a journal tail —
    /// including `AddTier` — must not invalidate the journal header's
    /// fingerprint, while a journal recorded against a different model or
    /// server must be refused at recovery (`daemon::journal`'s
    /// `ForeignModel` contract).
    pub fn fingerprint(&self) -> u64 {
        fn fold(h: &mut u64, v: u64) {
            for byte in v.to_le_bytes() {
                *h ^= byte as u64;
                *h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
        }
        let c = &self.tiers[0].1;
        let mut h = 0xcbf2_9ce4_8422_2325u64; // FNV-1a offset basis
        fold(&mut h, c.len() as u64);
        fold(&mut h, c.dag.num_edges() as u64);
        for e in c.dag.edges() {
            fold(&mut h, e.from as u64);
            fold(&mut h, e.to as u64);
        }
        for &a in &c.act_bytes {
            fold(&mut h, a.to_bits());
        }
        for &k in &c.param_bytes {
            fold(&mut h, k.to_bits());
        }
        for &s in &c.xi_s {
            fold(&mut h, s.to_bits());
        }
        fold(&mut h, c.n_loc.to_bits());
        h
    }

    /// Rebuild a spec from recovered parts — the `daemon::snapshot`
    /// decoder's constructor. Unlike [`FleetSpec::new`] this can express
    /// retired tiers and departed device slots (states only reachable
    /// through churn); the membership invariants are asserted the same
    /// way.
    pub(crate) fn from_parts(
        tiers: Vec<(&'static str, CostGraph)>,
        retired: Vec<bool>,
        tier_of_device: Vec<Option<usize>>,
    ) -> FleetSpec {
        assert!(!tiers.is_empty(), "a fleet needs at least one tier");
        assert_eq!(tiers.len(), retired.len(), "one retire flag per tier");
        assert!(
            tier_of_device
                .iter()
                .flatten()
                .all(|&t| t < tiers.len() && !retired[t]),
            "device mapped to unknown or retired tier"
        );
        FleetSpec {
            tiers,
            retired,
            tier_of_device,
        }
    }
}

/// One device's planning request for the current epoch.
#[derive(Clone, Copy, Debug)]
pub struct PlanRequest {
    /// Caller-scoped device id, echoed back in the decision.
    pub device: usize,
    /// Tier index within the [`FleetSpec`] (see [`FleetSpec::tier_of`]).
    pub tier: usize,
    /// The device's current link state (bytes/s).
    pub link: Link,
}

/// Construction-time switches of the fleet engine (see
/// [`FleetPlanner::with_options`]). `Default` is the full fast
/// configuration: pinned inputs, closure edges, block reduction and
/// incremental re-solves all on — what [`FleetPlanner::new`] builds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FleetOptions {
    /// Input layers (raw data) may never move to the server.
    pub pin_inputs: bool,
    /// Infinite precedence edges for unambiguous cut extraction.
    pub closure_edges: bool,
    /// Fleet-level Theorem 2 block reduction (cost-equivalent decisions).
    pub block_reduction: bool,
    /// GGT-style flow-reusing re-solves when only σ changed since a
    /// tier's previous solve (cost-equivalent decisions); off = the PR-1
    /// bit-identical cold-refresh path.
    pub incremental: bool,
    /// How many `plan` epochs a retired tier's archived last-good decision
    /// stays servable. Within the TTL a late request for the tier is
    /// answered with the archived cut re-evaluated at the request's link
    /// (always feasible — cut feasibility is link-independent); past it
    /// the archive is dropped and the deterministic device-only fallback
    /// is served instead. Both are [`DecisionProvenance::Retired`].
    pub retire_ttl: u64,
    /// σ-quantization resolution of the log-spaced per-tier bandwidth
    /// grid ([`SigmaQuantizer`]): how many buckets each decade of link
    /// rate is split into. `0` (the default) disables quantization —
    /// every distinct link solves exactly, the historical behavior.
    /// With `b > 0`, each epoch batch snaps every request's link to its
    /// (tier, bucket)'s canonical representative before cache lookup /
    /// refresh, so distinct-but-close links share one solve; the served
    /// cost stays within the analytic per-bucket bound (PERF.md "PR 8",
    /// pinned by `assert_cut_cost_within`).
    pub sigma_buckets_per_decade: u32,
}

impl Default for FleetOptions {
    fn default() -> FleetOptions {
        FleetOptions {
            pin_inputs: true,
            closure_edges: true,
            block_reduction: true,
            incremental: true,
            retire_ttl: 64,
            sigma_buckets_per_decade: 0,
        }
    }
}

impl FleetOptions {
    /// The unreduced, non-incremental engine: bit-identical to the cold
    /// general engine — the [`crate::partition::PartitionPlanner`]
    /// contract and the reference configuration the cost-equivalence
    /// suites diff the fast paths against.
    pub fn bit_identical() -> FleetOptions {
        FleetOptions {
            block_reduction: false,
            incremental: false,
            ..FleetOptions::default()
        }
    }
}

/// The log-spaced per-tier bandwidth grid of the million-device scale
/// path: each link rate is binned into `floor(log10(rate)·b)` for `b`
/// buckets per decade, and a link's bucket is the pair of its (up, down)
/// rate buckets. Within one epoch batch, every (tier, bucket) snaps to a
/// **canonical representative** — the bucket's member link with the
/// smallest `(up, down)` bit pattern (positive finite f64 bit order is
/// numeric order, so this is the slowest member, deterministic under any
/// request order and any tier sharding). Snapping to a batch member
/// rather than a fixed grid point keeps two contracts exact:
///
/// - a *sub-resolution* fleet (no two links of a tier share a bucket)
///   rewrites nothing, so quantization-on is **bit-identical** to
///   quantization-off there, and
/// - re-quantizing an already-snapped batch is the identity, so stacked
///   entry points (service → joint → fleet) never double-count.
///
/// For a fixed cut, Eq. (7) delay is affine in σ = 1/R_up + 1/R_down
/// (`T(σ) = C + B·σ` with `B` the cut's `bw_scale` mass), so serving a
/// bucket sibling's cut costs at most `(B_served + B_opt)` times the
/// bucket's σ-width ([`SigmaQuantizer::sigma_width`]) — the analytic
/// bound the PR-8 property suite pins via `assert_cut_cost_within`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SigmaQuantizer {
    buckets_per_decade: u32,
}

impl SigmaQuantizer {
    /// A quantizer at `buckets_per_decade` resolution, `None` when 0
    /// (quantization disabled — the [`FleetOptions`] encoding).
    pub fn new(buckets_per_decade: u32) -> Option<SigmaQuantizer> {
        (buckets_per_decade > 0).then_some(SigmaQuantizer { buckets_per_decade })
    }

    pub fn buckets_per_decade(&self) -> u32 {
        self.buckets_per_decade
    }

    /// Grid index of one rate: `floor(log10(rate)·b)`. Monotone in the
    /// rate; rates on a grid line land deterministically on whichever
    /// side float `log10` resolves to (the error bound does not depend
    /// on the tie direction — only on the bucket width).
    pub fn rate_bucket(&self, rate_bps: f64) -> i64 {
        (rate_bps.log10() * self.buckets_per_decade as f64).floor() as i64
    }

    /// A link's (up, down) bucket pair.
    pub fn bucket_key(&self, link: Link) -> (i64, i64) {
        (self.rate_bucket(link.up_bps), self.rate_bucket(link.down_bps))
    }

    /// Analytic σ-width of the bucket holding `link`: rates of bucket
    /// `i` span `[10^(i/b), 10^((i+1)/b))`, so their reciprocal spans an
    /// interval of width `10^(-i/b)·(1 − 10^(-1/b))`; σ sums one such
    /// interval per direction. Any two links sharing the bucket pair
    /// differ in σ by at most this — the `Δσ` of the per-bucket cost
    /// bound.
    pub fn sigma_width(&self, link: Link) -> f64 {
        let b = self.buckets_per_decade as f64;
        let (i, j) = self.bucket_key(link);
        let shrink = 1.0 - 10f64.powf(-1.0 / b);
        shrink * (10f64.powf(-(i as f64) / b) + 10f64.powf(-(j as f64) / b))
    }
}

/// A malformed plan request, rejected by [`FleetPlanner::try_plan`]
/// before any planner state moves (counters, TTLs and caches are all
/// untouched by a rejected batch). The panicking [`FleetPlanner::plan`]
/// wraps this; service-facing callers route through the `try_` form so a
/// misbehaving producer that bypassed the daemon's ingest validation is
/// refused instead of crashing the epoch loop.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RequestError {
    /// The request names a tier index the spec does not have.
    UnknownTier { tier: usize },
    /// The request's link has a non-finite or non-positive rate
    /// ([`Link::is_valid`]); planning on it would poison the SoA
    /// capacity refresh with NaN/∞ capacities.
    InvalidLink {
        device: usize,
        up_bps: f64,
        down_bps: f64,
    },
}

impl std::fmt::Display for RequestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RequestError::UnknownTier { tier } => {
                write!(f, "plan request for unknown tier {tier}")
            }
            RequestError::InvalidLink {
                device,
                up_bps,
                down_bps,
            } => write!(
                f,
                "rates must be positive and finite \
                 (device {device} reported up {up_bps} B/s, down {down_bps} B/s)"
            ),
        }
    }
}

impl std::error::Error for RequestError {}

/// Per-decision solver provenance.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DecisionStats {
    /// True iff this request triggered the tier's refresh + solve; false
    /// when the decision was served from the tier's cached solve (same
    /// link, earlier in the batch or a previous epoch).
    pub refreshed: bool,
}

/// The planner's answer for one request.
#[derive(Clone, Debug)]
pub struct PlanDecision {
    pub device: usize,
    pub tier: usize,
    /// The optimal partition (Eq. (7)-minimal device set + its delay).
    pub partition: Partition,
    /// Prefix cut position when the device set is index-contiguous (always,
    /// for chain models) — see [`Partition::cut_layer`].
    pub cut_layer: Option<usize>,
    pub stats: DecisionStats,
    /// Where this decision came from (fresh solve, warm cache, degraded
    /// fallback, retired-tier archive) — the PR-6 service contract.
    pub provenance: DecisionProvenance,
}

/// Aggregate solver counters (see the module docs' batched-refresh
/// invariant). `refreshes == flow_solves` always; they are distinct fields
/// because the linear fast path solves without a capacity refresh.
///
/// The `full_*`/`reduced_*` fields expose the fleet-level block reduction:
/// `reduced_vertices < full_vertices` proves every solve of this planner
/// ran on the Theorem 2 reduced DAG rather than the full model DAG (they
/// are equal when no block was abstracted or reduction was disabled).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FleetStats {
    /// `plan` calls served (one per epoch in the coordinator loop).
    pub plans: u64,
    /// Total requests across all `plan` calls.
    pub requests: u64,
    /// O(E) capacity-refresh passes performed (dirty tiers only).
    pub refreshes: u64,
    /// Dinic runs (== refreshes; every refresh is followed by one solve).
    pub flow_solves: u64,
    /// Linear-scan solves (chain *solve* DAGs — either a chain model or a
    /// block model whose reduced DAG collapsed to a chain — take the O(L)
    /// fast path instead of the flow network).
    pub linear_scans: u64,
    /// Flow solves that reused the previous epoch's flow (repair +
    /// residual augmentation) instead of running Dinic from zero. Always
    /// `<= flow_solves`; 0 when [`FleetOptions::incremental`] is off, on
    /// the linear path, or when every solve was a tier's first.
    pub incremental_solves: u64,
    /// Arc cancellations performed by incremental repair passes (0 on
    /// pure capacity-increase refreshes — the monotone GGT case).
    pub repair_pushes: u64,
    /// BFS phases run by incremental residual augmentations.
    pub augment_rounds: u64,
    /// Vertices of the full model DAG (shared by every tier).
    pub full_vertices: usize,
    /// Edges of the full model DAG.
    pub full_edges: usize,
    /// Vertices of the DAG the engine actually solves on.
    pub reduced_vertices: usize,
    /// Edges of the DAG the engine actually solves on.
    pub reduced_edges: usize,
    /// Blocks found by Alg. 3 detection (0 when reduction is disabled —
    /// detection is skipped entirely on the bit-exact general path).
    pub blocks_detected: usize,
    /// Blocks that passed the Theorem 2 test and were abstracted.
    pub blocks_abstracted: usize,
    /// Makespan-target probes of the joint planner's price loop (outer
    /// bisection iterations over the shared-server congestion level).
    /// Always 0 for a plain [`FleetPlanner`] and for a
    /// [`super::joint::JointPlanner`] with infinite server capacity —
    /// part of the ∞-capacity bit-identity contract.
    pub price_iterations: u64,
    /// Priced per-tier re-solves (λ probes) the joint loop triggered on
    /// top of the λ=1 epoch pass. Each is also counted in `refreshes`/
    /// `flow_solves` (or `linear_scans`) by the tier that served it.
    pub joint_resolves: u64,
    /// Incremental repair attempts that dead-ended and fell back to a
    /// cold refresh + Dinic run. Always `<= flow_solves -
    /// incremental_solves`; 0 when [`FleetOptions::incremental`] is off
    /// or every repair succeeded. Each fallback's cold solve is already
    /// in `flow_solves` — this counter only says the warm path was tried
    /// and lost (the PR-4 `None` dead-end that was previously invisible).
    pub fallback_cold_solves: u64,
    /// [`SpecDelta`] events applied through [`FleetPlanner::apply`].
    pub spec_deltas: u64,
    /// Decisions served with [`DecisionProvenance::Retired`] (late
    /// requests for a retired tier).
    pub retired_decisions: u64,
    /// Decisions the service layer served with
    /// [`DecisionProvenance::Degraded`] (stale input or budget overrun;
    /// counted here so one [`FleetStats`] carries the whole provenance
    /// story — see `partition::service`).
    pub degraded_decisions: u64,
    /// Requests whose link was rewritten to a σ-bucket canonical
    /// representative by the [`SigmaQuantizer`]
    /// ([`FleetOptions::sigma_buckets_per_decade`] > 0). Each physical
    /// rewrite is counted exactly once even when the batch flows through
    /// stacked planners (service → joint → fleet): re-quantizing an
    /// already-snapped batch is the identity. 0 whenever quantization is
    /// off **or** the fleet is sub-resolution (no two links of a tier
    /// share a bucket) — the counter-pinned bit-identity contract.
    pub quantized_requests: u64,
    /// Dynamic-programming transitions evaluated by the multi-hop
    /// [`super::multihop::PathPlanner`] (one per `(stage, cut, feasible
    /// predecessor)` triple in the exact nested lower-set DP). 0 on the
    /// K=1 degenerate path, on the separable fast path (per-hop optima
    /// already nested), and for every planner that never ran the DP —
    /// part of the K=1 ≡ [`super::planner::PartitionPlanner`]
    /// bit-identity contract.
    pub dp_transitions: u64,
    /// Accepted device→server reassignments (moves and swaps) of the
    /// [`super::assign::MultiServerPlanner`] local search, plus
    /// assignments adopted by its exhaustive small-instance path beyond
    /// the initial seed. 0 for a single-server planner — part of the
    /// 1-server ≡ [`super::joint::JointPlanner`] bit-identity contract.
    pub assignment_moves: u64,
    /// Per-server [`super::joint::JointPlanner`] makespan evaluations the
    /// assignment search triggered (each also contributes its own inner
    /// counters — `plans`, `price_iterations`, … — to the folded stats).
    /// 0 for a single-server planner, which delegates verbatim.
    pub inner_makespan_solves: u64,
}

impl FleetStats {
    /// Total solves of either kind.
    pub fn solves(&self) -> u64 {
        self.flow_solves + self.linear_scans
    }
}

/// Per-tier mutable solver state: the only data a tier's solve touches
/// besides the shared read-only [`NetShape`] — which is what keeps the
/// per-tier loop in [`FleetPlanner::plan`] embarrassingly parallel.
struct TierState {
    /// Clone of the frozen prototype network; `None` on the linear path.
    net: Option<FlowNetwork>,
    /// `N_loc·ξ_D` per layer (the tier half of the SoA capacity layout).
    exec_base: Vec<f64>,
    scratch: DinicScratch,
    inc: IncrementalScratch,
    /// True once the network carries a solved maximum flow — the
    /// precondition of the incremental re-solve path. No payload is
    /// needed as a validity check: only σ and the server congestion price
    /// λ can change between a tier's solves (the spec is fixed at
    /// construction), and the flow-preserving refresh re-targets *every*
    /// capacity, so any carried flow is reusable against any next (σ, λ).
    has_flow: bool,
    /// The link of the tier's cached solve and its decision. A request
    /// with the same link is served from here without touching the
    /// network; any other link marks the tier dirty. Only the λ=1 plan
    /// paths ever write it (priced probes and take-style solves return
    /// their decision without caching), so every entry is a dedicated
    /// λ=1 decision.
    solved: Option<(Link, Partition)>,
    refreshes: u64,
    flow_solves: u64,
    linear_scans: u64,
    incremental_solves: u64,
    repair_pushes: u64,
    augment_rounds: u64,
    fallback_cold_solves: u64,
}

/// A retired tier's archived remains: the last-good decision behind a TTL
/// plus the tier's lifetime counters (so [`FleetPlanner::stats`] stays
/// monotone across a retirement). The network, scratch and SoA vectors are
/// freed — a retired tier never solves again.
#[derive(Default)]
struct RetiredTier {
    /// The tier's cached decision at retirement; dropped once `ttl`
    /// reaches zero. Served to late requests re-evaluated at the
    /// request's link (cut feasibility is link-independent).
    last: Option<(Link, Partition)>,
    /// Remaining `plan` epochs the archive stays servable.
    ttl: u64,
    refreshes: u64,
    flow_solves: u64,
    linear_scans: u64,
    incremental_solves: u64,
    repair_pushes: u64,
    augment_rounds: u64,
    fallback_cold_solves: u64,
}

/// A tier slot of the planner: live solver state, or the archived remains
/// of a retired tier (slots are stable — tier indices never move).
enum TierEntry {
    Active(TierState),
    Retired(RetiredTier),
}

impl TierEntry {
    fn active_mut(&mut self) -> Option<&mut TierState> {
        match self {
            TierEntry::Active(t) => Some(t),
            TierEntry::Retired(_) => None,
        }
    }

    fn is_retired(&self) -> bool {
        matches!(self, TierEntry::Retired(_))
    }
}

/// Refresh + solve one tier for `link` at server congestion price `lambda`
/// and cache the decision. `lambda` scales the server-exec capacities
/// (`λ·N_loc·ξ_S`): 1.0 is the dedicated-server problem every non-joint
/// caller solves; the joint planner probes λ > 1 to model a shared,
/// congested server (the cached [`Partition`]'s delay stays the *unpriced*
/// Eq. (7) value — the joint layer re-derives its load-dependent terms
/// itself). When the fleet reduction is active, `solve_costs` is the
/// tier's *reduced* cost graph and `expand` carries the full→reduced
/// mapping plus the full graph: the solved device set is expanded back to
/// full layers and the cached partition's delay is Eq. (7) on the full
/// graph. With [`FleetOptions::incremental`] on and a previous flow in the
/// tier's network, the solve routes through the flow-reusing refresh +
/// repair + residual augmentation — for σ refreshes *and* λ probes alike,
/// which is what makes each joint price probe a warm refresh — falling
/// back to the cold refresh + Dinic run if the repair pass dead-ends. Free
/// function over split borrows so a rayon `par_iter_mut` over tiers can
/// adopt it unchanged.
fn solve_tier(
    shape: Option<&NetShape>,
    solve_costs: &CostGraph,
    expand: Option<(&[usize], &CostGraph)>,
    options: FleetOptions,
    tier: &mut TierState,
    link: Link,
    lambda: f64,
) -> Partition {
    let FleetOptions {
        pin_inputs,
        closure_edges,
        ..
    } = options;
    let TierState {
        net,
        exec_base,
        scratch,
        inc,
        has_flow,
        refreshes,
        flow_solves,
        linear_scans,
        incremental_solves,
        repair_pushes,
        augment_rounds,
        fallback_cold_solves,
        ..
    } = tier;
    // Problem::with_pin validates the link (positive rates), exactly like
    // the cold path — a dead uplink must panic, not produce NaN capacities
    // that solve to a silent garbage cut.
    let problem = Problem::with_pin(solve_costs, link, pin_inputs);
    let solved_partition = match (shape, net.as_mut()) {
        (None, None) => {
            *linear_scans += 1;
            linear_scan_partition_priced(&problem, lambda)
        }
        (Some(shape), Some(net)) => {
            *refreshes += 1;
            *flow_solves += 1;
            let sigma = link.sigma();
            // Flow reuse is sound only across pure capacity ((σ, λ))
            // refreshes of a net that holds a solved flow; `has_flow`
            // certifies the latter, the engine's fixed spec the former.
            let mut cut = None;
            let mut attempted_repair = false;
            if options.incremental && *has_flow {
                attempted_repair = true;
                refresh_capacities_preserving(net, shape, exec_base, sigma, lambda, inc);
                if let Some((c, rs)) = inc.resolve(net, shape.source, shape.sink, scratch) {
                    *incremental_solves += 1;
                    *repair_pushes += rs.repair_pushes;
                    *augment_rounds += rs.augment_rounds;
                    cut = Some(c);
                }
                // A failed repair leaves arbitrary residual state; the
                // cold refresh below rewrites every capacity and clears
                // all flow, so the fallback solve is exact regardless.
            }
            let cut = cut.unwrap_or_else(|| {
                if attempted_repair {
                    *fallback_cold_solves += 1;
                }
                refresh_capacities(net, shape, exec_base, sigma, lambda);
                dinic_with(net, shape.source, shape.sink, scratch)
            });
            *has_flow = true;
            let device_set: Vec<bool> = shape.exec.iter().map(|&e| cut.source_side[e]).collect();
            // Without closure edges the cut need not be a lower set (that
            // is the point of ablA), so only assert under the default
            // construction — mirrors general.rs.
            debug_assert!(
                !closure_edges || problem.is_feasible(&device_set),
                "fleet planner produced an infeasible partition"
            );
            problem.partition(device_set)
        }
        _ => unreachable!("tier flow state out of sync with the shared shape"),
    };
    let partition = match expand {
        None => solved_partition,
        Some((to_reduced, full)) => {
            let device_set: Vec<bool> = to_reduced
                .iter()
                .map(|&r| solved_partition.device_set[r])
                .collect();
            let full_problem = Problem::with_pin(full, link, pin_inputs);
            debug_assert!(
                !closure_edges || full_problem.is_feasible(&device_set),
                "expanded block-reduced partition is infeasible"
            );
            full_problem.partition(device_set)
        }
    };
    partition
}

/// One tier's slice of an epoch batch: its mutable solver state, the
/// tier's distinct-link request groups, and the per-group decisions the
/// sweep produces. The unit of the (optionally rayon-parallel) dirty-tier
/// loop in [`FleetPlanner::plan`] — a job touches nothing but its own
/// `tier`/`out` plus shared read-only state, which is what makes the
/// sweep embarrassingly parallel.
struct TierJob<'a> {
    /// Tier index (keys the shared reduction/spec lookups).
    t: usize,
    tier: &'a mut TierState,
    /// This tier's (link, request indices) groups, first-seen order.
    groups: &'a [(Link, Vec<usize>)],
    /// Per-group (decision, freshly solved) results, in `groups` order.
    out: Vec<Option<(Partition, bool)>>,
}

/// Serve every group of one tier job: the group matching the tier's
/// epoch-start cache first (processed later it would find the cache
/// evicted by another of the tier's links and re-solve a decision that
/// was still valid), then the rest in first-seen order. The within-job
/// order is fixed, so the produced decisions, flow history, and counters
/// are identical however jobs are scheduled across threads.
fn run_tier_job(
    shape: Option<&NetShape>,
    solve_costs: &CostGraph,
    expand: Option<(&[usize], &CostGraph)>,
    options: FleetOptions,
    job: &mut TierJob,
) {
    let cached = job
        .tier
        .solved
        .as_ref()
        .and_then(|(l, _)| job.groups.iter().position(|(gl, _)| gl == l));
    let order = cached
        .into_iter()
        .chain((0..job.groups.len()).filter(|&g| Some(g) != cached));
    for g in order {
        let (link, _) = &job.groups[g];
        let clean = matches!(&job.tier.solved, Some((l, _)) if l == link);
        if !clean {
            let partition = solve_tier(shape, solve_costs, expand, options, job.tier, *link, 1.0);
            job.tier.solved = Some((*link, partition));
        }
        let partition = job
            .tier
            .solved
            .as_ref()
            .expect("tier just solved")
            .1
            .clone();
        job.out[g] = Some((partition, !clean));
    }
}

/// The fleet planning facade: all per-tier transformed networks behind one
/// batched request/response epoch API. See the module docs for the layout
/// and invariants; `benches/fleet.rs` measures the 10/100/1000-device epoch
/// decision times this design targets.
pub struct FleetPlanner {
    spec: FleetSpec,
    options: FleetOptions,
    /// The fleet-wide Theorem 2 reduction; `Some` iff block reduction was
    /// requested and at least one block passed the intra-block cut test.
    reduction: Option<FleetReduction>,
    /// Shared structure of the *solved* (reduced when active) DAG; `None`
    /// when that DAG is a chain (every tier then takes the O(L) linear-scan
    /// fast path — e.g. ResNet/GPT-2 fleets, whose reduced DAGs are chains).
    shape: Option<NetShape>,
    /// The frozen zero-capacity prototype network ([`NetShape::build`]),
    /// kept so [`SpecDelta::AddTier`] can clone a fresh tier network
    /// without rebuilding the shape; `None` on the linear fast path.
    proto: Option<FlowNetwork>,
    tiers: Vec<TierEntry>,
    /// (vertices, edges) of the full model DAG.
    full_dag: (usize, usize),
    /// (vertices, edges) of the DAG the solver actually runs on.
    solve_dag: (usize, usize),
    blocks_detected: usize,
    blocks_abstracted: usize,
    plans: u64,
    requests: u64,
    spec_deltas: u64,
    retired_decisions: u64,
    degraded_decisions: u64,
    quantized_requests: u64,
}

impl FleetPlanner {
    /// Plan for the default problem (pinned inputs, closure edges on,
    /// fleet-level block reduction and incremental re-solves enabled).
    pub fn new(spec: FleetSpec) -> FleetPlanner {
        FleetPlanner::with_options(spec, FleetOptions::default())
    }

    /// Explicit control over every engine switch ([`FleetOptions`]):
    /// input pinning and closure edges (mirror
    /// `general_partition_with_options`), the fleet-level block reduction,
    /// and the incremental flow-reusing re-solves. With both fast paths
    /// **off** ([`FleetOptions::bit_identical`]) the engine solves the
    /// full DAG from a cold refresh every time and decisions are
    /// bit-identical to the cold general engine (the
    /// [`super::PartitionPlanner`] contract); with either **on**,
    /// decisions are *cost-equivalent* — equal T(cut), possibly a
    /// different co-optimal cut (see the module docs).
    pub fn with_options(spec: FleetSpec, options: FleetOptions) -> FleetPlanner {
        let template = &spec.tiers[0].1;
        for (name, costs) in &spec.tiers[1..] {
            assert_shared_shape(template, costs, name);
        }

        // One Theorem 2 pass for the whole fleet: detection + intra-block
        // min-cuts read only the DAG and activation bytes, which
        // `assert_shared_shape` just proved identical across tiers. The
        // full reduction (mapping + shared arrays) is applied once, to the
        // template; every other tier differs only in ξ_D, which is
        // re-derived through the shared mapping.
        let (reduction, blocks_detected, blocks_abstracted) = if options.block_reduction {
            let plan = Reduction::detect(template);
            let (detected, abstracted) = (plan.blocks_detected(), plan.blocks_abstracted());
            let reduction = if plan.reduces() {
                let (first, to_reduced) = plan.apply(template);
                let mut reduced = Vec::with_capacity(spec.tiers.len());
                reduced.push(first);
                for (_, costs) in &spec.tiers[1..] {
                    let r = retarget_xi_d(&reduced[0], &to_reduced, costs);
                    reduced.push(r);
                }
                Some(FleetReduction { to_reduced, reduced })
            } else {
                None
            };
            (reduction, detected, abstracted)
        } else {
            (None, 0, 0)
        };

        let full_dag = (template.len(), template.dag.num_edges());
        let solve_template = reduction.as_ref().map_or(template, |r| &r.reduced[0]);
        let solve_dag = (solve_template.len(), solve_template.dag.num_edges());
        let n = solve_template.len();
        let linear = !(0..n).any(|v| solve_template.dag.out_degree(v) > 1);
        let (shape, proto) = if linear {
            (None, None)
        } else {
            let (shape, proto) =
                NetShape::build(solve_template, options.pin_inputs, options.closure_edges);
            (Some(shape), Some(proto))
        };
        let tiers = (0..spec.tiers.len())
            .map(|t| {
                let solve_costs = reduction
                    .as_ref()
                    .map_or(&spec.tiers[t].1, |r| &r.reduced[t]);
                TierEntry::Active(TierState {
                    net: proto.clone(),
                    exec_base: NetShape::exec_base(solve_costs),
                    scratch: DinicScratch::default(),
                    inc: IncrementalScratch::default(),
                    has_flow: false,
                    solved: None,
                    refreshes: 0,
                    flow_solves: 0,
                    linear_scans: 0,
                    incremental_solves: 0,
                    repair_pushes: 0,
                    augment_rounds: 0,
                    fallback_cold_solves: 0,
                })
            })
            .collect();
        FleetPlanner {
            spec,
            options,
            reduction,
            shape,
            proto,
            tiers,
            full_dag,
            solve_dag,
            blocks_detected,
            blocks_abstracted,
            plans: 0,
            requests: 0,
            spec_deltas: 0,
            retired_decisions: 0,
            degraded_decisions: 0,
            quantized_requests: 0,
        }
    }

    /// Serve one epoch: one decision per request, in request order. Dirty
    /// (tier, link) pairs are refreshed + solved exactly once; everything
    /// else is served from the per-tier cache (bit-exact, the solve being
    /// deterministic). An empty batch is a no-op epoch.
    ///
    /// Panics on a malformed request (the historical contract); callers
    /// that cannot afford a crashed epoch loop use [`FleetPlanner::try_plan`].
    pub fn plan(&mut self, requests: &[PlanRequest]) -> Vec<PlanDecision> {
        match self.try_plan(requests) {
            Ok(decisions) => decisions,
            Err(e) => panic!("{e}"),
        }
    }

    /// [`FleetPlanner::plan`] with malformed requests refused instead of
    /// panicked. Validation runs before any planner state moves: a
    /// rejected batch leaves every counter, TTL and cache untouched, so a
    /// producer that bypassed the daemon's ingest checks cannot skew an
    /// epoch it never got.
    pub fn try_plan(&mut self, requests: &[PlanRequest]) -> Result<Vec<PlanDecision>, RequestError> {
        for r in requests {
            if r.tier >= self.spec.num_tiers() {
                return Err(RequestError::UnknownTier { tier: r.tier });
            }
            if !r.link.is_valid() {
                return Err(RequestError::InvalidLink {
                    device: r.device,
                    up_bps: r.link.up_bps,
                    down_bps: r.link.down_bps,
                });
            }
        }
        self.plans += 1;
        self.requests += requests.len() as u64;
        self.tick_retired_ttls();
        Ok(match self.quantize_requests(requests) {
            Some(snapped) => self.plan_inner(&snapped),
            None => self.plan_inner(requests),
        })
    }

    /// Snap a validated batch's links to their σ-bucket canonical
    /// representatives ([`SigmaQuantizer`] docs), `None` when quantization
    /// is off or nothing collapsed (the caller then plans the original
    /// batch — preserving bit-identity, and letting stacked planners
    /// re-quantize without double-counting). Bumps `quantized_requests`
    /// once per rewritten request.
    pub(crate) fn quantize_requests(
        &mut self,
        requests: &[PlanRequest],
    ) -> Option<Vec<PlanRequest>> {
        let q = SigmaQuantizer::new(self.options.sigma_buckets_per_decade)?;
        // Pass 1: per (tier, bucket), the canonical member — minimum
        // (up, down) bit pattern among the batch's members. Positive
        // finite f64 bits order numerically, so this is the slowest
        // member and is independent of request order.
        let mut canonical: std::collections::HashMap<(usize, i64, i64), Link> =
            std::collections::HashMap::new();
        for r in requests {
            let (i, j) = q.bucket_key(r.link);
            canonical
                .entry((r.tier, i, j))
                .and_modify(|best| {
                    let a = (r.link.up_bps.to_bits(), r.link.down_bps.to_bits());
                    let b = (best.up_bps.to_bits(), best.down_bps.to_bits());
                    if a < b {
                        *best = r.link;
                    }
                })
                .or_insert(r.link);
        }
        // Pass 2: rewrite non-canonical members. A batch where every link
        // is already its bucket's canonical member (sub-resolution fleet,
        // or an already-snapped batch) rewrites nothing and returns None.
        let mut rewrites = 0u64;
        let mut snapped = requests.to_vec();
        for r in &mut snapped {
            let (i, j) = q.bucket_key(r.link);
            let rep = canonical[&(r.tier, i, j)];
            if rep != r.link {
                r.link = rep;
                rewrites += 1;
            }
        }
        if rewrites == 0 {
            return None;
        }
        self.quantized_requests += rewrites;
        Some(snapped)
    }

    fn plan_inner(&mut self, requests: &[PlanRequest]) -> Vec<PlanDecision> {
        // Single-request fast path: the per-epoch hot path of the one-tier
        // PartitionPlanner wrapper (and the coordinator's one-device
        // epochs). Skips the batch grouping structures so the warm decision
        // stays allocation-free apart from the returned decision itself —
        // the PR-1 contract.
        if let [r] = requests {
            if self.tiers[r.tier].is_retired() {
                self.retired_decisions += 1;
                let partition = self.retired_partition(r.tier, r.link);
                return vec![PlanDecision {
                    device: r.device,
                    tier: r.tier,
                    cut_layer: partition.cut_layer(),
                    partition,
                    stats: DecisionStats { refreshed: false },
                    provenance: DecisionProvenance::Retired,
                }];
            }
            let (solve_costs, expand) = tier_inputs(&self.reduction, &self.spec, r.tier);
            let tier = self.tiers[r.tier]
                .active_mut()
                .expect("retired handled above");
            let clean = matches!(&tier.solved, Some((l, _)) if *l == r.link);
            if !clean {
                let partition = solve_tier(
                    self.shape.as_ref(),
                    solve_costs,
                    expand,
                    self.options,
                    tier,
                    r.link,
                    1.0,
                );
                tier.solved = Some((r.link, partition));
            }
            let partition = tier.solved.as_ref().expect("tier just solved").1.clone();
            return vec![PlanDecision {
                device: r.device,
                tier: r.tier,
                cut_layer: partition.cut_layer(),
                partition,
                stats: DecisionStats { refreshed: !clean },
                provenance: if clean {
                    DecisionProvenance::Cached
                } else {
                    DecisionProvenance::Fresh
                },
            }];
        }

        // Group request indices per tier AND per distinct link (first-seen
        // order), so a (tier, link) pair solves at most once per epoch even
        // when different links of the same tier interleave in the batch.
        let mut by_tier: Vec<Vec<(Link, Vec<usize>)>> = vec![Vec::new(); self.spec.num_tiers()];
        let mut group_of: std::collections::HashMap<(usize, u64, u64), usize> =
            std::collections::HashMap::new();
        for (i, r) in requests.iter().enumerate() {
            let key = (r.tier, r.link.up_bps.to_bits(), r.link.down_bps.to_bits());
            let g = *group_of.entry(key).or_insert_with(|| {
                by_tier[r.tier].push((r.link, Vec::new()));
                by_tier[r.tier].len() - 1
            });
            by_tier[r.tier][g].1.push(i);
        }

        // Answer retired tiers' groups up front (sequentially — a retired
        // answer is a cache read + one Eq. (7) evaluation, no solver), so
        // the job sweep below only ever sees live tiers.
        let mut results: Vec<Option<(Partition, bool, DecisionProvenance)>> =
            vec![None; requests.len()];
        for (t, groups) in by_tier.iter().enumerate() {
            if groups.is_empty() || !self.tiers[t].is_retired() {
                continue;
            }
            for (link, idxs) in groups {
                let partition = self.retired_partition(t, *link);
                self.retired_decisions += idxs.len() as u64;
                for &i in idxs {
                    results[i] =
                        Some((partition.clone(), false, DecisionProvenance::Retired));
                }
            }
        }

        // Per-tier solve sweep over explicit jobs. Tiers are independent
        // (each TierState owns its network + scratch and reads only the
        // shared shape/spec), so the jobs run serially or — behind the
        // `parallel` cargo feature — through rayon's par_iter_mut; each
        // job's groups are served in a deterministic order either way, so
        // decisions and stats are bit-identical across the two modes.
        let shape = self.shape.as_ref();
        let reduction = &self.reduction;
        let spec = &self.spec;
        let options = self.options;
        let mut jobs: Vec<TierJob> = self
            .tiers
            .iter_mut()
            .zip(by_tier.iter())
            .enumerate()
            .filter_map(|(t, (entry, groups))| {
                entry.active_mut().map(|tier| TierJob {
                    t,
                    tier,
                    groups,
                    out: vec![None; groups.len()],
                })
            })
            .collect();
        let run = |job: &mut TierJob| {
            let (solve_costs, expand) = tier_inputs(reduction, spec, job.t);
            run_tier_job(shape, solve_costs, expand, options, job);
        };
        #[cfg(not(feature = "parallel"))]
        jobs.iter_mut().for_each(run);
        #[cfg(feature = "parallel")]
        {
            use rayon::prelude::*;
            jobs.par_iter_mut().for_each(run);
        }

        // Serial fan-out of the per-group decisions, in request order.
        for job in &jobs {
            for (g, (_, idxs)) in job.groups.iter().enumerate() {
                let (partition, fresh) = job.out[g].as_ref().expect("every group is solved");
                for (j, &i) in idxs.iter().enumerate() {
                    // Only the group's first request carries refreshed=true.
                    let refreshed = *fresh && j == 0;
                    let provenance = if refreshed {
                        DecisionProvenance::Fresh
                    } else {
                        DecisionProvenance::Cached
                    };
                    results[i] = Some((partition.clone(), refreshed, provenance));
                }
            }
        }

        requests
            .iter()
            .zip(results)
            .map(|(r, res)| {
                let (partition, refreshed, provenance) =
                    res.expect("every request is solved above");
                PlanDecision {
                    device: r.device,
                    tier: r.tier,
                    cut_layer: partition.cut_layer(),
                    partition,
                    stats: DecisionStats { refreshed },
                    provenance,
                }
            })
            .collect()
    }

    /// Advance every retired tier's TTL by one epoch, dropping archives
    /// that expired. Called once per [`FleetPlanner::plan`]; an archive
    /// retired with `retire_ttl = n` stays servable for exactly the next
    /// `n` plan epochs (the drop happens on epoch `n + 1`'s entry).
    fn tick_retired_ttls(&mut self) {
        for entry in &mut self.tiers {
            if let TierEntry::Retired(r) = entry {
                if r.ttl == 0 {
                    r.last = None;
                } else {
                    r.ttl -= 1;
                }
            }
        }
    }

    /// The deterministic answer for a late request against a retired tier:
    /// the archived last-good cut re-evaluated at the request's link while
    /// the TTL holds, the device-only fallback after (or if the tier never
    /// solved). Both are feasible — the device-only set trivially, the
    /// archived cut because cut feasibility is link-independent.
    fn retired_partition(&mut self, tier: usize, link: Link) -> Partition {
        let problem = Problem::with_pin(&self.spec.tiers[tier].1, link, self.options.pin_inputs);
        let archived = match &self.tiers[tier] {
            TierEntry::Retired(r) => r.last.as_ref().map(|(_, p)| p.device_set.clone()),
            TierEntry::Active(_) => unreachable!("retired_partition on a live tier"),
        };
        match archived {
            Some(device_set) => problem.partition(device_set),
            None => problem.device_only(),
        }
    }

    /// Apply one churn event to the live planner: patch the spec and the
    /// per-tier SoA state in place. Untouched tiers keep their warm flows
    /// and cached decisions (pinned by [`FleetStats`] counters in the
    /// churn suite); device-level deltas touch no solver state at all
    /// (the tier map is request routing, not solver input). A malformed
    /// delta is rejected with a typed [`SpecError`] *before* anything
    /// moves — spec, tier states and the `spec_deltas` counter are all
    /// untouched by a rejected event.
    pub fn try_apply(&mut self, delta: &SpecDelta) -> Result<(), SpecError> {
        self.spec.validate(delta)?;
        self.spec_deltas += 1;
        match delta {
            SpecDelta::AddTier { name, costs } => {
                assert_shared_shape(&self.spec.tiers[0].1, costs, name);
                // Extend the fleet-wide reduction with the tier's reduced
                // graph (ξ_D re-derived through the shared mapping, same
                // as construction), then clone a zero-capacity network
                // off the stored prototype.
                if let Some(r) = &mut self.reduction {
                    let reduced = retarget_xi_d(&r.reduced[0], &r.to_reduced, costs);
                    r.reduced.push(reduced);
                }
                let exec_base = match &self.reduction {
                    Some(r) => NetShape::exec_base(r.reduced.last().expect("just pushed")),
                    None => NetShape::exec_base(costs),
                };
                self.tiers.push(TierEntry::Active(TierState {
                    net: self.proto.clone(),
                    exec_base,
                    scratch: DinicScratch::default(),
                    inc: IncrementalScratch::default(),
                    has_flow: false,
                    solved: None,
                    refreshes: 0,
                    flow_solves: 0,
                    linear_scans: 0,
                    incremental_solves: 0,
                    repair_pushes: 0,
                    augment_rounds: 0,
                    fallback_cold_solves: 0,
                }));
                self.spec.apply(delta);
            }
            SpecDelta::RetireTier { tier } => {
                let old = std::mem::replace(
                    &mut self.tiers[*tier],
                    TierEntry::Retired(RetiredTier::default()),
                );
                let state = match old {
                    TierEntry::Active(s) => s,
                    TierEntry::Retired(_) => unreachable!("double retire rejected by validate"),
                };
                // Archive the cached decision and the lifetime counters
                // (stats stay monotone); free the network and scratch.
                self.tiers[*tier] = TierEntry::Retired(RetiredTier {
                    last: state.solved,
                    ttl: self.options.retire_ttl,
                    refreshes: state.refreshes,
                    flow_solves: state.flow_solves,
                    linear_scans: state.linear_scans,
                    incremental_solves: state.incremental_solves,
                    repair_pushes: state.repair_pushes,
                    augment_rounds: state.augment_rounds,
                    fallback_cold_solves: state.fallback_cold_solves,
                });
                self.spec.apply(delta);
            }
            // Device membership is pure request routing: no per-tier
            // solver state to touch.
            SpecDelta::AddDevice { .. }
            | SpecDelta::RemoveDevice { .. }
            | SpecDelta::MigrateDevice { .. } => self.spec.apply(delta),
        }
        Ok(())
    }

    /// [`FleetPlanner::try_apply`] for callers that treat churn as a
    /// stream of facts (a contradictory fact is a caller bug): panics
    /// where `try_apply` returns the typed error.
    pub fn apply(&mut self, delta: &SpecDelta) {
        if let Err(e) = self.try_apply(delta) {
            panic!("malformed churn event: {e}");
        }
    }

    /// Immediately expire a retired tier's archived last-good decision:
    /// the daemon's retire-TTL hook (`daemon::timeq` fires it at
    /// `retirement + retire_ttl` wall ticks instead of counting `plan`
    /// epochs). Late requests for the tier fall through to the
    /// deterministic device-only answer from the next plan on. A no-op on
    /// live or out-of-range tiers.
    pub fn expire_retired(&mut self, tier: usize) {
        if let Some(TierEntry::Retired(r)) = self.tiers.get_mut(tier) {
            r.ttl = 0;
            r.last = None;
        }
    }

    /// The link of a tier's warm cached decision (`None` for retired or
    /// never-solved tiers) — the service layer's solve-budget estimator.
    pub(crate) fn cached_link(&self, tier: usize) -> Option<Link> {
        match &self.tiers[tier] {
            TierEntry::Active(t) => t.solved.as_ref().map(|(l, _)| *l),
            TierEntry::Retired(_) => None,
        }
    }

    /// Record `n` degraded decisions served by the service layer on this
    /// planner's behalf (so [`FleetStats`] carries the full provenance
    /// accounting in one place).
    pub(crate) fn note_degraded(&mut self, n: u64) {
        self.degraded_decisions += n;
    }

    /// Drop every tier's cached decision, forcing the next request per tier
    /// to refresh + re-solve even under an identical link — the honest way
    /// to benchmark the warm solve path rather than the cache lookup.
    pub fn invalidate(&mut self) {
        for t in &mut self.tiers {
            if let TierEntry::Active(t) = t {
                t.solved = None;
            }
        }
    }

    /// Unconditional refresh + solve of one tier, returning the decision
    /// without touching the tier cache: the [`super::PartitionPlanner`]
    /// per-call hot path, which re-solves every call anyway (so a cached
    /// copy would be discarded unused) and whose PR-1 contract is one
    /// O(E) refresh + one Dinic run + only the returned device-set
    /// allocation. With [`FleetOptions::incremental`] on, the solve still
    /// reuses the previous call's flow (the skipped cache holds decisions,
    /// not flow), which is what `benches/replan.rs` times as the
    /// incremental per-epoch path.
    pub fn take_solve(&mut self, tier: usize, link: Link) -> Partition {
        assert!(tier < self.spec.num_tiers(), "unknown tier {tier}");
        assert!(link.is_valid(), "rates must be positive and finite");
        self.plans += 1;
        self.requests += 1;
        let (solve_costs, expand) = tier_inputs(&self.reduction, &self.spec, tier);
        let t = self.tiers[tier]
            .active_mut()
            .unwrap_or_else(|| panic!("take_solve on retired tier {tier}"));
        solve_tier(
            self.shape.as_ref(),
            solve_costs,
            expand,
            self.options,
            t,
            link,
            1.0,
        )
    }

    /// Unconditional refresh + solve of one tier at server congestion
    /// price `lambda` — the joint planner's probe entry point. The priced
    /// solve minimizes `A(cut) + λ·W(cut)` (Eq. (7) with the server FLOPs
    /// term scaled by λ); the returned [`Partition`]'s delay is the
    /// *unpriced* Eq. (7) value for that cut. Rides the same incremental
    /// flow-reuse path as σ refreshes (consecutive probes differ only in
    /// capacities), so a Dinkelbach/bisection price loop pays a warm
    /// refresh per probe, not a cold Dinic run. Never touches the tier's
    /// λ=1 decision cache (a previously planned decision stays valid and
    /// servable — the probe only advances the flow state) and does not
    /// count as a served plan (`refreshes`/`flow_solves`/
    /// `incremental_solves` still move — the joint stats surface them).
    ///
    /// λ ≠ 1 is rejected on a reduced engine: Theorem 2's abstraction
    /// argument assumes the server is never slower than the device per
    /// layer, which a congestion price can invert — a λ-optimal cut may
    /// then split a block the reduced DAG cannot split. Priced callers
    /// hold an unreduced engine for probing (see `partition::joint`).
    pub(crate) fn priced_solve(&mut self, tier: usize, link: Link, lambda: f64) -> Partition {
        assert!(tier < self.spec.num_tiers(), "unknown tier {tier}");
        assert!(link.is_valid(), "rates must be positive and finite");
        assert!(
            lambda.is_finite() && lambda > 0.0,
            "congestion price must be positive and finite"
        );
        assert!(
            lambda == 1.0 || !self.is_reduced(),
            "priced solves (λ ≠ 1) require an unreduced engine \
             (the Theorem 2 reduction is only valid at the dedicated price)"
        );
        let (solve_costs, expand) = tier_inputs(&self.reduction, &self.spec, tier);
        let t = self.tiers[tier]
            .active_mut()
            .unwrap_or_else(|| panic!("priced_solve on retired tier {tier}"));
        solve_tier(
            self.shape.as_ref(),
            solve_costs,
            expand,
            self.options,
            t,
            link,
            lambda,
        )
    }

    /// Aggregate solver counters across all tiers.
    pub fn stats(&self) -> FleetStats {
        let mut s = FleetStats {
            plans: self.plans,
            requests: self.requests,
            full_vertices: self.full_dag.0,
            full_edges: self.full_dag.1,
            reduced_vertices: self.solve_dag.0,
            reduced_edges: self.solve_dag.1,
            blocks_detected: self.blocks_detected,
            blocks_abstracted: self.blocks_abstracted,
            spec_deltas: self.spec_deltas,
            retired_decisions: self.retired_decisions,
            degraded_decisions: self.degraded_decisions,
            quantized_requests: self.quantized_requests,
            ..FleetStats::default()
        };
        for entry in &self.tiers {
            // Retired tiers keep their lifetime counters (archived at
            // retirement), so the aggregate stays monotone across churn.
            let (r, f, l, i, p, a, fb) = match entry {
                TierEntry::Active(t) => (
                    t.refreshes,
                    t.flow_solves,
                    t.linear_scans,
                    t.incremental_solves,
                    t.repair_pushes,
                    t.augment_rounds,
                    t.fallback_cold_solves,
                ),
                TierEntry::Retired(t) => (
                    t.refreshes,
                    t.flow_solves,
                    t.linear_scans,
                    t.incremental_solves,
                    t.repair_pushes,
                    t.augment_rounds,
                    t.fallback_cold_solves,
                ),
            };
            s.refreshes += r;
            s.flow_solves += f;
            s.linear_scans += l;
            s.incremental_solves += i;
            s.repair_pushes += p;
            s.augment_rounds += a;
            s.fallback_cold_solves += fb;
        }
        s
    }

    /// The switches this planner was built with.
    pub fn options(&self) -> FleetOptions {
        self.options
    }

    /// The fleet this planner serves.
    pub fn spec(&self) -> &FleetSpec {
        &self.spec
    }

    /// (vertices, edges) of the shared flow-network shape — built on the
    /// *reduced* DAG when the fleet-level block reduction is active;
    /// `None` on the linear fast path (chain solve DAGs never build one).
    pub fn flow_size(&self) -> Option<(usize, usize)> {
        self.shape.as_ref().map(|s| (s.vertices, s.edges))
    }

    /// True iff this engine solves on a Theorem 2 *reduced* DAG. The
    /// reduction's validity argument assumes the dedicated λ = 1 cost
    /// model (a block member is never cheaper on the device), so a priced
    /// caller (`partition::joint`) must route its λ ≠ 1 probes through an
    /// unreduced engine whenever this is true.
    pub(crate) fn is_reduced(&self) -> bool {
        self.reduction.is_some()
    }

    /// Export the crash-surviving state of this planner (see
    /// [`FleetImage`]); the byte codec lives in `daemon::snapshot`.
    pub(crate) fn export_image(&self) -> FleetImage {
        let tiers = self
            .tiers
            .iter()
            .map(|entry| match entry {
                TierEntry::Active(t) => TierImage::Active {
                    solved: t.solved.clone(),
                    counters: [
                        t.refreshes,
                        t.flow_solves,
                        t.linear_scans,
                        t.incremental_solves,
                        t.repair_pushes,
                        t.augment_rounds,
                        t.fallback_cold_solves,
                    ],
                },
                TierEntry::Retired(t) => TierImage::Retired {
                    last: t.last.clone(),
                    ttl: t.ttl,
                    counters: [
                        t.refreshes,
                        t.flow_solves,
                        t.linear_scans,
                        t.incremental_solves,
                        t.repair_pushes,
                        t.augment_rounds,
                        t.fallback_cold_solves,
                    ],
                },
            })
            .collect();
        FleetImage {
            tier_names: self.spec.tiers.iter().map(|(n, _)| n.to_string()).collect(),
            tier_costs: self.spec.tiers.iter().map(|(_, c)| c.clone()).collect(),
            retired: self.spec.retired.clone(),
            tier_of_device: self.spec.tier_of_device.clone(),
            tiers,
            plans: self.plans,
            requests: self.requests,
            spec_deltas: self.spec_deltas,
            retired_decisions: self.retired_decisions,
            degraded_decisions: self.degraded_decisions,
            quantized_requests: self.quantized_requests,
        }
    }

    /// Rebuild a planner from a recovered image: reconstruct the spec
    /// (tier names live for the process lifetime — one bounded
    /// `Box::leak` per recovery, mirroring the `&'static str` tier-name
    /// contract), run the normal construction — reduction, shapes and
    /// prototype networks are deterministic functions of spec + options —
    /// then patch in the archived decisions, retirements and counters.
    /// Flow state restarts cold (`has_flow` false): under the engine
    /// configuration the recovery contract pins
    /// ([`FleetOptions::bit_identical`], incremental reuse off) that is
    /// not observable in any decision or counter.
    pub(crate) fn from_image(img: FleetImage, options: FleetOptions) -> FleetPlanner {
        let FleetImage {
            tier_names,
            tier_costs,
            retired,
            tier_of_device,
            tiers: tier_images,
            plans,
            requests,
            spec_deltas,
            retired_decisions,
            degraded_decisions,
            quantized_requests,
        } = img;
        let tiers: Vec<(&'static str, CostGraph)> = tier_names
            .into_iter()
            .zip(tier_costs)
            .map(|(name, costs)| {
                let name: &'static str = Box::leak(name.into_boxed_str());
                (name, costs)
            })
            .collect();
        let spec = FleetSpec::from_parts(tiers, retired, tier_of_device);
        let mut planner = FleetPlanner::with_options(spec, options);
        assert_eq!(
            planner.tiers.len(),
            tier_images.len(),
            "image tier count matches its own spec"
        );
        for (entry, image) in planner.tiers.iter_mut().zip(tier_images) {
            match image {
                TierImage::Active { solved, counters } => {
                    let t = entry
                        .active_mut()
                        .expect("spec marked this tier live, so construction built it Active");
                    t.solved = solved;
                    t.refreshes = counters[0];
                    t.flow_solves = counters[1];
                    t.linear_scans = counters[2];
                    t.incremental_solves = counters[3];
                    t.repair_pushes = counters[4];
                    t.augment_rounds = counters[5];
                    t.fallback_cold_solves = counters[6];
                }
                TierImage::Retired { last, ttl, counters } => {
                    *entry = TierEntry::Retired(RetiredTier {
                        last,
                        ttl,
                        refreshes: counters[0],
                        flow_solves: counters[1],
                        linear_scans: counters[2],
                        incremental_solves: counters[3],
                        repair_pushes: counters[4],
                        augment_rounds: counters[5],
                        fallback_cold_solves: counters[6],
                    });
                }
            }
        }
        planner.plans = plans;
        planner.requests = requests;
        planner.spec_deltas = spec_deltas;
        planner.retired_decisions = retired_decisions;
        planner.degraded_decisions = degraded_decisions;
        planner.quantized_requests = quantized_requests;
        planner
    }
}

/// Plain-data image of one tier slot of a [`FleetPlanner`]: the part of a
/// tier that must survive a crash — the cached λ=1 decision (or the
/// retired archive and its TTL) plus the tier's lifetime counters, in
/// [`FleetStats`] field order (refreshes, flow_solves, linear_scans,
/// incremental_solves, repair_pushes, augment_rounds,
/// fallback_cold_solves). Flow networks, scratch buffers and SoA vectors
/// are deliberately absent: they are deterministic functions of the spec
/// and options and are rebuilt cold by [`FleetPlanner::from_image`].
pub(crate) enum TierImage {
    Active {
        solved: Option<(Link, Partition)>,
        counters: [u64; 7],
    },
    Retired {
        last: Option<(Link, Partition)>,
        ttl: u64,
        counters: [u64; 7],
    },
}

/// Plain-data image of a whole [`FleetPlanner`] for the daemon's crash
/// snapshots: the spec's parts, every tier's [`TierImage`], and the
/// engine-global counters — everything [`FleetPlanner::from_image`] needs
/// to rebuild a planner whose observable behavior (decisions,
/// [`FleetStats`], metrics) continues bit-identically. The byte codec
/// lives in `daemon::snapshot`.
pub(crate) struct FleetImage {
    pub(crate) tier_names: Vec<String>,
    pub(crate) tier_costs: Vec<CostGraph>,
    pub(crate) retired: Vec<bool>,
    pub(crate) tier_of_device: Vec<Option<usize>>,
    pub(crate) tiers: Vec<TierImage>,
    pub(crate) plans: u64,
    pub(crate) requests: u64,
    pub(crate) spec_deltas: u64,
    pub(crate) retired_decisions: u64,
    pub(crate) degraded_decisions: u64,
    pub(crate) quantized_requests: u64,
}

/// The SoA layout shares `base[]`/`bw_scale[]` across tiers, which is only
/// sound when everything but ξ_D is identical: same DAG, same activation
/// and parameter bytes, same server costs, same N_loc.
fn assert_shared_shape(a: &CostGraph, b: &CostGraph, tier: &str) {
    assert_eq!(a.len(), b.len(), "tier '{tier}': layer count differs");
    assert_eq!(
        a.dag.num_edges(),
        b.dag.num_edges(),
        "tier '{tier}': DAG edge count differs"
    );
    assert!(
        a.dag
            .edges()
            .iter()
            .zip(b.dag.edges())
            .all(|(x, y)| x.from == y.from && x.to == y.to),
        "tier '{tier}': DAG topology differs"
    );
    assert!(
        a.act_bytes == b.act_bytes && a.param_bytes == b.param_bytes,
        "tier '{tier}': activation/parameter bytes differ (different model?)"
    );
    assert!(
        a.xi_s == b.xi_s && a.n_loc == b.n_loc,
        "tier '{tier}': server costs / N_loc differ (different server or config?)"
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::models::REDUCING_MODELS;
    use crate::partition::general::general_partition;
    use crate::partition::PartitionPlanner;
    use crate::profiles::TrainCfg;
    use crate::util::prop::{
        assert_cut_cost_equal, assert_cut_cost_within, fading_walk, random_link, zoo_matrix,
    };
    use crate::util::rng::Rng;

    fn tier_profiles() -> [DeviceProfile; 4] {
        [
            DeviceProfile::jetson_tx1(),
            DeviceProfile::jetson_tx2(),
            DeviceProfile::jetson_orin_nano(),
            DeviceProfile::jetson_agx_orin(),
        ]
    }

    fn spec_for(model: &str, devices: usize) -> FleetSpec {
        let m = models::by_name(model).unwrap();
        FleetSpec::from_fleet(&DeviceProfile::fleet_of(devices), |d| {
            CostGraph::build(&m, d, &DeviceProfile::rtx_a6000(), &TrainCfg::default())
        })
    }

    #[test]
    fn spec_deduplicates_tiers_by_name() {
        let spec = spec_for("block-residual", 10);
        assert_eq!(spec.num_tiers(), 4);
        assert_eq!(spec.num_devices(), 10);
        let profiles = tier_profiles();
        for d in 0..10 {
            assert_eq!(spec.tier_name(spec.tier_of(d)), profiles[d % 4].name);
        }
    }

    /// The fleet-vs-independent equivalence suite: a batched `plan` is
    /// **cost-equivalent** to N independent `PartitionPlanner::partition`
    /// calls (the unreduced general engine), across the whole model zoo and
    /// random tier/link batches (duplicates included), over several epochs.
    /// Reduced-DAG solves may pick different co-optimal cuts, so the pinned
    /// property is equal T(cut) — while duplicates of one (tier, link)
    /// within the fleet remain bit-exact cache copies of each other.
    #[test]
    fn plan_cost_equivalent_to_independent_partition_planners_across_zoo() {
        let base = crate::util::rng::test_seed();
        for model in models::MODEL_NAMES {
            let spec = spec_for(model, 6);
            let mut reference: Vec<PartitionPlanner> = (0..spec.num_tiers())
                .map(|t| PartitionPlanner::new(spec.tier_costs(t)))
                .collect();
            let mut fleet = FleetPlanner::new(spec);
            let mut rng = Rng::new(base ^ model.len() as u64);
            for epoch in 0..6 {
                let batch_size = rng.index(7); // includes the empty batch
                let mut requests = Vec::with_capacity(batch_size);
                for _ in 0..batch_size {
                    let device = rng.index(fleet.spec().num_devices());
                    let link = if rng.chance(0.3) && !requests.is_empty() {
                        // Duplicate an earlier link: exercises the cache.
                        let prev: &PlanRequest = &requests[rng.index(requests.len())];
                        prev.link
                    } else {
                        random_link(&mut rng)
                    };
                    let tier = fleet.spec().tier_of(device);
                    requests.push(PlanRequest { device, tier, link });
                }
                let decisions = fleet.plan(&requests);
                assert_eq!(decisions.len(), requests.len());
                for (i, (r, d)) in requests.iter().zip(&decisions).enumerate() {
                    assert_eq!(d.device, r.device);
                    assert_eq!(d.tier, r.tier);
                    let reference = reference[r.tier].partition(r.link);
                    let problem = Problem::new(fleet.spec().tier_costs(r.tier), r.link);
                    assert_cut_cost_equal(&problem, &d.partition, &reference);
                    assert_eq!(d.cut_layer, d.partition.cut_layer());
                    // Duplicate (tier, link) pairs in the batch are served
                    // from the tier cache, bit-exactly.
                    for (r2, d2) in requests.iter().zip(&decisions).take(i) {
                        if r2.tier == r.tier && r2.link == r.link {
                            assert_eq!(
                                d.partition.delay.to_bits(),
                                d2.partition.delay.to_bits(),
                                "{model} epoch {epoch}: cache copy diverged"
                            );
                            assert_eq!(d.partition.device_set, d2.partition.device_set);
                        }
                    }
                }
            }
        }
    }

    /// With both fast paths disabled (`FleetOptions::bit_identical`: no
    /// block reduction, no incremental re-solves) the facade stays
    /// bit-identical to independent `PartitionPlanner`s — the PR-2 pinned
    /// property, now the explicit contract of that configuration.
    #[test]
    fn unreduced_plan_is_bit_identical_to_partition_planners() {
        let mut rng = Rng::new(crate::util::rng::test_seed() ^ 0xB17);
        for model in ["googlenet", "resnet18", "gpt2"] {
            let spec = spec_for(model, 6);
            let mut reference: Vec<PartitionPlanner> = (0..spec.num_tiers())
                .map(|t| PartitionPlanner::new(spec.tier_costs(t)))
                .collect();
            let mut fleet = FleetPlanner::with_options(spec, FleetOptions::bit_identical());
            let s = fleet.stats();
            assert_eq!(s.reduced_vertices, s.full_vertices, "{model}");
            assert_eq!(s.blocks_detected, 0, "{model}: detection must be skipped");
            for _ in 0..8 {
                let link = random_link(&mut rng);
                let device = rng.index(fleet.spec().num_devices());
                let tier = fleet.spec().tier_of(device);
                let d = fleet
                    .plan(&[PlanRequest { device, tier, link }])
                    .pop()
                    .unwrap();
                let want = reference[tier].partition(link);
                assert_eq!(d.partition.device_set, want.device_set, "{model}");
                assert_eq!(d.partition.delay.to_bits(), want.delay.to_bits(), "{model}");
            }
        }
    }

    #[test]
    fn empty_batch_is_a_noop_epoch() {
        let mut fleet = FleetPlanner::new(spec_for("block-residual", 4));
        let decisions = fleet.plan(&[]);
        assert!(decisions.is_empty());
        let s = fleet.stats();
        assert_eq!(s.plans, 1);
        assert_eq!(s.requests, 0);
        assert_eq!(s.solves(), 0);
        assert_eq!(s.refreshes, 0);
    }

    #[test]
    fn single_device_fleet_cost_matches_partition_planner() {
        let m = models::by_name("googlenet").unwrap();
        let costs = CostGraph::build(
            &m,
            &DeviceProfile::jetson_tx2(),
            &DeviceProfile::rtx_a6000(),
            &TrainCfg::default(),
        );
        let mut fleet = FleetPlanner::new(FleetSpec::single(costs.clone()));
        let mut reference = PartitionPlanner::new(&costs);
        let mut rng = Rng::new(crate::util::rng::test_seed());
        for _ in 0..10 {
            let link = random_link(&mut rng);
            let d = fleet
                .plan(&[PlanRequest {
                    device: 0,
                    tier: 0,
                    link,
                }])
                .pop()
                .unwrap();
            let r = reference.partition(link);
            assert_cut_cost_equal(&Problem::new(&costs, link), &d.partition, &r);
        }
        // GoogLeNet reduces only partially (several mid-network inception
        // blocks fail the Theorem 2 test), so the reduced DAG still has
        // branches and every solve runs the flow network — on a strictly
        // smaller graph.
        let s = fleet.stats();
        assert_eq!(s.flow_solves, 10);
        assert!(s.blocks_abstracted > 0);
        assert!(s.reduced_vertices < s.full_vertices);
    }

    /// The PR-2 acceptance criterion, kept under the reduction: a
    /// 1000-device epoch performs exactly one capacity-refresh pass per
    /// dirty tier, asserted via solver stats, while clean tiers (unchanged
    /// link) are served from cache. GoogLeNet keeps the flow path after
    /// reduction (partial abstraction), so refresh accounting is exercised
    /// on the reduced network; decisions are cost-checked against the
    /// unreduced reference.
    #[test]
    fn thousand_device_epoch_refreshes_once_per_dirty_tier() {
        let spec = spec_for("googlenet", 1000);
        let num_tiers = spec.num_tiers();
        assert_eq!(num_tiers, 4);
        let mut reference: Vec<PartitionPlanner> = (0..num_tiers)
            .map(|t| PartitionPlanner::new(spec.tier_costs(t)))
            .collect();
        let mut fleet = FleetPlanner::new(spec);
        assert!(fleet.flow_size().is_some(), "googlenet must stay on flow");

        // Per-tier epoch links (the broadcast channel state of each tier).
        let epoch_link = |tier: usize, epoch: usize| Link {
            up_bps: 1e5 * (1.0 + tier as f64) * (1.0 + epoch as f64),
            down_bps: 4e5 * (1.0 + tier as f64) * (1.0 + epoch as f64),
        };
        let requests_for = |fleet: &FleetPlanner, epoch: usize| -> Vec<PlanRequest> {
            fleet.spec().requests(|tier| epoch_link(tier, epoch))
        };
        let check = |fleet: &FleetPlanner,
                     refs: &[Partition],
                     reqs: &[PlanRequest],
                     decisions: &[PlanDecision]| {
            for (r, d) in reqs.iter().zip(decisions) {
                let problem = Problem::new(fleet.spec().tier_costs(r.tier), r.link);
                assert_cut_cost_equal(&problem, &d.partition, &refs[r.tier]);
            }
        };

        // Epoch 0: all four tiers dirty -> exactly 4 refreshes, 1000 answers.
        // (Reference solves once per tier — all of a tier's devices share
        // the epoch link, and fleet decisions for duplicates are bit-exact
        // cache copies, so per-request reference solves would add nothing.)
        let reqs = requests_for(&fleet, 0);
        let decisions = fleet.plan(&reqs);
        assert_eq!(decisions.len(), 1000);
        assert_eq!(fleet.stats().refreshes, 4);
        assert_eq!(fleet.stats().flow_solves, 4);
        assert_eq!(decisions.iter().filter(|d| d.stats.refreshed).count(), 4);
        let refs: Vec<Partition> = (0..num_tiers)
            .map(|t| reference[t].partition(epoch_link(t, 0)))
            .collect();
        check(&fleet, &refs, &reqs, &decisions);

        // Epoch 1: same links -> every tier clean, no new refreshes.
        let reqs = requests_for(&fleet, 0);
        let decisions = fleet.plan(&reqs);
        assert_eq!(decisions.len(), 1000);
        assert_eq!(fleet.stats().refreshes, 4);
        assert!(decisions.iter().all(|d| !d.stats.refreshed));

        // Epoch 2: fresh links -> all four tiers dirty again.
        let reqs = requests_for(&fleet, 2);
        let decisions = fleet.plan(&reqs);
        assert_eq!(fleet.stats().refreshes, 8);
        let refs: Vec<Partition> = (0..num_tiers)
            .map(|t| reference[t].partition(epoch_link(t, 2)))
            .collect();
        check(&fleet, &refs, &reqs, &decisions);
        assert_eq!(fleet.stats().plans, 3);
        assert_eq!(fleet.stats().requests, 3000);
    }

    #[test]
    fn linear_models_take_the_scan_fast_path() {
        let mut fleet = FleetPlanner::new(spec_for("lenet5", 8));
        assert!(fleet.flow_size().is_none());
        let link = Link::symmetric(1e6);
        let reqs = fleet.spec().requests(|_| link);
        let decisions = fleet.plan(&reqs);
        assert_eq!(decisions.len(), 8);
        let s = fleet.stats();
        assert_eq!(s.refreshes, 0);
        // One scan per tier (all devices of a tier share the link).
        assert_eq!(s.linear_scans, fleet.spec().num_tiers() as u64);
        for d in &decisions {
            assert!(d.cut_layer.is_some(), "chain partitions are prefixes");
        }
    }

    /// Different links of one tier interleaved in a batch must not thrash
    /// the tier cache: each distinct (tier, link) solves at most once per
    /// epoch, with duplicates served bit-exactly. (block-residual's reduced
    /// DAG is a chain, so the solves here are linear scans — the cache
    /// grouping is engine-agnostic.)
    #[test]
    fn interleaved_links_solve_once_per_distinct_pair() {
        let mut fleet = FleetPlanner::new(spec_for("block-residual", 1));
        let a = Link::symmetric(1e5);
        let b = Link::symmetric(7e6);
        let req = |link| PlanRequest {
            device: 0,
            tier: 0,
            link,
        };
        let decisions = fleet.plan(&[req(a), req(b), req(a)]);
        assert_eq!(fleet.stats().solves(), 2, "a and b each solve once");
        assert_eq!(
            decisions[0].partition.delay.to_bits(),
            decisions[2].partition.delay.to_bits()
        );
        assert_eq!(
            decisions[0].partition.device_set,
            decisions[2].partition.device_set
        );
        assert!(decisions[0].stats.refreshed);
        assert!(decisions[1].stats.refreshed);
        assert!(!decisions[2].stats.refreshed, "duplicate served from group");
    }

    #[test]
    fn invalidate_forces_resolve_under_identical_link() {
        let mut fleet = FleetPlanner::new(spec_for("block-residual", 1));
        let link = Link::symmetric(2e6);
        let req = PlanRequest {
            device: 0,
            tier: 0,
            link,
        };
        let a = fleet.plan(&[req]).pop().unwrap();
        assert!(a.stats.refreshed);
        let b = fleet.plan(&[req]).pop().unwrap();
        assert!(!b.stats.refreshed);
        fleet.invalidate();
        let c = fleet.plan(&[req]).pop().unwrap();
        assert!(c.stats.refreshed);
        assert_eq!(a.partition.device_set, c.partition.device_set);
        assert_eq!(a.partition.delay.to_bits(), c.partition.delay.to_bits());
    }

    #[test]
    #[should_panic(expected = "rates must be positive")]
    fn rejects_dead_links() {
        let mut fleet = FleetPlanner::new(spec_for("block-residual", 1));
        let _ = fleet.plan(&[PlanRequest {
            device: 0,
            tier: 0,
            link: Link::symmetric(0.0),
        }]);
    }

    /// The tentpole acceptance hook: `FleetStats` proves block-structured
    /// models actually solve on strictly smaller DAGs, fleet-wide, while
    /// every decision stays cost-equivalent to the unreduced engine.
    #[test]
    fn reduction_solves_on_strictly_smaller_dags_for_block_models() {
        for model in REDUCING_MODELS {
            let spec = spec_for(model, 8);
            let mut reference: Vec<PartitionPlanner> = (0..spec.num_tiers())
                .map(|t| PartitionPlanner::new(spec.tier_costs(t)))
                .collect();
            let mut fleet = FleetPlanner::new(spec);
            let s = fleet.stats();
            assert!(s.blocks_abstracted > 0, "{model}: nothing abstracted");
            assert!(
                s.reduced_vertices < s.full_vertices && s.reduced_edges < s.full_edges,
                "{model}: solve DAG {}v/{}e is not smaller than full {}v/{}e",
                s.reduced_vertices,
                s.reduced_edges,
                s.full_vertices,
                s.full_edges
            );
            let link = Link::symmetric(2e6);
            let reqs = fleet.spec().requests(|_| link);
            let decisions = fleet.plan(&reqs);
            for (r, d) in reqs.iter().zip(&decisions) {
                let problem = Problem::new(fleet.spec().tier_costs(r.tier), link);
                assert_cut_cost_equal(&problem, &d.partition, &reference[r.tier].partition(link));
            }
        }
    }

    /// ResNet-style models whose blocks all abstract reduce to a pure
    /// chain: the engine then runs the O(L) linear scan on the reduced DAG
    /// — no flow network at all — and still matches the unreduced engine's
    /// cut cost on the full DAG.
    #[test]
    fn chain_reduced_models_take_the_linear_path() {
        let spec = spec_for("block-residual", 4);
        let mut reference: Vec<PartitionPlanner> = (0..spec.num_tiers())
            .map(|t| PartitionPlanner::new(spec.tier_costs(t)))
            .collect();
        let mut fleet = FleetPlanner::new(spec);
        assert!(
            fleet.flow_size().is_none(),
            "reduced block-residual must be a chain"
        );
        let mut rng = Rng::new(crate::util::rng::test_seed() ^ 0xC4A1);
        for _ in 0..6 {
            let link = random_link(&mut rng);
            let reqs = fleet.spec().requests(|_| link);
            let decisions = fleet.plan(&reqs);
            for (r, d) in reqs.iter().zip(&decisions) {
                let problem = Problem::new(fleet.spec().tier_costs(r.tier), link);
                assert_cut_cost_equal(&problem, &d.partition, &reference[r.tier].partition(link));
                // The decision is over the FULL layer set, not the reduced.
                assert_eq!(
                    d.partition.device_set.len(),
                    fleet.spec().tier_costs(r.tier).len()
                );
            }
        }
        let s = fleet.stats();
        assert_eq!(s.refreshes, 0, "linear path never refreshes capacities");
        assert!(s.linear_scans > 0 && s.flow_solves == 0);
        assert!(s.reduced_vertices < s.full_vertices);
    }

    /// The σ-drift regression (ISSUE 4 satellite): a fading walk — many
    /// consecutive small σ steps on one tier — must take the incremental
    /// path on every step after the first, and every step's cost must
    /// match a per-step cold general solve. Two walks cover both
    /// directions: rates fading (σ grows → capacities grow → pure
    /// augmentation) and recovering (σ shrinks → capacities shrink →
    /// repair passes run).
    #[test]
    fn fading_walk_resolves_incrementally_with_cold_costs() {
        let m = models::by_name("googlenet").unwrap();
        let costs = CostGraph::build(
            &m,
            &DeviceProfile::jetson_tx2(),
            &DeviceProfile::rtx_a6000(),
            &TrainCfg::default(),
        );
        let mut fleet = FleetPlanner::new(FleetSpec::single(costs.clone()));
        assert!(
            fleet.flow_size().is_some(),
            "googlenet must stay on the flow path"
        );
        let mut rng = Rng::new(crate::util::rng::test_seed() ^ 0xFAD1);
        let mut steps = 0u64;
        for start_rate in [4e6, 2e5] {
            // Phase A: rates fade (σ grows); phase B: rates recover
            // (σ shrinks). Factor ranges exclude 1.0, so consecutive
            // links always differ and every plan call really solves.
            for (lo, hi) in [(0.85, 0.99), (1.02, 1.25)] {
                let start = Link {
                    up_bps: start_rate,
                    down_bps: 3.0 * start_rate,
                };
                for link in fading_walk(&mut rng, start, 12, lo, hi) {
                    let d = fleet
                        .plan(&[PlanRequest {
                            device: 0,
                            tier: 0,
                            link,
                        }])
                        .pop()
                        .unwrap();
                    let p = Problem::new(&costs, link);
                    let cold = general_partition(&p);
                    assert_cut_cost_equal(&p, &d.partition, &cold);
                    steps += 1;
                }
            }
        }
        let s = fleet.stats();
        assert_eq!(s.flow_solves, steps);
        assert_eq!(
            s.incremental_solves,
            steps - 1,
            "every step after the first must reuse the previous flow"
        );
        assert!(
            s.repair_pushes > 0,
            "σ-shrinking steps must exercise the repair pass"
        );
        // The PR-4 dead-end fallback is now counted, not silent: on this
        // walk every repair succeeds, and the warm/fallback split must
        // account for every post-first solve exactly.
        assert_eq!(s.fallback_cold_solves, 0, "no repair may dead-end here");
        assert_eq!(
            s.incremental_solves + s.fallback_cold_solves,
            steps - 1,
            "every warm solve either repaired or fell back — nothing silent"
        );
    }

    /// The parallel-feature determinism pin: the batched sweep (rayon
    /// `par_iter_mut` under `--features parallel`, serial otherwise) must
    /// produce decisions bit-identical to a fresh planner answering the
    /// same epochs one request at a time through the always-serial
    /// single-request fast path — same per-tier link and flow history,
    /// same tie-breaks. Since this holds under any job schedule,
    /// feature-on ≡ feature-off (CI runs both).
    #[test]
    fn batched_plan_is_bit_identical_to_sequential_plans() {
        for model in ["googlenet", "block-residual"] {
            let mut batched = FleetPlanner::new(spec_for(model, 12));
            let mut serial = FleetPlanner::new(spec_for(model, 12));
            for epoch in 0..5u64 {
                let reqs = batched.spec().requests(|t| Link {
                    up_bps: 1e5 * (1.0 + t as f64) * (1.0 + 0.37 * epoch as f64),
                    down_bps: 5e5 * (1.0 + t as f64) * (1.0 + 0.29 * epoch as f64),
                });
                let decisions = batched.plan(&reqs);
                for (r, d) in reqs.iter().zip(&decisions) {
                    let want = serial.plan(&[*r]).pop().unwrap();
                    assert_eq!(d.partition.device_set, want.partition.device_set, "{model}");
                    assert_eq!(
                        d.partition.delay.to_bits(),
                        want.partition.delay.to_bits(),
                        "{model}"
                    );
                    assert_eq!(d.cut_layer, want.cut_layer, "{model}");
                    assert_eq!(d.stats.refreshed, want.stats.refreshed, "{model}");
                }
            }
            let (b, s) = (batched.stats(), serial.stats());
            assert_eq!(b.refreshes, s.refreshes, "{model}");
            assert_eq!(b.flow_solves, s.flow_solves, "{model}");
            assert_eq!(b.incremental_solves, s.incremental_solves, "{model}");
            assert_eq!(b.repair_pushes, s.repair_pushes, "{model}");
            assert_eq!(b.augment_rounds, s.augment_rounds, "{model}");
        }
    }

    /// Dirty multi-tier epochs route every flow tier through the
    /// incremental path from its second solve on.
    #[test]
    fn dirty_epochs_reuse_flow_across_all_tiers() {
        let spec = spec_for("googlenet", 8);
        let num_tiers = spec.num_tiers() as u64;
        let mut fleet = FleetPlanner::new(spec);
        for epoch in 0..4u64 {
            let reqs = fleet.spec().requests(|t| Link {
                up_bps: 2e5 * (1.0 + t as f64) * (1.0 + epoch as f64),
                down_bps: 8e5 * (1.0 + t as f64) * (1.0 + epoch as f64),
            });
            let _ = fleet.plan(&reqs);
        }
        let s = fleet.stats();
        assert_eq!(s.flow_solves, 4 * num_tiers);
        assert_eq!(
            s.incremental_solves,
            3 * num_tiers,
            "only each tier's first solve may run cold"
        );
    }

    /// `FleetOptions::incremental` off = the PR-1 engine: every solve is
    /// a cold refresh + Dinic run, and no incremental counter ever moves.
    #[test]
    fn incremental_off_never_reuses_flow() {
        let mut fleet = FleetPlanner::with_options(
            spec_for("googlenet", 4),
            FleetOptions {
                incremental: false,
                ..FleetOptions::default()
            },
        );
        for epoch in 0..3u64 {
            let reqs = fleet.spec().requests(|t| Link {
                up_bps: 3e5 * (1.0 + t as f64) * (1.0 + epoch as f64),
                down_bps: 9e5 * (1.0 + t as f64) * (1.0 + epoch as f64),
            });
            let _ = fleet.plan(&reqs);
        }
        let s = fleet.stats();
        assert!(s.flow_solves > 0);
        assert_eq!(s.incremental_solves, 0);
        assert_eq!(s.repair_pushes, 0);
        assert_eq!(s.augment_rounds, 0);
    }

    /// A joint price probe (λ ≠ 1) never touches the λ=1 decision cache:
    /// the probe's priced cut is returned by value only, and the cached
    /// dedicated decision stays servable bit-exactly afterwards — while
    /// the probe itself reuses the tier's flow (capacity-only refresh).
    /// Probes require an unreduced engine (Theorem 2 is a λ=1 argument —
    /// see `priced_solve`).
    #[test]
    fn priced_probes_do_not_pollute_the_plan_cache() {
        let mut fleet = FleetPlanner::with_options(
            spec_for("googlenet", 1),
            FleetOptions {
                block_reduction: false,
                ..FleetOptions::default()
            },
        );
        let link = Link::symmetric(8e5);
        let req = PlanRequest {
            device: 0,
            tier: 0,
            link,
        };
        let a = fleet.plan(&[req]).pop().unwrap();
        assert!(a.stats.refreshed);
        // A congested price moves layers device-ward, never server-ward
        // (λ scales the source-adjacent server-exec capacities, so the
        // minimal min cut's source side can only grow).
        let probed = fleet.priced_solve(0, link, 4.0);
        assert!(probed.device_layers() >= a.partition.device_layers());
        let b = fleet.plan(&[req]).pop().unwrap();
        assert!(
            !b.stats.refreshed,
            "the cached λ=1 decision must survive the probe untouched"
        );
        assert_eq!(b.partition.device_set, a.partition.device_set);
        assert_eq!(b.partition.delay.to_bits(), a.partition.delay.to_bits());
        let s = fleet.stats();
        assert_eq!(s.flow_solves, 2, "plan solve + probe solve only");
        assert_eq!(
            s.incremental_solves, 1,
            "the probe must reuse the plan solve's flow"
        );
    }

    /// The reduction guard: λ ≠ 1 probes on a reduced engine are a
    /// correctness hazard (a priced optimum may split an abstracted
    /// block), so the engine refuses them outright.
    #[test]
    #[should_panic(expected = "require an unreduced engine")]
    fn priced_probes_reject_reduced_engines() {
        let mut fleet = FleetPlanner::new(spec_for("googlenet", 1));
        assert!(fleet.is_reduced(), "googlenet must reduce for this test");
        let _ = fleet.priced_solve(0, Link::symmetric(8e5), 2.0);
    }

    #[test]
    #[should_panic(expected = "tier 'b'")]
    fn rejects_mixed_model_tiers() {
        let build = |model: &str| {
            CostGraph::build(
                &models::by_name(model).unwrap(),
                &DeviceProfile::jetson_tx1(),
                &DeviceProfile::rtx_a6000(),
                &TrainCfg::default(),
            )
        };
        let spec = FleetSpec::new(
            vec![("a", build("block-residual")), ("b", build("block-dense"))],
            vec![0, 1],
        );
        let _ = FleetPlanner::new(spec);
    }

    /// S3 + tentpole: two deltas in one tick that cancel out must be a
    /// no-op against the warm caches — identical spec, zero extra solves,
    /// every decision served bit-exact from the tier caches.
    #[test]
    fn churn_cancel_out_deltas_are_noops_against_warm_caches() {
        let mut fleet = FleetPlanner::new(spec_for("block-residual", 8));
        let reqs = fleet
            .spec()
            .requests(|t| Link::symmetric(2e5 * (1.0 + t as f64)));
        let before_decisions = fleet.plan(&reqs);
        let before = fleet.stats();
        let tier = fleet.spec().tier_of(3);
        fleet.apply(&SpecDelta::RemoveDevice { device: 3 });
        fleet.apply(&SpecDelta::AddDevice { device: 3, tier });
        assert_eq!(fleet.spec().tier_of(3), tier);
        assert_eq!(fleet.spec().active_devices(), 8);
        let after_decisions = fleet.plan(&reqs);
        let after = fleet.stats();
        assert_eq!(
            after.solves(),
            before.solves(),
            "cancel-out churn must not dirty any tier"
        );
        assert_eq!(after.refreshes, before.refreshes);
        assert_eq!(after.spec_deltas, 2);
        for (a, b) in before_decisions.iter().zip(&after_decisions) {
            assert_eq!(a.partition.device_set, b.partition.device_set);
            assert_eq!(a.partition.delay.to_bits(), b.partition.delay.to_bits());
            assert_eq!(b.provenance, DecisionProvenance::Cached);
        }
    }

    /// S3: a fleet whose every device left is a valid (if silent) fleet —
    /// stable slots, no requests, no-op epochs.
    #[test]
    fn churn_empty_fleet_after_all_devices_leave() {
        let mut fleet = FleetPlanner::new(spec_for("block-residual", 4));
        for d in 0..4 {
            fleet.apply(&SpecDelta::RemoveDevice { device: d });
        }
        assert_eq!(fleet.spec().active_devices(), 0);
        assert_eq!(fleet.spec().num_devices(), 4, "slots are stable ids");
        let reqs = fleet.spec().requests(|_| Link::symmetric(2e5));
        assert!(reqs.is_empty(), "departed devices issue no requests");
        assert!(fleet.plan(&reqs).is_empty());
    }

    /// S3: a device re-joining on a different tier routes to that tier's
    /// solver and gets an optimal decision for its new hardware.
    #[test]
    fn churn_device_rejoins_on_a_different_tier() {
        let mut fleet = FleetPlanner::new(spec_for("googlenet", 8));
        let old_tier = fleet.spec().tier_of(5);
        let new_tier = (old_tier + 1) % fleet.spec().num_tiers();
        fleet.apply(&SpecDelta::RemoveDevice { device: 5 });
        assert_eq!(fleet.spec().tier_of_opt(5), None);
        fleet.apply(&SpecDelta::AddDevice {
            device: 5,
            tier: new_tier,
        });
        assert_eq!(fleet.spec().tier_of(5), new_tier);
        let link = Link::symmetric(6e5);
        let d = fleet
            .plan(&[PlanRequest {
                device: 5,
                tier: new_tier,
                link,
            }])
            .pop()
            .unwrap();
        let p = Problem::new(fleet.spec().tier_costs(new_tier), link);
        let cold = general_partition(&p);
        assert_cut_cost_equal(&p, &d.partition, &cold);
    }

    /// Tentpole: a retired tier answers late requests deterministically —
    /// the archived last-good cut re-costed at the request's link while
    /// the TTL holds, the device-only fallback after. Never a panic,
    /// never an infeasible set, never a solver run.
    #[test]
    fn churn_retired_tier_serves_archived_cut_then_device_only() {
        let mut fleet = FleetPlanner::with_options(
            spec_for("googlenet", 8),
            FleetOptions {
                retire_ttl: 1,
                ..FleetOptions::default()
            },
        );
        let link = Link::symmetric(4e5);
        let d0 = fleet
            .plan(&[PlanRequest {
                device: 1,
                tier: 1,
                link,
            }])
            .pop()
            .unwrap();
        let solves_before = fleet.stats().solves();
        fleet.apply(&SpecDelta::RetireTier { tier: 1 });
        assert!(fleet.spec().tier_retired(1));
        assert_eq!(
            fleet.spec().tier_of_opt(1),
            None,
            "tier-1 devices depart with their tier"
        );
        // Within the TTL: the archived cut, re-evaluated at the late
        // request's (different) link.
        let late = Link::symmetric(9e5);
        let d1 = fleet
            .plan(&[PlanRequest {
                device: 1,
                tier: 1,
                link: late,
            }])
            .pop()
            .unwrap();
        assert_eq!(d1.provenance, DecisionProvenance::Retired);
        assert!(!d1.stats.refreshed);
        assert_eq!(d1.partition.device_set, d0.partition.device_set);
        let problem = Problem::new(fleet.spec().tier_costs(1), late);
        assert!(problem.is_feasible(&d1.partition.device_set));
        assert_eq!(
            d1.partition.delay.to_bits(),
            problem
                .partition(d0.partition.device_set.clone())
                .delay
                .to_bits(),
            "archived cut must be re-costed at the request's link"
        );
        // Past the TTL: the deterministic device-only fallback.
        let d2 = fleet
            .plan(&[PlanRequest {
                device: 1,
                tier: 1,
                link: late,
            }])
            .pop()
            .unwrap();
        assert_eq!(d2.provenance, DecisionProvenance::Retired);
        assert!(
            d2.partition.device_set.iter().all(|&on| on),
            "expired archive falls back to device-only"
        );
        let s = fleet.stats();
        assert_eq!(s.retired_decisions, 2);
        assert_eq!(s.solves(), solves_before, "retired answers never solve");
    }

    /// Tentpole: a tier joining mid-run solves exactly like a tier built
    /// at construction — same reduction retargeting, same prototype
    /// network — and leaves the existing tiers' warm state untouched.
    #[test]
    fn churn_added_tier_matches_a_fresh_planner() {
        let m = models::by_name("googlenet").unwrap();
        let build = |d: &DeviceProfile| {
            CostGraph::build(&m, d, &DeviceProfile::rtx_a6000(), &TrainCfg::default())
        };
        let spec = FleetSpec::new(
            vec![
                ("jetson-tx1", build(&DeviceProfile::jetson_tx1())),
                ("jetson-tx2", build(&DeviceProfile::jetson_tx2())),
            ],
            vec![0, 1],
        );
        let mut fleet = FleetPlanner::new(spec);
        let link0 = Link::symmetric(3e5);
        let _ = fleet.plan(&[PlanRequest {
            device: 0,
            tier: 0,
            link: link0,
        }]);
        let warm = fleet.stats();
        let new_costs = build(&DeviceProfile::jetson_agx_orin());
        fleet.apply(&SpecDelta::AddTier {
            name: "jetson-agx-orin",
            costs: new_costs.clone(),
        });
        fleet.apply(&SpecDelta::AddDevice { device: 2, tier: 2 });
        assert_eq!(fleet.spec().num_tiers(), 3);
        let link = Link::symmetric(7e5);
        let d = fleet
            .plan(&[PlanRequest {
                device: 2,
                tier: 2,
                link,
            }])
            .pop()
            .unwrap();
        let p = Problem::new(&new_costs, link);
        let cold = general_partition(&p);
        assert_cut_cost_equal(&p, &d.partition, &cold);
        let s = fleet.stats();
        assert_eq!(
            s.solves(),
            warm.solves() + 1,
            "the join must cost exactly the new tier's own solve"
        );
        assert_eq!(s.spec_deltas, 2);
    }

    #[test]
    #[should_panic(expected = "already retired")]
    fn churn_double_retire_panics() {
        let mut fleet = FleetPlanner::new(spec_for("block-residual", 4));
        fleet.apply(&SpecDelta::RetireTier { tier: 2 });
        fleet.apply(&SpecDelta::RetireTier { tier: 2 });
    }

    /// Malformed deltas come back as typed `SpecError`s from `try_apply`,
    /// and a rejected delta leaves the planner untouched — no half-patched
    /// spec, no phantom `spec_deltas` tick.
    #[test]
    fn churn_malformed_deltas_rejected_with_typed_errors() {
        let mut fleet = FleetPlanner::new(spec_for("block-residual", 4));
        let before: Vec<Option<usize>> = (0..fleet.spec().num_devices())
            .map(|d| fleet.spec().tier_of_opt(d))
            .collect();
        let deltas_before = fleet.stats().spec_deltas;

        // Migrating a device that was never in the fleet.
        assert_eq!(
            fleet.try_apply(&SpecDelta::MigrateDevice { device: 9, tier: 0 }),
            Err(SpecError::UnknownDevice { device: 9 })
        );
        // Migrating to a tier that does not exist.
        assert_eq!(
            fleet.try_apply(&SpecDelta::MigrateDevice { device: 1, tier: 7 }),
            Err(SpecError::UnknownTier { tier: 7 })
        );
        // Removing an absent device, and adding over a live slot.
        assert_eq!(
            fleet.try_apply(&SpecDelta::RemoveDevice { device: 42 }),
            Err(SpecError::UnknownDevice { device: 42 })
        );
        assert_eq!(
            fleet.try_apply(&SpecDelta::AddDevice { device: 1, tier: 0 }),
            Err(SpecError::DeviceAlreadyPresent { device: 1 })
        );
        // Adding a device on a tier that does not exist.
        assert_eq!(
            fleet.try_apply(&SpecDelta::AddDevice { device: 9, tier: 7 }),
            Err(SpecError::UnknownTier { tier: 7 })
        );

        let after: Vec<Option<usize>> = (0..fleet.spec().num_devices())
            .map(|d| fleet.spec().tier_of_opt(d))
            .collect();
        assert_eq!(after, before, "rejected deltas must not patch the spec");
        assert_eq!(fleet.spec().num_tiers(), 4);
        assert_eq!(fleet.stats().spec_deltas, deltas_before);

        // The same requests still plan identically after the rejections.
        let link = Link::symmetric(5e5);
        let d = fleet
            .plan(&[PlanRequest {
                device: 1,
                tier: fleet.spec().tier_of(1),
                link,
            }])
            .pop()
            .unwrap();
        assert!(d.partition.delay.is_finite());
    }

    /// Retired and departed slots are rejected as migration endpoints:
    /// a `MigrateDevice` naming a retired destination tier or a departed
    /// device is a typed error, not a silent patch.
    #[test]
    fn churn_migrate_rejects_retired_tier_and_departed_device() {
        let mut fleet = FleetPlanner::new(spec_for("block-residual", 4));
        fleet.apply(&SpecDelta::RetireTier { tier: 2 });
        assert_eq!(
            fleet.try_apply(&SpecDelta::MigrateDevice { device: 0, tier: 2 }),
            Err(SpecError::RetiredTier { tier: 2 })
        );
        assert_eq!(
            fleet.try_apply(&SpecDelta::AddDevice { device: 9, tier: 2 }),
            Err(SpecError::RetiredTier { tier: 2 })
        );
        assert_eq!(
            fleet.try_apply(&SpecDelta::RetireTier { tier: 2 }),
            Err(SpecError::AlreadyRetired { tier: 2 })
        );

        fleet.apply(&SpecDelta::RemoveDevice { device: 1 });
        assert_eq!(
            fleet.try_apply(&SpecDelta::MigrateDevice { device: 1, tier: 0 }),
            Err(SpecError::UnknownDevice { device: 1 }),
            "a departed device is not a migration source"
        );
    }

    /// `expire_retired` collapses a retired tier's TTL: the next request
    /// for that tier skips the archived cut and goes straight to the
    /// device-only fallback, exactly as if the TTL had run out naturally.
    #[test]
    fn churn_expire_retired_fast_forwards_the_ttl() {
        let opts = FleetOptions {
            retire_ttl: 8,
            ..FleetOptions::default()
        };
        let mut natural = FleetPlanner::with_options(spec_for("block-residual", 4), opts);
        let mut forced = FleetPlanner::with_options(spec_for("block-residual", 4), opts);
        let link = Link::symmetric(5e5);
        let req = [PlanRequest {
            device: 2,
            tier: 2,
            link,
        }];
        // Warm the archived cut, then retire on both planners.
        natural.plan(&req);
        forced.plan(&req);
        natural.apply(&SpecDelta::RetireTier { tier: 2 });
        forced.apply(&SpecDelta::RetireTier { tier: 2 });

        // Natural: burn the TTL down with archived serves. Forced: expire now.
        for _ in 0..8 {
            let d = natural.plan(&req).pop().unwrap();
            assert!(matches!(d.provenance, DecisionProvenance::Retired));
        }
        forced.expire_retired(2);

        let a = natural.plan(&req).pop().unwrap();
        let b = forced.plan(&req).pop().unwrap();
        assert_eq!(a.partition, b.partition, "post-TTL fallbacks must agree");
        assert_eq!(a.partition.delay.to_bits(), b.partition.delay.to_bits());

        // Expiring a live (or out-of-range) tier is a no-op.
        forced.expire_retired(0);
        forced.expire_retired(99);
        let d = forced
            .plan(&[PlanRequest {
                device: 0,
                tier: 0,
                link,
            }])
            .pop()
            .unwrap();
        assert!(matches!(d.provenance, DecisionProvenance::Fresh));
    }

    /// The cut's Eq. (7) bandwidth mass `B`: for a fixed device set,
    /// delay is affine in σ (`T(σ) = C + B·σ`), so two evaluations at
    /// distinct σ recover the slope exactly. The quantization error bound
    /// is `(B_served + B_opt)·Δσ` — see `SigmaQuantizer`.
    fn bw_mass(costs: &CostGraph, device_set: &[bool]) -> f64 {
        let (l1, l2) = (Link::symmetric(1e6), Link::symmetric(2e6));
        let t1 = Problem::new(costs, l1).delay(device_set);
        let t2 = Problem::new(costs, l2).delay(device_set);
        (t1 - t2) / (l1.sigma() - l2.sigma())
    }

    /// Quantizer edge cases: rates exactly on a bucket boundary bucket
    /// deterministically (whichever side float `log10` resolves to), the
    /// grid index is monotone in the rate, and any two links sharing a
    /// bucket pair differ in σ by at most the analytic width.
    #[test]
    fn quantizer_boundary_rates_bucket_deterministically() {
        for b in [1u32, 2, 4, 10] {
            let q = SigmaQuantizer::new(b).unwrap();
            assert_eq!(q.buckets_per_decade(), b);
            // Boundary and near-boundary rates: deterministic (equal on
            // re-evaluation) and monotone across the sorted list. 1e5 and
            // 1e6 sit exactly on decade grid lines for every b here.
            let rates = [1e4, 9.999e4, 1e5, 1.0001e5, 1e6, 5e6, 1e7];
            for w in rates.windows(2) {
                assert!(q.rate_bucket(w[0]) <= q.rate_bucket(w[1]), "b={b}: not monotone");
            }
            for r in rates {
                assert_eq!(q.rate_bucket(r), q.rate_bucket(r), "b={b}: not deterministic");
            }
        }
        // Same bucket pair ⇒ σ gap within the analytic width (the Δσ of
        // the per-bucket cost bound), across random link pairs.
        let q = SigmaQuantizer::new(3).unwrap();
        let mut rng = Rng::new(crate::util::rng::test_seed() ^ 0x51674);
        for _ in 0..200 {
            let (a, b) = (random_link(&mut rng), random_link(&mut rng));
            if q.bucket_key(a) == q.bucket_key(b) {
                let width = q.sigma_width(a);
                assert!(
                    (a.sigma() - b.sigma()).abs() <= width * (1.0 + 1e-12),
                    "bucket {:?}: |Δσ| {} exceeds width {width}",
                    q.bucket_key(a),
                    (a.sigma() - b.sigma()).abs()
                );
            }
        }
    }

    /// The counter-pinned sub-resolution contract: when no two links of a
    /// tier share a bucket (buckets ≥ distinct links), canonical-member
    /// quantization rewrites nothing, so quantization-on is bit-identical
    /// to quantization-off — full decisions AND full `FleetStats`, with
    /// `quantized_requests` pinned at 0.
    #[test]
    fn quantized_sub_resolution_fleet_is_bit_identical_to_unquantized() {
        let spec = spec_for("googlenet", 1);
        let mut quantized = FleetPlanner::with_options(
            spec.clone(),
            FleetOptions {
                sigma_buckets_per_decade: 1000,
                ..FleetOptions::default()
            },
        );
        let mut plain = FleetPlanner::new(spec);
        // Deterministic geometric ladder, ratio 1.1 per rung: far coarser
        // than the 10^(1/1000) bucket ratio, so every link is alone in
        // its bucket on any platform's log10.
        for epoch in 0..3 {
            let batch: Vec<PlanRequest> = (0..6)
                .map(|d| PlanRequest {
                    device: 0,
                    tier: 0,
                    link: Link {
                        up_bps: 2e5 * 1.1f64.powi(d) * (1.0 + epoch as f64),
                        down_bps: 8e5 * 1.1f64.powi(d) * (1.0 + epoch as f64),
                    },
                })
                .collect();
            let a = quantized.plan(&batch);
            let b = plain.plan(&batch);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.partition.device_set, y.partition.device_set);
                assert_eq!(x.partition.delay.to_bits(), y.partition.delay.to_bits());
                assert_eq!(x.stats.refreshed, y.stats.refreshed);
                assert_eq!(x.provenance, y.provenance);
            }
        }
        assert_eq!(quantized.stats(), plain.stats(), "full stats must agree");
        assert_eq!(quantized.stats().quantized_requests, 0);
    }

    /// `try_plan` refuses malformed requests with typed errors before any
    /// planner state moves — the direct-call escape hatch around the
    /// daemon's ingest validation is closed without crashing callers.
    #[test]
    fn try_plan_rejects_invalid_links_with_typed_errors() {
        let mut fleet = FleetPlanner::new(spec_for("block-residual", 4));
        let good = PlanRequest {
            device: 0,
            tier: 0,
            link: Link::symmetric(5e5),
        };
        let _ = fleet.plan(&[good]);
        let before = fleet.stats();

        let bad_link = |link| PlanRequest {
            device: 2,
            tier: 0,
            link,
        };
        for link in [
            Link::symmetric(f64::NAN),
            Link::symmetric(f64::INFINITY),
            Link {
                up_bps: 1e6,
                down_bps: -3.0,
            },
            Link::symmetric(0.0),
        ] {
            assert!(
                matches!(
                    fleet.try_plan(&[good, bad_link(link)]),
                    Err(RequestError::InvalidLink { device: 2, .. })
                ),
                "{link:?} must be refused"
            );
        }
        assert!(matches!(
            fleet.try_plan(&[PlanRequest { tier: 99, ..good }]),
            Err(RequestError::UnknownTier { tier: 99 })
        ));
        assert_eq!(
            fleet.stats(),
            before,
            "rejected batches must not move counters, TTLs or caches"
        );
        let d = fleet.try_plan(&[good]).unwrap().pop().unwrap();
        assert_eq!(d.provenance, DecisionProvenance::Cached);
    }

    #[test]
    #[should_panic(expected = "rates must be positive and finite")]
    fn plan_panics_on_nan_rates() {
        let mut fleet = FleetPlanner::new(spec_for("block-residual", 1));
        let _ = fleet.plan(&[PlanRequest {
            device: 0,
            tier: 0,
            link: Link {
                up_bps: f64::NAN,
                down_bps: 1e6,
            },
        }]);
    }

    /// The tentpole property: every quantized decision lands within the
    /// analytic per-bucket bound of the unquantized optimum. For a fixed
    /// cut, delay is affine in σ, so serving the bucket representative's
    /// cut at the true link costs at most `(B_served + B_opt)·Δσ` with Δσ
    /// bounded by the bucket's σ-width — checked via
    /// `assert_cut_cost_within` across the zoo matrix, on clusters built
    /// to collapse (5 links within one bucket ratio ⇒ ≤4 bucket pairs ⇒
    /// at least one rewrite per cluster, any seed).
    #[test]
    fn quantized_decisions_stay_within_the_analytic_bucket_bound_across_zoo() {
        zoo_matrix("quantized_bucket_bound", |case, rng| {
            let q = SigmaQuantizer::new(2).unwrap();
            let mut quantized = FleetPlanner::with_options(
                FleetSpec::single(case.costs.clone()),
                FleetOptions {
                    sigma_buckets_per_decade: q.buckets_per_decade(),
                    ..FleetOptions::default()
                },
            );
            let mut reference = FleetPlanner::new(FleetSpec::single(case.costs.clone()));
            for _ in 0..4 {
                let base = random_link(rng);
                let batch: Vec<PlanRequest> = (0..5)
                    .map(|d| {
                        let f = 1.0 - 0.02 * d as f64;
                        PlanRequest {
                            device: d,
                            tier: 0,
                            link: Link {
                                up_bps: base.up_bps * f,
                                down_bps: base.down_bps * f,
                            },
                        }
                    })
                    .collect();
                let served = quantized.plan(&batch);
                let want = reference.plan(&batch);
                for (r, (s, w)) in batch.iter().zip(served.iter().zip(&want)) {
                    let problem = Problem::new(&case.costs, r.link);
                    let eps = (bw_mass(&case.costs, &s.partition.device_set)
                        + bw_mass(&case.costs, &w.partition.device_set))
                        * q.sigma_width(r.link);
                    assert_cut_cost_within(&problem, &s.partition, &w.partition, eps);
                }
            }
            assert!(
                quantized.stats().quantized_requests > 0,
                "{}/{}: the collapse-guaranteed clusters never rewrote a link",
                case.model,
                case.tier
            );
        });
    }
}
