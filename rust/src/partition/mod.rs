//! The paper's core contribution: optimal model partitioning for split
//! learning as a minimum s-t cut.
//!
//! * [`types`] — the partitioning problem ([`Problem`]) and the training-
//!   delay objective Eq. (7) evaluated directly from model semantics.
//! * [`weights`] — Alg. 1: DAG construction with the three edge-weight
//!   classes (Eqs. 9-11).
//! * [`general`] — Alg. 2: auxiliary-vertex restructuring (Fig. 3) +
//!   max-flow min-cut (Theorem 1).
//! * [`fleet`] — the fleet-scale planning engine and facade: per-tier
//!   transformed networks over a shared struct-of-arrays capacity layout,
//!   batch-refreshed and solved per epoch through [`FleetPlanner::plan`],
//!   with the Theorem 2 block reduction computed once per fleet so
//!   block-structured models solve at blockwise scale, GGT-style
//!   incremental re-solves reusing the previous epoch's flow across σ
//!   refreshes ([`FleetOptions::incremental`]), and a dirty-tier sweep
//!   that parallelizes behind the `parallel` cargo feature (see PERF.md;
//!   the pinned equivalence property of both fast paths is cost equality
//!   of co-optimal cuts, `util::prop::assert_cut_cost_equal`).
//! * [`planner`] — amortized re-partitioning for a single (model,
//!   device-tier): [`PartitionPlanner`], a thin one-tier wrapper over the
//!   fleet engine with reduction off (bit-identical to the cold general
//!   engine), re-solved per epoch via an O(E) capacity refresh.
//! * [`blocks`] — Alg. 3: block detection via branch/reconvergence
//!   (immediate post-dominators).
//! * [`blockwise`] — Alg. 4: intra-block cut test (Theorem 2) + block-level
//!   abstraction (Eqs. 17-20), then Alg. 2 on the reduced DAG;
//!   `blockwise::Planner` is the one-tier wrapper over the fleet engine
//!   with reduction on.
//! * [`joint`] — joint fleet partitioning under **shared** server capacity:
//!   [`JointPlanner`] wraps the fleet engine, couples per-tier cuts through
//!   a congestion-priced server term (λ-scaled server FLOPs), and solves
//!   the fleet-makespan problem exactly via makespan bisection ×
//!   per-device Dinkelbach price probes — each probe a warm incremental
//!   re-solve. Pinned against a brute-force cut-combination oracle;
//!   infinite capacity degenerates bit-identically to [`FleetPlanner`].
//! * [`sharded`] — million-device scale (PR 8): [`ShardedFleetPlanner`]
//!   partitions the tiers across worker shards (each a complete fleet
//!   engine owning its SoA slices, warm flows and caches), sweeps one
//!   plan per shard — serial or rayon behind `parallel` — and mirrors
//!   [`JointPlanner`]'s makespan bisection for shared-capacity coupling.
//!   Pinned bit-identical to the flat engine (quantization off, full
//!   [`FleetStats`] equality) and cost-within-eps under σ-quantization
//!   ([`fleet::SigmaQuantizer`], `FleetOptions::sigma_buckets_per_decade`).
//! * [`service`] — the churn-tolerant planning service (PR 6):
//!   [`PlannerService`] wraps [`JointPlanner`] behind a link-report inbox
//!   and a simulated-clock epoch loop, patches the live fleet with
//!   [`SpecDelta`] churn events, and degrades to last-good decisions
//!   (marked via [`DecisionProvenance`]) on stale reports or solve-budget
//!   overruns — never emitting an infeasible decision (RESILIENCE.md).
//! * [`multihop`] — K-segment splitting over a relay path (PR 10):
//!   [`PathPlanner`] decomposes the multi-hop delay into K single-split
//!   stage problems (stage separability) solved by warm per-hop fleet
//!   engines, with an exact nested-lower-set DP when the lattice is
//!   enumerable and a link-pooling fallback otherwise; K = 1 degenerates
//!   bit-identically to [`PartitionPlanner`]. Pinned against a
//!   brute-force nested-tuple oracle ([`oracle_path_delay`]).
//! * [`assign`] — device→server assignment for multi-server fleets
//!   (PR 10): [`MultiServerPlanner`] searches assignments over a
//!   per-server capacity vector (exhaustive odometer or greedy + local
//!   search), scoring each with warm per-server [`JointPlanner`]s; one
//!   server degenerates bit-identically to [`JointPlanner`]. Pinned
//!   against [`oracle_multi_server_makespan`].
//! * [`baselines`] — brute force (lower-set enumeration), regression [21],
//!   OSS [17], device-only, central.

pub mod types;
pub mod weights;
pub mod general;
pub mod fleet;
pub mod joint;
pub mod planner;
pub mod service;
pub mod sharded;
pub mod blocks;
pub mod blockwise;
pub mod multihop;
pub mod assign;
pub mod baselines;

pub use blockwise::blockwise_partition;
pub use fleet::{
    DecisionProvenance, DecisionStats, DegradedReason, FleetOptions, FleetPlanner, FleetSpec,
    FleetStats, PlanDecision, PlanRequest, RequestError, SigmaQuantizer, SpecDelta, SpecError,
};
pub use service::{ClockError, PlannerService, ReportError, ServiceOptions};
pub use sharded::ShardedFleetPlanner;
pub use assign::{oracle_multi_server_makespan, MultiServerOptions, MultiServerPlanner};
pub use general::general_partition;
pub use joint::{fleet_makespan_for_cuts, oracle_fleet_makespan, JointOptions, JointPlanner};
pub use multihop::{oracle_path_delay, PathOptions, PathPlan, PathPlanner, PathSpec};
pub use planner::PartitionPlanner;
pub use types::{Link, Partition, Problem};

#[cfg(test)]
mod equivalence_tests;
