//! Theorem 1 / Theorem 2 property tests: the min-cut construction and the
//! block-wise reduction must both match brute-force enumeration of Eq. (7)
//! over all feasible cuts, on randomized DAGs and cost profiles satisfying
//! Assumption 1 — plus the fleet-level cost-equivalence suite: the fleet
//! engine's reduced-DAG decisions must yield the same training delay
//! T(cut) as the unreduced general engine, across the shared zoo generator
//! matrix and on random DAGs (`scripts/check.sh` re-runs this module under
//! two fixed `PALLAS_TEST_SEED`s).

use super::baselines::brute_force_partition;
use super::blockwise::blockwise_partition;
use super::fleet::{FleetOptions, FleetPlanner, FleetSpec, PlanRequest, TransformedNet};
use super::general::general_partition;
use super::types::{Link, Problem};
use crate::graph::Dag;
use crate::maxflow::DinicScratch;
use crate::profiles::CostGraph;
use crate::util::prop::{
    assert_cut_cost_equal, fading_walk, for_all, random_layer_dag, random_link as prop_random_link,
    zoo_matrix,
};
use crate::util::rng::Rng;

/// Random cost graph over a random layer DAG, honoring Assumption 1
/// (ξ_D >= ξ_S elementwise).
fn random_cost_graph(rng: &mut Rng, n: usize) -> CostGraph {
    let edges = random_layer_dag(rng, n, 0.25);
    let mut dag = Dag::new();
    for i in 0..n {
        dag.add_node(format!("v{i}"));
    }
    for (u, v) in edges {
        dag.add_edge(u, v, 0.0);
    }
    let xi_s: Vec<f64> = (0..n).map(|_| rng.range(1e-4, 5e-2)).collect();
    let xi_d: Vec<f64> = xi_s
        .iter()
        .map(|&s| s * rng.range(1.0, 20.0)) // device slower: Assumption 1
        .collect();
    let act_bytes: Vec<f64> = (0..n).map(|_| rng.range(1e3, 1e7)).collect();
    let param_bytes: Vec<f64> = (0..n)
        .map(|_| if rng.chance(0.5) { rng.range(0.0, 1e6) } else { 0.0 })
        .collect();
    CostGraph {
        dag,
        xi_d,
        xi_s,
        act_bytes,
        param_bytes,
        n_loc: rng.range(1.0, 20.0).round(),
    }
}

/// Narrower 1e4..1e8 B/s regime the brute-force suites were seeded on; the
/// shared [`prop_random_link`] spans 1e4..1e9 (zoo-matrix suites). Kept
/// distinct so this module's historical case streams replay unchanged.
fn random_link_mid(rng: &mut Rng) -> Link {
    Link {
        up_bps: rng.range(1e4, 1e8),
        down_bps: rng.range(1e4, 1e8),
    }
}

#[test]
fn theorem1_general_equals_brute_force() {
    for_all("theorem1", 120, |rng| {
        let n = 2 + rng.index(9); // brute force is 2^n
        let c = random_cost_graph(rng, n);
        assert!(c.satisfies_assumption1());
        let link = random_link_mid(rng);
        let p = Problem::new(&c, link);
        let bf = brute_force_partition(&p);
        let gen = general_partition(&p);
        assert!(p.is_feasible(&gen.device_set), "general infeasible");
        assert!(
            (gen.delay - bf.delay).abs() <= 1e-9 * (1.0 + bf.delay),
            "general {} != brute force {} on n={n}",
            gen.delay,
            bf.delay
        );
    });
}

#[test]
fn theorem2_blockwise_equals_brute_force() {
    for_all("theorem2", 120, |rng| {
        let n = 2 + rng.index(9);
        let c = random_cost_graph(rng, n);
        let link = random_link_mid(rng);
        let p = Problem::new(&c, link);
        let bf = brute_force_partition(&p);
        let bw = blockwise_partition(&p);
        assert!(p.is_feasible(&bw.device_set), "blockwise infeasible");
        assert!(
            (bw.delay - bf.delay).abs() <= 1e-9 * (1.0 + bf.delay),
            "blockwise {} != brute force {} on n={n}",
            bw.delay,
            bf.delay
        );
    });
}

#[test]
fn general_optimal_without_assumption1_thanks_to_closure_edges() {
    // The paper's Theorem 1 assumes ξ_D >= ξ_S. Our closure edges make the
    // construction exact even when the assumption is violated (a device
    // faster than the server for some layers), which matters for the
    // heterogeneous fleets of Sec. VII-B. Verify against brute force.
    for_all("no-assumption1", 80, |rng| {
        let n = 2 + rng.index(8);
        let mut c = random_cost_graph(rng, n);
        // Violate Assumption 1 on some layers.
        for v in 0..n {
            if rng.chance(0.4) {
                c.xi_d[v] = c.xi_s[v] * rng.range(0.05, 1.0);
            }
        }
        let p = Problem::new(&c, random_link_mid(rng));
        let bf = brute_force_partition(&p);
        let gen = general_partition(&p);
        assert!(
            (gen.delay - bf.delay).abs() <= 1e-9 * (1.0 + bf.delay),
            "general {} != brute force {}",
            gen.delay,
            bf.delay
        );
    });
}

/// The tentpole acceptance property: across every zoo model × ≥50 random
/// (tier, link) draws (the shared generator matrix gives 4 tiers × 13
/// links = 52 per model), the fleet engine's block-reduced decision and
/// the unreduced general engine's decision yield equal T(cut) under
/// Eq. (7) — co-optimal cuts may differ, costs may not — and `FleetStats`
/// proves the block-structured models solved on strictly smaller DAGs.
#[test]
fn fleet_reduction_cost_equivalence_across_zoo() {
    zoo_matrix("fleet-reduction-vs-general", |case, rng| {
        let mut fleet = FleetPlanner::new(FleetSpec::single(case.costs.clone()));
        for _ in 0..13 {
            let link = prop_random_link(rng);
            let p = Problem::new(&case.costs, link);
            let decision = fleet
                .plan(&[PlanRequest {
                    device: 0,
                    tier: 0,
                    link,
                }])
                .pop()
                .expect("one decision per request");
            let cold = general_partition(&p);
            assert_cut_cost_equal(&p, &decision.partition, &cold);
        }
        let s = fleet.stats();
        assert_eq!(s.full_vertices, case.costs.len());
        assert!(s.reduced_vertices <= s.full_vertices);
        if crate::models::REDUCING_MODELS.contains(&case.model) {
            assert!(s.blocks_abstracted > 0, "{}: nothing abstracted", case.model);
            assert!(
                s.reduced_vertices < s.full_vertices,
                "{}: not solved on a smaller DAG ({} vs {} vertices)",
                case.model,
                s.reduced_vertices,
                s.full_vertices
            );
        }
    });
}

/// The same cost-equivalence property on random layer DAGs: whatever
/// blocks detection finds (if any) on an arbitrary branched DAG, the
/// reduced solve's expanded cut must cost exactly what the full general
/// solve costs.
#[test]
fn fleet_reduction_cost_equivalence_on_random_dags() {
    for_all("fleet-reduction-random-dags", 60, |rng| {
        let n = 2 + rng.index(14);
        let c = random_cost_graph(rng, n);
        let mut fleet = FleetPlanner::new(FleetSpec::single(c.clone()));
        for _ in 0..4 {
            let link = random_link_mid(rng);
            let p = Problem::new(&c, link);
            let decision = fleet
                .plan(&[PlanRequest {
                    device: 0,
                    tier: 0,
                    link,
                }])
                .pop()
                .expect("one decision per request");
            let cold = general_partition(&p);
            assert_cut_cost_equal(&p, &decision.partition, &cold);
        }
    });
}

/// The PR-4 tentpole acceptance property: across every zoo model × ≥50
/// random (tier, link) draws, **incremental** flow-reusing re-solves
/// (block reduction off, to isolate the flow-reuse path against the cold
/// general engine on the same DAG) are cost-equivalent to cold solves.
/// The trajectory mixes hard random jumps with small-σ drift bursts in
/// both directions, so the repair pass (capacities shrinking) and the
/// pure-augmentation case (capacities growing) both run; `FleetStats`
/// then proves every solve after the first actually reused flow.
/// `scripts/check.sh` and CI replay this suite under fixed seeds 1 and
/// 0xC0FFEE.
#[test]
fn fleet_incremental_cost_equivalence_across_zoo() {
    zoo_matrix("fleet-incremental-vs-general", |case, rng| {
        let mut fleet = FleetPlanner::with_options(
            FleetSpec::single(case.costs.clone()),
            FleetOptions {
                block_reduction: false,
                ..FleetOptions::default()
            },
        );
        let mut link = prop_random_link(rng);
        for i in 0..13 {
            link = match i % 3 {
                0 => prop_random_link(rng),
                1 => fading_walk(rng, link, 1, 0.8, 0.99)[0],
                _ => fading_walk(rng, link, 1, 1.01, 1.3)[0],
            };
            let p = Problem::new(&case.costs, link);
            let d = fleet
                .plan(&[PlanRequest {
                    device: 0,
                    tier: 0,
                    link,
                }])
                .pop()
                .expect("one decision per request");
            let cold = general_partition(&p);
            assert_cut_cost_equal(&p, &d.partition, &cold);
        }
        let s = fleet.stats();
        if fleet.flow_size().is_some() {
            assert!(s.flow_solves >= 1);
            assert_eq!(
                s.incremental_solves,
                s.flow_solves - 1,
                "{}/{}: a non-first solve fell back to cold",
                case.model,
                case.tier
            );
        } else {
            // Chain models take the linear scan: no flow to reuse.
            assert_eq!(s.incremental_solves, 0);
        }
    });
}

/// Cross-solver parity on the *transformed* (Alg. 2) networks the fleet
/// path actually solves — push-relabel previously had oracle coverage
/// only on raw random networks. Max-flow values must agree and both
/// extracted cuts must be feasible with equal T(cut) under Eq. (7).
#[test]
fn push_relabel_matches_dinic_on_zoo_transformed_networks() {
    zoo_matrix("pr-vs-dinic-transformed", |case, rng| {
        let mut tnet = TransformedNet::build(&case.costs, true, true);
        let mut scratch = DinicScratch::default();
        for _ in 0..4 {
            let link = prop_random_link(rng);
            let p = Problem::new(&case.costs, link);
            tnet.refresh(link);
            let d = tnet.min_cut(&mut scratch);
            // Refresh again: the Dinic run left routed flow behind, and
            // push-relabel must start from clean capacities.
            tnet.refresh(link);
            let pr = tnet.min_cut_push_relabel();
            assert!(
                (d.value - pr.value).abs() <= 1e-9 * (1.0 + d.value.abs()),
                "{}/{}: dinic {} vs push-relabel {}",
                case.model,
                case.tier,
                d.value,
                pr.value
            );
            let pa = p.partition(tnet.device_set(&d.source_side));
            let pb = p.partition(tnet.device_set(&pr.source_side));
            assert_cut_cost_equal(&p, &pa, &pb);
        }
    });
}

/// The same parity on random layer DAGs and cost profiles.
#[test]
fn push_relabel_matches_dinic_on_random_transformed_dags() {
    for_all("pr-vs-dinic-random-transformed", 40, |rng| {
        let n = 2 + rng.index(14);
        let c = random_cost_graph(rng, n);
        let mut tnet = TransformedNet::build(&c, true, true);
        let mut scratch = DinicScratch::default();
        for _ in 0..3 {
            let link = random_link_mid(rng);
            let p = Problem::new(&c, link);
            tnet.refresh(link);
            let d = tnet.min_cut(&mut scratch);
            tnet.refresh(link);
            let pr = tnet.min_cut_push_relabel();
            assert!(
                (d.value - pr.value).abs() <= 1e-9 * (1.0 + d.value.abs()),
                "dinic {} vs push-relabel {}",
                d.value,
                pr.value
            );
            let pa = p.partition(tnet.device_set(&d.source_side));
            let pb = p.partition(tnet.device_set(&pr.source_side));
            assert_cut_cost_equal(&p, &pa, &pb);
        }
    });
}

#[test]
fn zoo_blocknets_all_methods_agree_with_brute_force() {
    use crate::models;
    use crate::profiles::{DeviceProfile, TrainCfg};
    // The exact Fig. 7(b) setting: proposed algorithms must hit the
    // brute-force optimum on all three single-block networks.
    for model in models::BLOCK_NETS {
        let m = models::by_name(model).unwrap();
        for (i, device) in [
            DeviceProfile::jetson_tx1(),
            DeviceProfile::jetson_agx_orin(),
        ]
        .iter()
        .enumerate()
        {
            let c = CostGraph::build(&m, device, &DeviceProfile::rtx_a6000(), &TrainCfg::default());
            for rate in [1e5, 1e6, 1e8] {
                let p = Problem::new(&c, Link::symmetric(rate));
                let bf = brute_force_partition(&p);
                let gen = general_partition(&p);
                let bw = blockwise_partition(&p);
                for (name, got) in [("general", &gen), ("blockwise", &bw)] {
                    assert!(
                        (got.delay - bf.delay).abs() <= 1e-9 * (1.0 + bf.delay),
                        "{model} dev{i} rate={rate}: {name} {} != bf {}",
                        got.delay,
                        bf.delay
                    );
                }
            }
        }
    }
}
