//! Problem statement and the Eq. (7) training-delay objective.

use crate::graph::Dag;
use crate::profiles::CostGraph;

/// Wireless link state between a device and the server.
///
/// `up_Bps` is the device→server rate `R_D`, `down_Bps` the server→device
/// rate `R_S`, both in **bytes per second** (the profiler reports sizes in
/// bytes; the net simulator converts from bits).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Link {
    pub up_bps: f64,
    pub down_bps: f64,
}

impl Link {
    pub fn symmetric(bytes_per_sec: f64) -> Link {
        Link {
            up_bps: bytes_per_sec,
            down_bps: bytes_per_sec,
        }
    }

    /// Round-trip cost `σ = 1/R_up + 1/R_down` in seconds per byte — one
    /// byte crossing the cut pays it once up (smashed data / parameters)
    /// and once down (gradients / parameters). Every capacity of the
    /// transformed flow network is affine in σ (see `partition::fleet` and
    /// PERF.md), which is what makes the warm O(E) refresh possible.
    pub fn sigma(&self) -> f64 {
        1.0 / self.up_bps + 1.0 / self.down_bps
    }

    /// Whether both rates are finite and strictly positive — the
    /// admission predicate every planning entry point (problem
    /// construction, `FleetPlanner` requests, service reports, daemon
    /// ingest) shares. `+∞` is rejected alongside NaN and non-positive
    /// rates: an infinite rate contributes a silent 0 to σ and would
    /// poison the SoA capacity refresh without ever tripping a
    /// `rate > 0` check.
    pub fn is_valid(&self) -> bool {
        self.up_bps.is_finite()
            && self.down_bps.is_finite()
            && self.up_bps > 0.0
            && self.down_bps > 0.0
    }

    /// Serial composition of two store-and-forward hops: a byte crossing
    /// both links pays both transit times, so the composite rate is the
    /// harmonic combination `1/R = 1/R_a + 1/R_b` per direction —
    /// equivalently `σ_serial = σ_a + σ_b`. This is how the multi-hop
    /// planner (`partition::multihop`) contracts a relay host out of a
    /// path: the two links around it become one pooled link, and every
    /// σ-affine capacity stays σ-affine. Composing two valid links always
    /// yields a valid link (finite, positive rates).
    pub fn serial(a: Link, b: Link) -> Link {
        Link {
            up_bps: 1.0 / (1.0 / a.up_bps + 1.0 / b.up_bps),
            down_bps: 1.0 / (1.0 / a.down_bps + 1.0 / b.down_bps),
        }
    }
}

/// A partitioning problem instance: cost graph + link state.
///
/// `pin_inputs` (default true) constrains every source layer (in-degree 0,
/// i.e. the raw data) to the device side — the defining constraint of split
/// learning: raw data never leaves the device, so sending it to the server
/// is charged as that layer's smashed-data transmission. The unpinned
/// variant exists for the privacy-violating `central` reference baseline
/// and for ablations.
#[derive(Clone, Debug)]
pub struct Problem<'a> {
    pub costs: &'a CostGraph,
    pub link: Link,
    pub pin_inputs: bool,
}

/// A model partition `c = {V_D, V_S}` with its evaluated training delay.
#[derive(Clone, Debug)]
pub struct Partition {
    /// `device_set[v]` is true iff layer v trains on the device.
    pub device_set: Vec<bool>,
    /// Eq. (7) training delay of this partition, in seconds.
    pub delay: f64,
}

impl<'a> Problem<'a> {
    pub fn new(costs: &'a CostGraph, link: Link) -> Problem<'a> {
        assert!(link.is_valid(), "rates must be positive and finite");
        Problem {
            costs,
            link,
            pin_inputs: true,
        }
    }

    /// Variant without the data-locality constraint (see struct docs).
    pub fn unpinned(costs: &'a CostGraph, link: Link) -> Problem<'a> {
        Problem::with_pin(costs, link, false)
    }

    /// Explicit-pinning constructor: the variant the amortized planners use
    /// when replicating a caller's pinning choice on a derived (e.g.
    /// Theorem-2 reduced) problem.
    pub fn with_pin(costs: &'a CostGraph, link: Link, pin_inputs: bool) -> Problem<'a> {
        Problem {
            pin_inputs,
            ..Problem::new(costs, link)
        }
    }

    /// Validity: the device set must be a lower set of the layer DAG
    /// (problem (12)'s precedence constraint), and when `pin_inputs` every
    /// source layer must be on the device.
    pub fn is_feasible(&self, device_set: &[bool]) -> bool {
        assert_eq!(device_set.len(), self.costs.len());
        let lower_set = self.costs.dag.edges().iter().all(|e| {
            // edge from -> to: if `to` is on the device, `from` must be too.
            !device_set[e.to] || device_set[e.from]
        });
        if !lower_set {
            return false;
        }
        if self.pin_inputs {
            (0..self.costs.len())
                .all(|v| self.costs.dag.in_degree(v) > 0 || device_set[v])
        } else {
            true
        }
    }

    /// Evaluate the overall training delay Eq. (7) for a device set,
    /// directly from model semantics (independent of any graph encoding —
    /// this is the ground truth the min-cut construction is tested against).
    ///
    /// T(c) = N_loc (T_{D,C} + T_{D,S} + T_{S,C} + T_{S,G}) + T_{D,U} + T_{S,D}
    pub fn delay(&self, device_set: &[bool]) -> f64 {
        let c = self.costs;
        assert_eq!(device_set.len(), c.len());
        let mut compute_device = 0.0; // T_{D,C}
        let mut compute_server = 0.0; // T_{S,C}
        let mut boundary_bytes = 0.0; // Σ_{v ∈ V_c} a_v
        let mut device_param_bytes = 0.0; // Σ_{v ∈ V_D} k_v
        for v in 0..c.len() {
            if device_set[v] {
                compute_device += c.xi_d[v];
                device_param_bytes += c.param_bytes[v];
                // v ∈ V_c iff some child is on the server; smashed data is
                // transmitted once regardless of how many such children.
                let crosses = c
                    .dag
                    .out_edges(v)
                    .iter()
                    .any(|&e| !device_set[c.dag.edge(e).to]);
                if crosses {
                    boundary_bytes += c.act_bytes[v];
                }
            } else {
                compute_server += c.xi_s[v];
            }
        }
        let smashed_up = boundary_bytes / self.link.up_bps; // T_{D,S}
        let grad_down = boundary_bytes / self.link.down_bps; // T_{S,G}
        let model_up = device_param_bytes / self.link.up_bps; // T_{D,U}
        let model_down = device_param_bytes / self.link.down_bps; // T_{S,D}
        c.n_loc * (compute_device + compute_server + smashed_up + grad_down)
            + model_up
            + model_down
    }

    /// Split Eq. (7) into the two terms the shared-server joint problem
    /// couples: `(A, W)` with `W = N_loc·T_{S,C}` (the server-compute work,
    /// the part that contends for shared server throughput — a server
    /// running at share `φ` of its profiled rate serves it in `W/φ`) and
    /// `A = T(c) − W` (device compute + all transmission, unaffected by
    /// server load). Computed term-by-term rather than by subtraction so
    /// the planner and the brute-force oracle agree to the last ULP;
    /// `A + W` equals [`Problem::delay`] up to summation-order rounding
    /// (within the `CUT_COST_ULPS` tolerance of the equivalence harness).
    ///
    /// NOTE: this accumulation loop intentionally mirrors
    /// [`Problem::delay`] above and `sim::breakdown::DelayBreakdown::of`
    /// — a cost-model change (e.g. charging boundary bytes per edge
    /// instead of per source vertex) must be applied to all three.
    pub fn delay_terms(&self, device_set: &[bool]) -> (f64, f64) {
        let c = self.costs;
        assert_eq!(device_set.len(), c.len());
        let mut compute_device = 0.0;
        let mut compute_server = 0.0;
        let mut boundary_bytes = 0.0;
        let mut device_param_bytes = 0.0;
        for v in 0..c.len() {
            if device_set[v] {
                compute_device += c.xi_d[v];
                device_param_bytes += c.param_bytes[v];
                let crosses = c
                    .dag
                    .out_edges(v)
                    .iter()
                    .any(|&e| !device_set[c.dag.edge(e).to]);
                if crosses {
                    boundary_bytes += c.act_bytes[v];
                }
            } else {
                compute_server += c.xi_s[v];
            }
        }
        let smashed_up = boundary_bytes / self.link.up_bps;
        let grad_down = boundary_bytes / self.link.down_bps;
        let model_up = device_param_bytes / self.link.up_bps;
        let model_down = device_param_bytes / self.link.down_bps;
        let a = c.n_loc * (compute_device + smashed_up + grad_down) + model_up + model_down;
        let w = c.n_loc * compute_server;
        (a, w)
    }

    /// Wrap a device set into a [`Partition`] with its evaluated delay.
    pub fn partition(&self, device_set: Vec<bool>) -> Partition {
        let delay = self.delay(&device_set);
        Partition { device_set, delay }
    }

    /// The all-on-server partition (the `central` reference baseline —
    /// privacy-violating: raw data leaves the device uncharged).
    pub fn central(&self) -> Partition {
        self.partition(vec![false; self.costs.len()])
    }

    /// The all-on-device partition (the `device-only` baseline).
    pub fn device_only(&self) -> Partition {
        self.partition(vec![true; self.costs.len()])
    }
}

impl Partition {
    /// Number of layers on the device.
    pub fn device_layers(&self) -> usize {
        self.device_set.iter().filter(|&&b| b).count()
    }

    /// The cut position when the device set is an index-contiguous prefix:
    /// `Some(k)` means layers `0..k` train on the device and `k..` on the
    /// server. Chain models (and the coordinator's stage graph) always
    /// produce prefixes; general DAG partitions need not be contiguous, in
    /// which case this returns `None` and callers should consult
    /// [`Partition::boundary_edges`] instead of re-deriving anything from
    /// the raw `device_set`.
    pub fn cut_layer(&self) -> Option<usize> {
        let k = self.device_set.iter().take_while(|&&b| b).count();
        if self.device_set[k..].iter().any(|&b| b) {
            None
        } else {
            Some(k)
        }
    }

    /// The cut-set edges `V_c` of this partition in `dag`: every
    /// `(device parent, server child)` pair, i.e. the edges whose smashed
    /// data / gradients cross the wire.
    pub fn boundary_edges(&self, dag: &Dag) -> Vec<(usize, usize)> {
        dag.edges()
            .iter()
            .filter(|e| self.device_set[e.from] && !self.device_set[e.to])
            .map(|e| (e.from, e.to))
            .collect()
    }

    /// Device layers with at least one server child — the vertices whose
    /// activations are transmitted (each pays its `a_v` once, however many
    /// boundary edges it has).
    pub fn boundary_layers(&self, dag: &Dag) -> Vec<usize> {
        (0..self.device_set.len())
            .filter(|&v| {
                self.device_set[v]
                    && dag
                        .out_edges(v)
                        .iter()
                        .any(|&e| !self.device_set[dag.edge(e).to])
            })
            .collect()
    }

    /// Human-readable cut description.
    pub fn describe(&self) -> String {
        format!(
            "{} device layers / {} total, T = {}",
            self.device_layers(),
            self.device_set.len(),
            crate::util::fmt_secs(self.delay)
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::profiles::{CostGraph, DeviceProfile, TrainCfg};

    fn lenet_problem() -> CostGraph {
        let m = models::by_name("lenet5").unwrap();
        CostGraph::build(
            &m,
            &DeviceProfile::jetson_tx2(),
            &DeviceProfile::rtx_a6000(),
            &TrainCfg::default(),
        )
    }

    #[test]
    fn central_has_no_transmission_terms() {
        let cg = lenet_problem();
        let p = Problem::new(&cg, Link::symmetric(1e6));
        let c = p.central();
        // All layers on server: delay is pure server compute.
        let server_total: f64 = cg.xi_s.iter().sum();
        assert!((c.delay - cg.n_loc * server_total).abs() < 1e-12);
    }

    #[test]
    fn device_only_pays_model_upload() {
        let cg = lenet_problem();
        let p = Problem::new(&cg, Link::symmetric(1e6));
        let d = p.device_only();
        let device_total: f64 = cg.xi_d.iter().sum();
        let k_total: f64 = cg.param_bytes.iter().sum();
        let expected = cg.n_loc * device_total + k_total / 1e6 + k_total / 1e6;
        assert!((d.delay - expected).abs() < 1e-9);
    }

    #[test]
    fn feasibility_checks_precedence() {
        let cg = lenet_problem();
        let p = Problem::new(&cg, Link::symmetric(1e6));
        let n = cg.len();
        // Prefix = feasible.
        let mut mask = vec![false; n];
        mask[0] = true;
        mask[1] = true;
        assert!(p.is_feasible(&mask));
        // Hole in the middle = infeasible (layer 2 off-device feeding 3).
        let mut bad = vec![false; n];
        bad[0] = true;
        bad[3] = true;
        assert!(!p.is_feasible(&bad));
    }

    #[test]
    fn boundary_counted_once_with_multiple_server_children() {
        // Graph: 0 -> 1, 0 -> 2 with 0 on device, both children on server.
        let m = {
            use crate::models::{LayerKind, ModelGraph, Shape};
            let (mut m, input) = ModelGraph::new("t", Shape::chw(1, 4, 4));
            let a = m.add(LayerKind::Relu, &[input]);
            let b = m.add(LayerKind::Relu, &[input]);
            m.add(LayerKind::Add, &[a, b]);
            m
        };
        let cg = CostGraph::build(
            &m,
            &DeviceProfile::jetson_tx1(),
            &DeviceProfile::rtx_a6000(),
            &TrainCfg {
                batch: 1,
                n_loc: 1,
                bwd_ratio: 0.0,
            },
        );
        let p = Problem::new(&cg, Link::symmetric(1.0)); // 1 B/s: bytes = secs
        let mask = vec![true, false, false, false];
        let t = p.delay(&mask);
        // input activation = 16 elems * 4 B = 64 B, up + down = 128 s;
        // both children AND add on server side -> server compute.
        let server: f64 = cg.xi_s[1] + cg.xi_s[2] + cg.xi_s[3];
        assert!((t - (128.0 + server)).abs() < 1e-9, "t={t}");
    }

    /// `delay_terms` splits Eq. (7) into the shared-server coupling terms:
    /// A + W re-sums to the delay (up to association rounding), W is
    /// exactly the server-compute share, and the all-device cut keeps
    /// W = 0.
    #[test]
    fn delay_terms_split_matches_delay() {
        let cg = lenet_problem();
        let p = Problem::new(&cg, Link::symmetric(1e6));
        for k in 0..=cg.len() {
            let mut mask = vec![false; cg.len()];
            for v in 0..k {
                mask[v] = true;
            }
            let (a, w) = p.delay_terms(&mask);
            let delay = p.delay(&mask);
            assert!(
                (a + w - delay).abs() <= 1e-12 * (1.0 + delay.abs()),
                "prefix {k}: A+W = {} vs delay {delay}",
                a + w
            );
            let server: f64 = (k..cg.len()).map(|v| cg.xi_s[v]).sum();
            assert!((w - cg.n_loc * server).abs() <= 1e-12 * (1.0 + w));
            assert!(a >= 0.0 && w >= 0.0);
        }
        let all = vec![true; cg.len()];
        let (_, w_dev_only) = p.delay_terms(&all);
        assert_eq!(w_dev_only, 0.0);
    }

    #[test]
    #[should_panic(expected = "rates must be positive")]
    fn rejects_zero_rate() {
        let cg = lenet_problem();
        let _ = Problem::new(&cg, Link::symmetric(0.0));
    }

    #[test]
    #[should_panic(expected = "rates must be positive and finite")]
    fn rejects_nan_rate() {
        let cg = lenet_problem();
        let _ = Problem::new(&cg, Link::symmetric(f64::NAN));
    }

    /// `Link::is_valid` is the shared admission predicate of every
    /// planning entry point: finite AND strictly positive on both rates.
    /// `+∞` in particular must be rejected — it passes a bare `rate > 0`
    /// check while contributing a silent 0 to σ.
    #[test]
    fn link_validity_rejects_non_finite_and_non_positive_rates() {
        assert!(Link::symmetric(1e6).is_valid());
        assert!(Link { up_bps: 1e4, down_bps: 1e9 }.is_valid());
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            assert!(!Link::symmetric(bad).is_valid(), "accepted rate {bad}");
            assert!(
                !Link { up_bps: 1e6, down_bps: bad }.is_valid(),
                "accepted down rate {bad}"
            );
            assert!(
                !Link { up_bps: bad, down_bps: 1e6 }.is_valid(),
                "accepted up rate {bad}"
            );
        }
    }

    #[test]
    fn sigma_is_round_trip_byte_cost() {
        let l = Link {
            up_bps: 4.0,
            down_bps: 8.0,
        };
        assert_eq!(l.sigma(), 0.25 + 0.125);
        assert_eq!(Link::symmetric(2.0).sigma(), 1.0);
    }

    #[test]
    fn cut_layer_detects_prefixes() {
        let prefix = Partition {
            device_set: vec![true, true, false, false],
            delay: 0.0,
        };
        assert_eq!(prefix.cut_layer(), Some(2));
        let all_device = Partition {
            device_set: vec![true; 3],
            delay: 0.0,
        };
        assert_eq!(all_device.cut_layer(), Some(3));
        let all_server = Partition {
            device_set: vec![false; 3],
            delay: 0.0,
        };
        assert_eq!(all_server.cut_layer(), Some(0));
        let hole = Partition {
            device_set: vec![true, false, true],
            delay: 0.0,
        };
        assert_eq!(hole.cut_layer(), None);
    }

    #[test]
    fn boundary_accessors_match_delay_accounting() {
        // Diamond: 0 -> {1, 2} -> 3 with {0, 1} on the device: layer 0's
        // activation crosses to 2, layer 1's to 3 — two boundary edges,
        // two boundary layers.
        let mut dag = crate::graph::Dag::new();
        for i in 0..4 {
            dag.add_node(format!("v{i}"));
        }
        dag.add_edge(0, 1, 0.0);
        dag.add_edge(0, 2, 0.0);
        dag.add_edge(1, 3, 0.0);
        dag.add_edge(2, 3, 0.0);
        let p = Partition {
            device_set: vec![true, true, false, false],
            delay: 0.0,
        };
        assert_eq!(p.boundary_edges(&dag), vec![(0, 2), (1, 3)]);
        assert_eq!(p.boundary_layers(&dag), vec![0, 1]);
        assert_eq!(p.cut_layer(), Some(2));
        // Device-only: nothing crosses.
        let d = Partition {
            device_set: vec![true; 4],
            delay: 0.0,
        };
        assert!(d.boundary_edges(&dag).is_empty());
        assert!(d.boundary_layers(&dag).is_empty());
    }

    #[test]
    fn serial_links_add_sigmas_and_stay_valid() {
        let a = Link {
            up_bps: 2.0e6,
            down_bps: 8.0e6,
        };
        let b = Link {
            up_bps: 6.0e6,
            down_bps: 8.0e6,
        };
        let s = Link::serial(a, b);
        assert!(s.is_valid());
        // Per-direction harmonic rates: 1/(1/2 + 1/6) = 1.5, 8 || 8 = 4.
        assert!((s.up_bps - 1.5e6).abs() < 1e-3);
        assert!((s.down_bps - 4.0e6).abs() < 1e-3);
        // σ is additive under serial composition — the invariant the
        // multi-hop pooling path relies on.
        assert!((s.sigma() - (a.sigma() + b.sigma())).abs() < 1e-18);
        // Composition is symmetric.
        assert_eq!(Link::serial(a, b), Link::serial(b, a));
    }
}
