//! Joint fleet partitioning under shared, finite server capacity.
//!
//! The paper (and every engine below [`super::fleet`]) solves each device's
//! split against a *dedicated* server: Eq. (7)'s server-compute term
//! `T_{S,C}` assumes the full profiled throughput. In a real fleet the
//! server is shared — give device `d` a throughput share `φ_d ∈ (0, 1]`
//! and its server work `W_d` (see [`Problem::delay_terms`]) is served in
//! `W_d/φ_d`, with the shares bounded by the server's capacity
//! `Σ_d φ_d ≤ C` (`C` in concurrent full-throughput device-equivalents).
//! Cut decisions are thereby coupled across devices: pushing one device's
//! layers to the server eats capacity every other device wants. The joint
//! problem solved here is the fleet **makespan** minimization
//!
//! ```text
//!   min over cuts x_d and shares φ_d of  max_d  A_d(x_d) + W_d(x_d)/φ_d
//!   s.t.  φ_d ∈ (0, 1],  Σ_d φ_d ≤ C
//! ```
//!
//! # Exact decomposition: makespan bisection × per-device price probes
//!
//! For a candidate makespan `T`, device `d` needs share
//! `φ_d = W_d/(T − A_d)` (0 when `W_d = 0`), so `T` is achievable iff
//! every device has a cut with `A + W ≤ T` and
//!
//! ```text
//!   Σ_d  h_d(T) ≤ C,   h_d(T) = min over cuts {W/(T − A) : A + W ≤ T}
//! ```
//!
//! `Σ h_d` is continuous and non-increasing in `T`, so the optimal
//! makespan is found by **bisection** over `T` (the fixed-point/bisection
//! loop of the price iteration). Each `h_d(T)` is a linear-fractional
//! program over the finite cut set and is solved **exactly** by Dinkelbach
//! iteration: minimizing the ratio `W/(T − A)` reduces to repeatedly
//! minimizing `A + λ·W` at the congestion price `λ = (T − A)/W` of the
//! incumbent — which is precisely the paper's min-cut problem with the
//! server FLOPs scaled by `λ` ([`FleetPlanner::priced_solve`]). The ratio
//! iterates decrease strictly and the cut set is finite, so the loop
//! terminates at the true minimum; since the bisection then needs only
//! ULP-converged feasibility thresholds, the joint optimum matches the
//! brute-force oracle ([`oracle_fleet_makespan`]) to within the
//! `CUT_COST_ULPS` harness tolerance — the headline test of this module.
//!
//! Every price probe re-solves a tier whose flow network differs from the
//! previous probe **only in capacities** (σ and/or λ), so probes ride the
//! PR-4 incremental path: flow-preserving refresh → conservation repair →
//! residual augmentation. A whole joint epoch is one cold solve per tier
//! plus warm refreshes — `FleetStats::{price_iterations, joint_resolves,
//! incremental_solves}` prove it. One carve-out keeps the probes exact:
//! the Theorem 2 block reduction is a **λ = 1 theorem** (its exchange
//! argument assumes a layer is never cheaper on the device than on the
//! server, which a congestion price can invert, so a λ-optimal cut may
//! split an abstracted block). When the main engine solves a reduced DAG,
//! the planner therefore lazily builds an **unreduced sibling engine** on
//! the first congested epoch and routes every λ probe through it —
//! dedicated λ = 1 epochs keep their reduced-scale solves, probes keep
//! full-DAG expressiveness, and both engines' counters are folded into
//! [`JointPlanner::stats`].
//!
//! # Share allocation and reported delays
//!
//! With the final cuts fixed, shares are set to the minimal **congestion
//! level** `T_c`: the smallest level with
//! `Σ_d min(1, W_d/(max(T_c, A_d+W_d) − A_d)) ≤ C` (pure arithmetic
//! bisection, [`fleet_makespan_for_cuts`]). Each decision's
//! [`Partition::delay`] is the device's *load-dependent* delay
//! `max(A + W, T_c)` (`A` alone for zero-server-work cuts) — not the
//! dedicated-server Eq. (7) value — and the fleet makespan is their
//! maximum. Cut selection is **group-local** (each group takes its own
//! share-ratio minimizer at the optimal target): deterministic and
//! monotone in the capacity, at the cost that a non-bottleneck device may
//! keep a zero-share all-device cut while server budget idles — the
//! makespan is optimal either way; see the ROADMAP follow-up on Pareto
//! share redistribution. When the server can give every session a full
//! share (`#{W_d > 0} ≤ C`, in particular whenever `C = ∞`), the joint
//! plan **degenerates to the dedicated engine**: [`JointPlanner::plan`]
//! returns [`FleetPlanner::plan`]'s decisions verbatim — bit-identical,
//! counters included — which is the pinned ∞-capacity contract.

use super::fleet::{
    DecisionProvenance, DecisionStats, FleetImage, FleetOptions, FleetPlanner, FleetSpec,
    FleetStats, PlanDecision, PlanRequest, SpecDelta, SpecError,
};
use super::types::{Link, Partition, Problem};
use crate::graph::enumerate_lower_sets;

/// Construction-time switches of the joint engine.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct JointOptions {
    /// Shared server capacity in concurrent full-throughput
    /// device-equivalents: the share vector of one epoch's sessions must
    /// sum to at most this. `f64::INFINITY` (the default) means a
    /// dedicated server per device — the engine then delegates to
    /// [`FleetPlanner`] bit-identically.
    pub server_capacity: f64,
    /// Switches of the wrapped per-tier engine ([`FleetOptions`]).
    pub fleet: FleetOptions,
}

impl Default for JointOptions {
    fn default() -> JointOptions {
        JointOptions {
            server_capacity: f64::INFINITY,
            fleet: FleetOptions::default(),
        }
    }
}

impl JointOptions {
    /// Default engine switches at the given shared server capacity.
    pub fn with_capacity(server_capacity: f64) -> JointOptions {
        JointOptions {
            server_capacity,
            ..JointOptions::default()
        }
    }
}

/// Required total server share for per-cut terms `(A, W, sessions)` when
/// every session's delay is capped at `max(level, A + W)`: `W/(level − A)`
/// per session beyond its dedicated time, a full share (1) at or below it,
/// nothing for zero-server-work cuts. Non-increasing and continuous in
/// `level`.
pub(crate) fn required_shares(terms: &[(f64, f64, usize)], level: f64) -> f64 {
    terms
        .iter()
        .map(|&(a, w, n)| {
            if w <= 0.0 {
                0.0
            } else if level <= a + w {
                n as f64
            } else {
                n as f64 * (w / (level - a))
            }
        })
        .sum()
}

/// Minimal congestion level `T_c` whose share demand fits `capacity`
/// (0 when dedicated shares already fit). Pure arithmetic bisection,
/// converged to the ULP.
pub(crate) fn congestion_level(terms: &[(f64, f64, usize)], capacity: f64) -> f64 {
    if required_shares(terms, 0.0) <= capacity {
        return 0.0;
    }
    let mut hi = terms
        .iter()
        .map(|&(a, w, _)| a + w)
        .fold(f64::MIN_POSITIVE, f64::max);
    while required_shares(terms, hi) > capacity {
        hi *= 2.0;
    }
    let mut lo = 0.0;
    for _ in 0..600 {
        let mid = 0.5 * (lo + hi);
        if mid <= lo || mid >= hi {
            break;
        }
        if required_shares(terms, mid) <= capacity {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    hi
}

/// Optimal fleet makespan for **fixed** cuts: per-cut Eq. (7) terms
/// `(A, W, sessions)` sharing a server of the given capacity, under the
/// optimal share allocation (see the module docs). This is the objective
/// both [`JointPlanner`] and the brute-force oracle score combinations
/// with — sharing one implementation keeps the oracle pin honest about
/// everything except the search itself.
pub fn fleet_makespan_for_cuts(terms: &[(f64, f64, usize)], capacity: f64) -> f64 {
    assert!(capacity > 0.0, "server capacity must be positive");
    let dedicated = terms.iter().map(|&(a, w, _)| a + w).fold(0.0, f64::max);
    dedicated.max(congestion_level(terms, capacity))
}

/// Brute-force oracle for tiny fleets: exhaustively enumerate every
/// feasible cut (lower set, inputs pinned per each problem) **combination**
/// across the devices and return the minimal fleet makespan under
/// [`fleet_makespan_for_cuts`]. Exponential in fleet size and lower-set
/// counts — callers must keep fleets at 2–3 devices over small models (the
/// product of per-device cut counts is asserted below). This is the ground
/// truth `JointPlanner` is pinned against.
pub fn oracle_fleet_makespan(problems: &[Problem<'_>], capacity: f64) -> f64 {
    assert!(!problems.is_empty(), "oracle needs at least one device");
    assert!(capacity > 0.0, "server capacity must be positive");
    let per_device: Vec<Vec<(f64, f64)>> = problems
        .iter()
        .map(|p| {
            let inputs: Vec<usize> = (0..p.costs.len())
                .filter(|&v| p.costs.dag.in_degree(v) == 0)
                .collect();
            let mut cuts = Vec::new();
            enumerate_lower_sets(&p.costs.dag, |mask| {
                if p.pin_inputs && inputs.iter().any(|&v| !mask[v]) {
                    return;
                }
                cuts.push(p.delay_terms(mask));
            });
            assert!(!cuts.is_empty(), "no feasible cut for a device");
            cuts
        })
        .collect();
    let combos = per_device
        .iter()
        .fold(1u64, |acc, c| acc.saturating_mul(c.len() as u64));
    assert!(
        combos <= 5_000_000,
        "oracle fleet too large: {combos} cut combinations"
    );

    let mut idx = vec![0usize; per_device.len()];
    let mut terms: Vec<(f64, f64, usize)> = vec![(0.0, 0.0, 1); per_device.len()];
    let mut best = f64::INFINITY;
    loop {
        let mut dedicated: f64 = 0.0;
        for (d, &i) in idx.iter().enumerate() {
            let (a, w) = per_device[d][i];
            terms[d] = (a, w, 1);
            dedicated = dedicated.max(a + w);
        }
        // The makespan never beats the slowest dedicated time, so combos
        // whose dedicated bound already loses skip the share bisection —
        // this prune is what keeps the exhaustive sweep affordable.
        if dedicated < best {
            let makespan = dedicated.max(congestion_level(&terms, capacity));
            if makespan < best {
                best = makespan;
            }
        }
        // Odometer over the cartesian product of per-device cuts.
        let mut d = 0;
        loop {
            if d == per_device.len() {
                return best;
            }
            idx[d] += 1;
            if idx[d] < per_device[d].len() {
                break;
            }
            idx[d] = 0;
            d += 1;
        }
    }
}

/// Result of one [`min_share_ratio`] evaluation: the minimal share ratio
/// and the `(A, W)` terms + device set of the cut achieving it.
pub(crate) struct ProbeResult {
    pub(crate) ratio: f64,
    pub(crate) a: f64,
    pub(crate) w: f64,
    /// `None` = the λ=1 decision of the epoch's base pass.
    pub(crate) cut: Option<Vec<bool>>,
}

/// One distinct (tier, link) of an epoch batch: its member request
/// indices, the λ=1 (dedicated) optimum's terms, and the latest price
/// probe's result.
pub(crate) struct Group {
    pub(crate) tier: usize,
    pub(crate) link: Link,
    /// Request indices served by this group, in batch order.
    pub(crate) members: Vec<usize>,
    /// `(A, W)` of the dedicated-server (λ=1) optimal cut.
    pub(crate) base: (f64, f64),
    /// `A` of the all-on-device cut — the zero-share fallback every
    /// target above it can always take.
    pub(crate) device_only_a: f64,
    /// Latest [`min_share_ratio`] result.
    pub(crate) probe: ProbeResult,
}

/// `h_g(T)`: the minimal server-share ratio `W/(T − A)` over this group's
/// feasible cuts (`A + W ≤ T`), solved exactly by Dinkelbach price
/// iteration over warm [`FleetPlanner::priced_solve`] probes (see the
/// module docs). Updates `g.probe` with the achieving cut and returns the
/// ratio. Deterministic and group-local: the iterate sequence depends only
/// on the group's own `(link, λ)` probes, never on other groups.
pub(crate) fn min_share_ratio(
    fleet: &mut FleetPlanner,
    pin_inputs: bool,
    g: &mut Group,
    t: f64,
    joint_resolves: &mut u64,
) -> f64 {
    let (base_a, base_w) = g.base;
    if base_w <= 0.0 {
        g.probe = ProbeResult {
            ratio: 0.0,
            a: base_a,
            w: base_w,
            cut: None,
        };
        return 0.0;
    }
    // The base cut minimizes A + W, so it is feasible at every target the
    // outer bisection probes (t ≥ max over groups of the base A + W).
    let mut best = ProbeResult {
        ratio: base_w / (t - base_a),
        a: base_a,
        w: base_w,
        cut: None,
    };
    // Warm start from the previous evaluation's cut when it is still
    // feasible at the new target — consecutive bisection probes move T a
    // little, so the incumbent usually needs zero or one refinement.
    if let Some(set) = g.probe.cut.as_ref() {
        let (pa, pw) = (g.probe.a, g.probe.w);
        let ratio = if pw <= 0.0 {
            (pa <= t).then_some(0.0)
        } else {
            (pa + pw <= t).then(|| pw / (t - pa))
        };
        if let Some(r) = ratio {
            if r < best.ratio {
                best = ProbeResult {
                    ratio: r,
                    a: pa,
                    w: pw,
                    cut: Some(set.clone()),
                };
            }
        }
    }
    for _ in 0..48 {
        if best.ratio <= 0.0 {
            break;
        }
        // λ = 1/θ of the incumbent ratio; clamped at the dedicated price
        // (float noise in t − A could push θ a hair above 1).
        let lambda = (1.0 / best.ratio).max(1.0);
        let p = fleet.priced_solve(g.tier, g.link, lambda);
        *joint_resolves += 1;
        let problem = Problem::with_pin(fleet.spec().tier_costs(g.tier), g.link, pin_inputs);
        let (a2, w2) = problem.delay_terms(&p.device_set);
        let theta2 = if w2 <= 0.0 {
            0.0
        } else {
            let headroom = t - a2;
            if headroom <= 0.0 {
                // Float-pathological probe; the incumbent stands.
                break;
            }
            w2 / headroom
        };
        if theta2 < best.ratio * (1.0 - 1e-13) {
            best = ProbeResult {
                ratio: theta2,
                a: a2,
                w: w2,
                cut: Some(p.device_set),
            };
        } else {
            // Dinkelbach fixed point: the priced optimum no longer
            // improves the ratio — `best` is the exact minimum. When the
            // incumbent is still the λ=1 base cut (possibly from a
            // *reduced* solve), adopt the ratio-equal probe cut instead:
            // it came from this probe engine, so every reported congested
            // cut shares one solver family and the λ-nesting (cut never
            // moves server-ward under more congestion) holds uniformly.
            if best.cut.is_none() && theta2 <= best.ratio * (1.0 + 1e-12) {
                best = ProbeResult {
                    ratio: theta2,
                    a: a2,
                    w: w2,
                    cut: Some(p.device_set),
                };
            }
            break;
        }
    }
    // A zero-share cut is always available once the target admits the
    // all-on-device delay; it dominates any positive ratio (and guards the
    // iteration cap above from ever leaving a positive ratio standing
    // where 0 is reachable — the upper bisection bracket relies on this).
    if best.ratio > 0.0 && g.device_only_a <= t {
        let n = fleet.spec().tier_costs(g.tier).len();
        best = ProbeResult {
            ratio: 0.0,
            a: g.device_only_a,
            w: 0.0,
            cut: Some(vec![true; n]),
        };
    }
    let ratio = best.ratio;
    g.probe = best;
    ratio
}

/// The joint planning facade: wraps a [`FleetPlanner`] and couples its
/// per-tier decisions through the shared server capacity. Keeps the
/// request/response `plan(&[PlanRequest]) -> Vec<PlanDecision>` shape of
/// the fleet engine; see the module docs for the solved problem and the
/// degeneracy contracts.
pub struct JointPlanner {
    fleet: FleetPlanner,
    /// The λ-probe engine: an **unreduced** clone of the fleet engine,
    /// built lazily on the first congested epoch and only when the main
    /// engine solves a Theorem 2 reduced DAG. The reduction's validity
    /// argument assumes the dedicated λ = 1 cost model (a block member is
    /// never cheaper on the device than on the server), which a
    /// congestion price λ > 1 can invert — a λ-optimal cut may split an
    /// abstracted block, so probes must run on the full DAG to stay
    /// exact. `None` while unneeded (unreduced main engine, or no
    /// congested epoch yet); probes then share the main engine.
    probe: Option<FleetPlanner>,
    options: JointOptions,
    price_iterations: u64,
    joint_resolves: u64,
    /// Fleet makespan of the latest non-empty epoch.
    last_makespan: Option<f64>,
    /// Congestion level `T_c` of the latest epoch (`None` when every
    /// session got a dedicated share).
    last_congestion: Option<f64>,
}

impl JointPlanner {
    /// Build for a fleet and explicit joint options.
    pub fn new(spec: FleetSpec, options: JointOptions) -> JointPlanner {
        assert!(
            options.server_capacity > 0.0,
            "server capacity must be positive"
        );
        JointPlanner {
            fleet: FleetPlanner::with_options(spec, options.fleet),
            probe: None,
            options,
            price_iterations: 0,
            joint_resolves: 0,
            last_makespan: None,
            last_congestion: None,
        }
    }

    /// Build with the default engine switches at the given capacity.
    pub fn with_capacity(spec: FleetSpec, server_capacity: f64) -> JointPlanner {
        JointPlanner::new(spec, JointOptions::with_capacity(server_capacity))
    }

    /// Update the shared server capacity for subsequent epochs (the
    /// server scaling up or down at runtime). Capacity is not baked into
    /// any flow network — it only gates the price loop — so the per-tier
    /// solver state (and its reusable flows) carries over untouched.
    pub fn set_server_capacity(&mut self, server_capacity: f64) {
        assert!(server_capacity > 0.0, "server capacity must be positive");
        self.options.server_capacity = server_capacity;
    }

    /// Serve one epoch jointly: one decision per request, in request
    /// order, with duplicate (tier, link) requests served as bit-exact
    /// copies of their group's decision. Infinite capacity (or enough
    /// capacity for a dedicated share per server-using session) returns
    /// the wrapped [`FleetPlanner::plan`] decisions verbatim; otherwise
    /// the makespan bisection runs and every decision's delay is the
    /// load-dependent `max(A + W, T_c)` (see the module docs).
    pub fn plan(&mut self, requests: &[PlanRequest]) -> Vec<PlanDecision> {
        let capacity = self.options.server_capacity;
        if capacity.is_infinite() {
            // Dedicated server per device: delegate bit-identically —
            // decisions AND counters (the ∞-capacity pin).
            let decisions = self.fleet.plan(requests);
            self.last_makespan = decisions
                .iter()
                .map(|d| d.partition.delay)
                .fold(None, |m: Option<f64>, d| Some(m.map_or(d, |m| m.max(d))));
            self.last_congestion = None;
            return decisions;
        }

        // σ-quantization runs before any grouping key forms, so the base
        // pass, the probe groups and the tier caches all see the snapped
        // links; the re-quantization inside `FleetPlanner::plan` is then
        // the identity (rewrites count exactly once).
        let quantized = self.fleet.quantize_requests(requests);
        let requests: &[PlanRequest] = quantized.as_deref().unwrap_or(requests);

        // λ=1 base pass: per-device dedicated optima. Also the epoch's
        // answer whenever the capacity covers a full share per session.
        let base = self.fleet.plan(requests);
        if requests.is_empty() {
            self.last_makespan = None;
            self.last_congestion = None;
            return base;
        }

        // Group requests per distinct (tier, link) — members share (A, W)
        // curves, so they share a cut and a share ratio.
        let pin_inputs = self.fleet.options().pin_inputs;
        let mut groups: Vec<Group> = Vec::new();
        let mut group_of: std::collections::HashMap<(usize, u64, u64), usize> =
            std::collections::HashMap::new();
        for (i, r) in requests.iter().enumerate() {
            // Retired tiers never join the congestion coupling: their
            // devices have departed, their base answer is the archived
            // [`DecisionProvenance::Retired`] decision (served verbatim
            // below), and probing them would need a solver that no longer
            // exists.
            if self.fleet.spec().tier_retired(r.tier) {
                continue;
            }
            let key = (r.tier, r.link.up_bps.to_bits(), r.link.down_bps.to_bits());
            let g = *group_of.entry(key).or_insert_with(|| {
                let costs = self.fleet.spec().tier_costs(r.tier);
                let problem = Problem::with_pin(costs, r.link, pin_inputs);
                let (a, w) = problem.delay_terms(&base[i].partition.device_set);
                let all_on_device = vec![true; costs.len()];
                let device_only_a = problem.delay_terms(&all_on_device).0;
                groups.push(Group {
                    tier: r.tier,
                    link: r.link,
                    members: Vec::new(),
                    base: (a, w),
                    device_only_a,
                    probe: ProbeResult {
                        ratio: f64::INFINITY,
                        a: 0.0,
                        w: 0.0,
                        cut: None,
                    },
                });
                groups.len() - 1
            });
            groups[g].members.push(i);
        }
        // Canonical group order: probe sequences and share-demand sums run
        // over this list, and each group's price iteration is group-local,
        // so sorting here makes the whole joint solve independent of the
        // request order (pinned by the batched-bit-identity test).
        groups.sort_by_key(|g| (g.tier, g.link.up_bps.to_bits(), g.link.down_bps.to_bits()));

        // Uncongested epoch: a full share for every server-using session
        // fits, so the dedicated decisions are jointly optimal — return
        // them untouched (delays stay the plain Eq. (7) values).
        let dedicated_shares: f64 = groups
            .iter()
            .filter(|g| g.base.1 > 0.0)
            .map(|g| g.members.len() as f64)
            .sum();
        if dedicated_shares <= capacity {
            self.last_makespan = Some(
                base.iter()
                    .map(|d| d.partition.delay)
                    .fold(0.0, f64::max),
            );
            self.last_congestion = None;
            return base;
        }

        // Congested epoch ahead: probes at λ ≠ 1 need the full DAG, so a
        // reduced main engine gets an unreduced sibling for them (built
        // once, reused — and never built at all if no epoch ever
        // congests). See the `probe` field docs.
        if self.probe.is_none() && self.fleet.is_reduced() {
            self.probe = Some(FleetPlanner::with_options(
                self.fleet.spec().clone(),
                FleetOptions {
                    block_reduction: false,
                    ..self.options.fleet
                },
            ));
        }

        // Makespan bisection. Lower bracket: no device can beat its own
        // dedicated optimum, so T* ≥ max over groups of base A + W. Upper
        // bracket: at the worst all-on-device delay every group can take a
        // zero-share cut, so the demand is 0 ≤ C.
        let t_lo = groups
            .iter()
            .map(|g| g.base.0 + g.base.1)
            .fold(0.0, f64::max);
        let t_hi = groups
            .iter()
            .map(|g| g.device_only_a)
            .fold(t_lo, f64::max);
        let mut lo = t_lo;
        let mut hi = t_hi;
        // Whether the group probes are currently positioned at `hi` (the
        // feasible end), so the final re-evaluation below can be skipped.
        let mut probes_at_hi = false;
        if self.probe_feasible(&mut groups, t_lo) {
            hi = t_lo;
            probes_at_hi = true;
        } else {
            for _ in 0..120 {
                let mid = 0.5 * (lo + hi);
                if mid <= lo || mid >= hi {
                    break;
                }
                if self.probe_feasible(&mut groups, mid) {
                    hi = mid;
                    probes_at_hi = true;
                } else {
                    lo = mid;
                    probes_at_hi = false;
                }
            }
        }
        // Final evaluation at the feasible end, unless the last probe
        // already ran there. (`hi` starts at the worst all-on-device
        // delay, where every group's zero-share cut is admissible, so the
        // feasible end always exists.)
        if !probes_at_hi {
            let still_feasible = self.probe_feasible(&mut groups, hi);
            debug_assert!(still_feasible, "bisection kept `hi` feasible throughout");
            let _ = still_feasible;
        }

        // Fix the cuts, set shares at the minimal congestion level, and
        // report load-dependent delays. The per-group cut is the
        // group-LOCAL share-ratio minimizer at the optimal target — a
        // deliberate trade: a non-bottleneck device may land on a
        // zero-share (all-device) cut even when idle server budget could
        // have served it faster, but keeping the selection group-local is
        // what makes it deterministic and monotone in the capacity (a
        // budget-coupled "give idle shares back" pass can flip a cut
        // *server-ward* as capacity shrinks — see the ROADMAP follow-up
        // on Pareto share redistribution). The fleet makespan is optimal
        // either way; only non-binding devices' slack is left unused.
        let terms: Vec<(f64, f64, usize)> = groups
            .iter()
            .map(|g| (g.probe.a, g.probe.w, g.members.len()))
            .collect();
        let t_c = congestion_level(&terms, capacity);
        let dedicated = terms.iter().map(|&(a, w, _)| a + w).fold(0.0, f64::max);
        let makespan = dedicated.max(t_c);
        self.last_makespan = Some(makespan);
        self.last_congestion = Some(t_c);

        let mut decisions: Vec<Option<PlanDecision>> = (0..requests.len()).map(|_| None).collect();
        for g in &groups {
            let (a, w) = (g.probe.a, g.probe.w);
            let device_set = g
                .probe
                .cut
                .clone()
                .unwrap_or_else(|| base[g.members[0]].partition.device_set.clone());
            let delay = if w <= 0.0 { a } else { (a + w).max(t_c) };
            for (j, &i) in g.members.iter().enumerate() {
                let partition = Partition {
                    device_set: device_set.clone(),
                    delay,
                };
                decisions[i] = Some(PlanDecision {
                    device: requests[i].device,
                    tier: requests[i].tier,
                    cut_layer: partition.cut_layer(),
                    partition,
                    // Only the group's first request carries refreshed=true
                    // (mirrors the fleet facade's duplicate handling).
                    stats: DecisionStats { refreshed: j == 0 },
                    provenance: if j == 0 {
                        DecisionProvenance::Fresh
                    } else {
                        DecisionProvenance::Cached
                    },
                });
            }
        }
        decisions
            .into_iter()
            .enumerate()
            .map(|(i, d)| {
                // Requests for retired tiers bypassed the grouping above;
                // their answer is the base pass's archived decision.
                d.unwrap_or_else(|| base[i].clone())
            })
            .collect()
    }

    /// One feasibility probe of the makespan bisection: can every group
    /// meet target `t` with total share demand within capacity? Updates
    /// every group's `probe` via [`min_share_ratio`] (counted in
    /// `price_iterations`; the priced solves it triggers in
    /// `joint_resolves`).
    fn probe_feasible(&mut self, groups: &mut [Group], t: f64) -> bool {
        self.price_iterations += 1;
        let pin_inputs = self.options.fleet.pin_inputs;
        let capacity = self.options.server_capacity;
        // Probes run on the unreduced sibling when the main engine is
        // reduced (split borrow keeps both engines reachable).
        let JointPlanner {
            fleet,
            probe,
            joint_resolves,
            ..
        } = &mut *self;
        let engine = probe.as_mut().unwrap_or(fleet);
        let mut demand = 0.0;
        for g in groups.iter_mut() {
            let ratio = min_share_ratio(engine, pin_inputs, g, t, joint_resolves);
            demand += g.members.len() as f64 * ratio;
        }
        demand <= capacity
    }

    /// Fleet makespan of the latest non-empty epoch: the maximum
    /// load-dependent delay across its sessions (equal to the dedicated
    /// maximum whenever the epoch was uncongested).
    pub fn makespan(&self) -> Option<f64> {
        self.last_makespan
    }

    /// Congestion level `T_c` of the latest epoch: the common delay
    /// congested sessions were equalized at, `None` when every session got
    /// a dedicated share (also for every ∞-capacity epoch).
    pub fn congestion(&self) -> Option<f64> {
        self.last_congestion
    }

    /// Aggregate solver counters: the wrapped fleet engine's
    /// [`FleetStats`] plus this planner's `price_iterations` /
    /// `joint_resolves` (both 0 under infinite capacity — the bit-identity
    /// pin covers the full struct). When the unreduced λ-probe engine
    /// exists, its solve/refresh/incremental counters are folded in (its
    /// `plans`/`requests` are always 0 — probes are not served plans);
    /// the DAG-size and block fields keep reporting the *main* engine.
    pub fn stats(&self) -> FleetStats {
        let mut s = self.fleet.stats();
        if let Some(p) = &self.probe {
            let ps = p.stats();
            s.refreshes += ps.refreshes;
            s.flow_solves += ps.flow_solves;
            s.linear_scans += ps.linear_scans;
            s.incremental_solves += ps.incremental_solves;
            s.repair_pushes += ps.repair_pushes;
            s.augment_rounds += ps.augment_rounds;
            s.fallback_cold_solves += ps.fallback_cold_solves;
        }
        s.price_iterations = self.price_iterations;
        s.joint_resolves = self.joint_resolves;
        s
    }

    /// Apply one churn event to the live planner: forwarded to the main
    /// fleet engine and — so the two stay one fleet — to the unreduced
    /// λ-probe sibling if it has been built (its `spec_deltas` counter is
    /// probe-local and never reported; [`JointPlanner::stats`] counts the
    /// main engine's). A malformed delta is rejected with a typed
    /// [`SpecError`] before either engine moves.
    pub fn try_apply_delta(&mut self, delta: &SpecDelta) -> Result<(), SpecError> {
        self.fleet.try_apply(delta)?;
        if let Some(p) = &mut self.probe {
            p.try_apply(delta)
                .expect("probe sibling shares the fleet spec");
        }
        Ok(())
    }

    /// Panicking convenience over [`JointPlanner::try_apply_delta`] for
    /// callers that treat a malformed delta as a bug.
    pub fn apply_delta(&mut self, delta: &SpecDelta) {
        if let Err(e) = self.try_apply_delta(delta) {
            panic!("malformed churn event: {e}");
        }
    }

    /// Immediately expire a retired tier's archived decision on both
    /// engines (see [`FleetPlanner::expire_retired`] — the daemon's
    /// retire-TTL hook).
    pub fn expire_retired(&mut self, tier: usize) {
        self.fleet.expire_retired(tier);
        if let Some(p) = &mut self.probe {
            p.expire_retired(tier);
        }
    }

    /// The link of a tier's warm cached λ=1 decision (see
    /// [`FleetPlanner::cached_link`]) — the service layer's solve-budget
    /// estimator.
    pub(crate) fn cached_link(&self, tier: usize) -> Option<Link> {
        self.fleet.cached_link(tier)
    }

    /// Record `n` degraded decisions the service layer served on this
    /// planner's behalf (surfaced via [`FleetStats::degraded_decisions`]).
    pub(crate) fn note_degraded(&mut self, n: u64) {
        self.fleet.note_degraded(n);
    }

    /// Forward of [`FleetPlanner::quantize_requests`] for the service
    /// layer, which must snap links *before* its budget walk compares
    /// them against the tier caches (a post-walk snap would misclassify
    /// bucket siblings as dirty).
    pub(crate) fn quantize_requests(
        &mut self,
        requests: &[PlanRequest],
    ) -> Option<Vec<PlanRequest>> {
        self.fleet.quantize_requests(requests)
    }

    /// The switches this planner was built with.
    pub fn options(&self) -> JointOptions {
        self.options
    }

    /// The fleet this planner serves.
    pub fn spec(&self) -> &FleetSpec {
        self.fleet.spec()
    }

    /// Drop every tier's cached λ=1 decision (see
    /// [`FleetPlanner::invalidate`]).
    pub fn invalidate(&mut self) {
        self.fleet.invalidate();
    }

    /// Export the crash-surviving state of this planner (see
    /// [`JointImage`]); the byte codec lives in `daemon::snapshot`.
    pub(crate) fn export_image(&self) -> JointImage {
        JointImage {
            options: self.options,
            fleet: self.fleet.export_image(),
            probe: self.probe.as_ref().map(|p| p.export_image()),
            price_iterations: self.price_iterations,
            joint_resolves: self.joint_resolves,
            last_makespan: self.last_makespan,
            last_congestion: self.last_congestion,
        }
    }

    /// Rebuild a planner from a recovered image. The λ-probe sibling is
    /// rebuilt exactly when the image carried one, with the same derived
    /// options the lazy build uses (`block_reduction: false` over the main
    /// engine's switches), so its folded counters — and the question of
    /// whether a future congested epoch triggers the lazy build — continue
    /// bit-identically across the crash.
    pub(crate) fn from_image(img: JointImage) -> JointPlanner {
        let options = img.options;
        assert!(
            options.server_capacity > 0.0,
            "server capacity must be positive"
        );
        JointPlanner {
            fleet: FleetPlanner::from_image(img.fleet, options.fleet),
            probe: img.probe.map(|p| {
                FleetPlanner::from_image(
                    p,
                    FleetOptions {
                        block_reduction: false,
                        ..options.fleet
                    },
                )
            }),
            options,
            price_iterations: img.price_iterations,
            joint_resolves: img.joint_resolves,
            last_makespan: img.last_makespan,
            last_congestion: img.last_congestion,
        }
    }
}

/// Plain-data image of a [`JointPlanner`] for the daemon's crash
/// snapshots: both engines' [`FleetImage`]s (the probe's only when the
/// lazy build has happened), the joint-level counters, and the last
/// epoch's observables. Options ride along so recovery is self-contained.
/// The byte codec lives in `daemon::snapshot`.
pub(crate) struct JointImage {
    pub(crate) options: JointOptions,
    pub(crate) fleet: FleetImage,
    pub(crate) probe: Option<FleetImage>,
    pub(crate) price_iterations: u64,
    pub(crate) joint_resolves: u64,
    pub(crate) last_makespan: Option<f64>,
    pub(crate) last_congestion: Option<f64>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{count_lower_sets, Dag};
    use crate::models;
    use crate::partition::baselines::brute_force_partition;
    use crate::partition::PartitionPlanner;
    use crate::profiles::{CostGraph, DeviceProfile, TrainCfg};
    use crate::util::prop::{
        assert_cut_cost_equal, assert_fleet_cost_equal, for_all, joint_fading_walk,
        random_layer_dag, random_link, seeded_case, zoo_matrix,
    };

    fn costs_for(model: &str, device: &DeviceProfile) -> CostGraph {
        let m = models::by_name(model).unwrap();
        CostGraph::build(&m, device, &DeviceProfile::rtx_a6000(), &TrainCfg::default())
    }

    fn spec_for(model: &str, devices: usize) -> FleetSpec {
        let m = models::by_name(model).unwrap();
        FleetSpec::from_fleet(&DeviceProfile::fleet_of(devices), |d| {
            CostGraph::build(&m, d, &DeviceProfile::rtx_a6000(), &TrainCfg::default())
        })
    }

    /// Share-allocation arithmetic on hand-solvable instances.
    #[test]
    fn makespan_for_cuts_equalizes_the_shared_server() {
        // Two pure-server sessions (A = 0, W = 1) on capacity 1: half a
        // share each, both finish at T = 2.
        let t = fleet_makespan_for_cuts(&[(0.0, 1.0, 1), (0.0, 1.0, 1)], 1.0);
        assert!((t - 2.0).abs() < 1e-9, "t = {t}");
        // Session multiplicity folds in: 4 sessions of (0, 1) -> T = 4.
        let t = fleet_makespan_for_cuts(&[(0.0, 1.0, 4)], 1.0);
        assert!((t - 4.0).abs() < 1e-9, "t = {t}");
        // Capacity 2 gives both sessions a dedicated share: T = 1.
        let t = fleet_makespan_for_cuts(&[(0.0, 1.0, 2)], 2.0);
        assert!((t - 1.0).abs() < 1e-12, "t = {t}");
        // Zero-server-work sessions need no share and only bound via A.
        let t = fleet_makespan_for_cuts(&[(3.0, 0.0, 5), (0.0, 1.0, 1)], 1.0);
        assert!((t - 3.0).abs() < 1e-9, "t = {t}");
        // Asymmetric closed form: 1/(T-1) + 2/T = 1 -> T = 2 + sqrt(2).
        let t = fleet_makespan_for_cuts(&[(1.0, 1.0, 1), (0.0, 2.0, 1)], 1.0);
        assert!((t - (2.0 + 2f64.sqrt())).abs() < 1e-9, "t = {t}");
    }

    /// The oracle on a single device with abundant capacity is the plain
    /// brute-force optimum of Eq. (7).
    #[test]
    fn oracle_degenerates_to_brute_force_on_one_device() {
        let c = costs_for("block-residual", &DeviceProfile::jetson_tx2());
        let p = Problem::new(&c, Link::symmetric(1e6));
        let bf = brute_force_partition(&p);
        let oracle = oracle_fleet_makespan(&[p.clone()], 1e9);
        assert!(
            (oracle - bf.delay).abs() <= 1e-9 * (1.0 + bf.delay),
            "oracle {oracle} vs brute force {bf}",
            bf = bf.delay
        );
    }

    /// The headline pin: on every exhaustively enumerable small fleet —
    /// 2-3 devices over the small zoo models, mixed tiers, random links,
    /// a ladder of capacities from heavily congested to nearly dedicated —
    /// `JointPlanner`'s fleet makespan equals the brute-force oracle's
    /// optimum over all cut combinations, within `CUT_COST_ULPS`. Swept
    /// over the seeded `zoo_matrix` lanes (cells of large models skip —
    /// their lower-set counts are not enumerable).
    #[test]
    fn joint_matches_brute_force_oracle_on_small_fleets() {
        zoo_matrix("joint-vs-oracle", |case, rng| {
            // Cheap size gate first: counting lower sets *enumerates* them,
            // so it must never run on the big branchy models (their counts
            // are astronomical). The small zoo — the chains and the three
            // single-block nets — all sit under this vertex bound.
            if case.costs.len() > 48 {
                return;
            }
            let per_device = count_lower_sets(&case.costs.dag);
            if per_device > 512 {
                return; // not exhaustively enumerable at fleet scale
            }
            // 3 devices when the combination count stays cheap, else 2.
            let devices = if per_device.saturating_pow(3) <= 50_000 { 3 } else { 2 };
            let m = models::by_name(case.model).unwrap();
            let others = [
                DeviceProfile::jetson_tx1(),
                DeviceProfile::jetson_agx_orin(),
            ];
            let mut tiers = vec![("cell", case.costs.clone())];
            for (i, d) in others.iter().take(devices - 1).enumerate() {
                let name: &'static str = ["other-a", "other-b"][i];
                tiers.push((
                    name,
                    CostGraph::build(&m, d, &DeviceProfile::rtx_a6000(), &TrainCfg::default()),
                ));
            }
            let tier_of_device = (0..devices).collect::<Vec<_>>();
            for capacity in [0.5, 1.0, 1.8] {
                let spec = FleetSpec::new(tiers.clone(), tier_of_device.clone());
                let mut joint = JointPlanner::with_capacity(spec, capacity);
                for epoch in 0..2 {
                    let links: Vec<Link> = (0..devices).map(|_| random_link(rng)).collect();
                    let requests: Vec<PlanRequest> = (0..devices)
                        .map(|d| PlanRequest {
                            device: d,
                            tier: d,
                            link: links[d],
                        })
                        .collect();
                    let decisions = joint.plan(&requests);
                    let makespan = joint.makespan().expect("non-empty epoch");
                    let problems: Vec<Problem> = (0..devices)
                        .map(|d| Problem::new(joint.spec().tier_costs(d), links[d]))
                        .collect();
                    let oracle = oracle_fleet_makespan(&problems, capacity);
                    assert_fleet_cost_equal(
                        makespan,
                        oracle,
                        &format!(
                            "{}/{} devices={devices} capacity={capacity} epoch={epoch}",
                            case.model, case.tier
                        ),
                    );
                    // Every decision is feasible and within the makespan.
                    for (d, dec) in decisions.iter().enumerate() {
                        assert!(problems[d].is_feasible(&dec.partition.device_set));
                        assert!(
                            dec.partition.delay <= makespan * (1.0 + 1e-9),
                            "device {d} delay {} above makespan {makespan}",
                            dec.partition.delay
                        );
                    }
                }
            }
        });
    }

    /// The oracle pin again, on random layer DAGs with strictly positive
    /// random costs (two compute tiers, three devices) — structure the zoo
    /// does not cover.
    #[test]
    fn joint_matches_oracle_on_random_dags() {
        for_all("joint-oracle-random-dags", 12, |rng| {
            let n = 3 + rng.index(5);
            let edges = random_layer_dag(rng, n, 0.25);
            let mut dag = Dag::new();
            for i in 0..n {
                dag.add_node(format!("v{i}"));
            }
            for (u, v) in edges {
                dag.add_edge(u, v, 0.0);
            }
            if count_lower_sets(&dag).saturating_pow(3) > 50_000 {
                return;
            }
            let xi_s: Vec<f64> = (0..n).map(|_| rng.range(1e-3, 5e-2)).collect();
            let base = CostGraph {
                xi_d: xi_s.iter().map(|&s| s * rng.range(1.5, 20.0)).collect(),
                xi_s,
                act_bytes: (0..n).map(|_| rng.range(1e3, 1e6)).collect(),
                param_bytes: (0..n).map(|_| rng.range(1.0, 1e5)).collect(),
                n_loc: rng.range(1.0, 8.0).round(),
                dag,
            };
            let mut faster = base.clone();
            faster.xi_d = base.xi_d.iter().map(|&x| x * 0.35).collect();
            let spec = FleetSpec::new(vec![("slow", base), ("fast", faster)], vec![0, 1, 0]);
            let capacity = rng.range(0.3, 2.5);
            let mut joint = JointPlanner::with_capacity(spec, capacity);
            let links: Vec<Link> = (0..3)
                .map(|_| Link {
                    up_bps: rng.range(1e4, 1e8),
                    down_bps: rng.range(1e4, 1e8),
                })
                .collect();
            let requests: Vec<PlanRequest> = (0..3)
                .map(|d| PlanRequest {
                    device: d,
                    tier: joint.spec().tier_of(d),
                    link: links[d],
                })
                .collect();
            let _ = joint.plan(&requests);
            let problems: Vec<Problem> = (0..3)
                .map(|d| Problem::new(joint.spec().tier_costs(joint.spec().tier_of(d)), links[d]))
                .collect();
            let oracle = oracle_fleet_makespan(&problems, capacity);
            assert_fleet_cost_equal(
                joint.makespan().unwrap(),
                oracle,
                &format!("random dag n={n} capacity={capacity}"),
            );
        });
    }

    /// The ∞-capacity degenerate pin: decisions AND the full `FleetStats`
    /// struct (price counters included) are bit-identical to a plain
    /// `FleetPlanner` fed the same epochs.
    #[test]
    fn infinite_capacity_is_bit_identical_to_fleet_planner() {
        for model in ["googlenet", "block-residual", "lenet5"] {
            let mut fleet = FleetPlanner::new(spec_for(model, 6));
            let mut joint = JointPlanner::new(spec_for(model, 6), JointOptions::default());
            for epoch in 0..4u64 {
                let reqs = fleet.spec().requests(|t| Link {
                    up_bps: 2e5 * (1.0 + t as f64) * (1.0 + 0.31 * epoch as f64),
                    down_bps: 7e5 * (1.0 + t as f64) * (1.0 + 0.17 * epoch as f64),
                });
                let want = fleet.plan(&reqs);
                let got = joint.plan(&reqs);
                assert_eq!(want.len(), got.len());
                for (w, g) in want.iter().zip(&got) {
                    assert_eq!(g.device, w.device, "{model}");
                    assert_eq!(g.tier, w.tier, "{model}");
                    assert_eq!(g.cut_layer, w.cut_layer, "{model}");
                    assert_eq!(g.partition.device_set, w.partition.device_set, "{model}");
                    assert_eq!(
                        g.partition.delay.to_bits(),
                        w.partition.delay.to_bits(),
                        "{model}"
                    );
                    assert_eq!(g.stats.refreshed, w.stats.refreshed, "{model}");
                }
            }
            assert_eq!(joint.stats(), fleet.stats(), "{model}: counters diverged");
            assert_eq!(joint.stats().price_iterations, 0, "{model}");
            assert_eq!(joint.stats().joint_resolves, 0, "{model}");
            assert!(joint.congestion().is_none(), "{model}");
        }
    }

    /// The single-device degenerate pin, across the whole zoo matrix: a
    /// one-device fleet with a full share available (capacity 1) decides
    /// exactly like the dedicated per-device engine (`PartitionPlanner`,
    /// cost-equal — the joint facade defaults to the reduced engine), and
    /// its makespan is that decision's Eq. (7) delay.
    #[test]
    fn single_device_fleet_matches_partition_planner() {
        zoo_matrix("joint-single-device", |case, rng| {
            let mut joint =
                JointPlanner::with_capacity(FleetSpec::single(case.costs.clone()), 1.0);
            let mut reference = PartitionPlanner::new(&case.costs);
            for _ in 0..4 {
                let link = random_link(rng);
                let d = joint
                    .plan(&[PlanRequest {
                        device: 0,
                        tier: 0,
                        link,
                    }])
                    .pop()
                    .unwrap();
                let want = reference.partition(link);
                let problem = Problem::new(&case.costs, link);
                assert_cut_cost_equal(&problem, &d.partition, &want);
                assert_fleet_cost_equal(
                    joint.makespan().unwrap(),
                    d.partition.delay,
                    &format!("{}/{}", case.model, case.tier),
                );
                assert!(joint.congestion().is_none(), "capacity 1 covers 1 device");
            }
            assert_eq!(joint.stats().price_iterations, 0);
        });
    }

    /// The seeded σ/capacity fuzz lane (runs under the fixed-seed CI
    /// equivalence lanes): a joint fading walk drifts every tier's link
    /// and the shared capacity together; every warm joint re-solve must be
    /// cost-equal to a cold planner solving the same epoch from scratch,
    /// and the warm planner's counters must prove the probes reused flow —
    /// every flow solve after each tier's first is incremental.
    #[test]
    fn joint_walk_warm_cold_equivalence() {
        // seeded_case (not a raw seed XOR) so a failure echoes both the
        // base seed and the derived case seed for replay (PR 10's
        // seed-echo parity fix).
        seeded_case("joint-walk-warm-cold", 0x101A7, |rng| {
            let num_devices = 4;
            let mut warm = JointPlanner::with_capacity(spec_for("googlenet", num_devices), 1.2);
            let num_tiers = warm.spec().num_tiers();
            assert_eq!(num_tiers, 4);
            let start = Link {
                up_bps: 3e5,
                down_bps: 9e5,
            };
            let walk = joint_fading_walk(rng, start, 1.2, 16, 0.88, 1.13);
            let mut congested_steps = 0;
            for (step, &(link, capacity)) in walk.iter().enumerate() {
                let reqs: Vec<PlanRequest> = (0..num_devices)
                    .map(|d| {
                        let t = warm.spec().tier_of(d);
                        PlanRequest {
                            device: d,
                            tier: t,
                            link: Link {
                                up_bps: link.up_bps * (1.0 + 0.4 * t as f64),
                                down_bps: link.down_bps * (1.0 + 0.25 * t as f64),
                            },
                        }
                    })
                    .collect();
                warm.set_server_capacity(capacity);
                let warm_decisions = warm.plan(&reqs);
                let warm_makespan = warm.makespan().unwrap();

                let mut cold =
                    JointPlanner::with_capacity(spec_for("googlenet", num_devices), capacity);
                let _ = cold.plan(&reqs);
                assert_fleet_cost_equal(
                    warm_makespan,
                    cold.makespan().unwrap(),
                    &format!("walk step {step} capacity {capacity}"),
                );
                for (r, d) in reqs.iter().zip(&warm_decisions) {
                    let problem = Problem::new(warm.spec().tier_costs(r.tier), r.link);
                    assert!(problem.is_feasible(&d.partition.device_set), "step {step}");
                    assert!(
                        d.partition.delay <= warm_makespan * (1.0 + 1e-9),
                        "step {step}: device delay above the fleet makespan"
                    );
                }
                if warm.congestion().is_some() {
                    congested_steps += 1;
                }
            }
            let s = warm.stats();
            assert!(congested_steps > 0, "walk never congested the server");
            assert!(s.price_iterations > 0, "no makespan bisection ran");
            assert!(s.joint_resolves > 0, "no price probe ran");
            // Cold solves are exactly the per-(engine, tier) firsts: the λ=1
            // engine's four tiers plus at most four firsts of the lazily built
            // unreduced λ-probe engine. Everything else — later epochs' λ=1
            // solves and every probe — must reuse the previous flow.
            let cold = s.flow_solves - s.incremental_solves;
            assert!(
                cold > num_tiers as u64 && cold <= 2 * num_tiers as u64,
                "expected one cold solve per (engine, tier) first, got {cold} \
                 cold of {} total",
                s.flow_solves
            );
            assert!(s.repair_pushes > 0, "capacity-shrinking probes must repair");
        });
    }

    /// Monotonicity across the capacity ladder, zoo models: shrinking the
    /// shared capacity never lowers the optimal fleet makespan, never
    /// shrinks any device's layer set, and never grows any device's server
    /// work — congestion only ever pushes layers device-ward. The engine
    /// runs unreduced so every reported cut (dedicated λ=1 and priced
    /// alike) is a minimal min cut of one solver family — the GGT nesting
    /// that grounds the cut-direction half of the property; reduced
    /// engines may pick differently tie-broken *co-optimal* cuts at the
    /// uncongested↔congested seam (the cost-side invariants are engine-
    /// independent and stay pinned by the oracle + equivalence suites).
    #[test]
    fn shrinking_capacity_is_monotone_on_zoo_models() {
        for model in ["googlenet", "block-residual", "lenet5"] {
            let link_of = |t: usize| Link {
                up_bps: 4e5 * (1.0 + 0.6 * t as f64),
                down_bps: 1.2e6 * (1.0 + 0.4 * t as f64),
            };
            let mut prev_makespan = 0.0f64;
            let mut prev_layers: Option<Vec<usize>> = None;
            let mut prev_server_work: Option<Vec<f64>> = None;
            for capacity in [f64::INFINITY, 3.0, 2.0, 1.2, 0.7, 0.35] {
                let options = JointOptions {
                    server_capacity: capacity,
                    fleet: FleetOptions {
                        block_reduction: false,
                        ..FleetOptions::default()
                    },
                };
                let mut joint = JointPlanner::new(spec_for(model, 6), options);
                let reqs = joint.spec().requests(link_of);
                let decisions = joint.plan(&reqs);
                let makespan = joint.makespan().unwrap();
                assert!(
                    makespan >= prev_makespan * (1.0 - 1e-9),
                    "{model}: makespan fell from {prev_makespan} to {makespan} \
                     when capacity shrank to {capacity}"
                );
                prev_makespan = makespan;
                let layers: Vec<usize> = decisions
                    .iter()
                    .map(|d| d.partition.device_layers())
                    .collect();
                let server_work: Vec<f64> = reqs
                    .iter()
                    .zip(&decisions)
                    .map(|(r, d)| {
                        let p = Problem::new(joint.spec().tier_costs(r.tier), r.link);
                        p.delay_terms(&d.partition.device_set).1
                    })
                    .collect();
                if let (Some(pl), Some(pw)) = (&prev_layers, &prev_server_work) {
                    for d in 0..decisions.len() {
                        // Two cuts with zero server work are interchangeable
                        // for the shared server (only zero-cost layers can
                        // differ between them), so the layer-count direction
                        // is only meaningful outside that tie.
                        if !(server_work[d] <= 0.0 && pw[d] <= 0.0) {
                            assert!(
                                layers[d] >= pl[d],
                                "{model} device {d}: cut moved server-ward \
                                 ({} -> {} device layers) as capacity shrank to {capacity}",
                                pl[d],
                                layers[d]
                            );
                        }
                        assert!(
                            server_work[d] <= pw[d] * (1.0 + 1e-9) + 1e-12,
                            "{model} device {d}: server work grew under congestion"
                        );
                    }
                }
                prev_layers = Some(layers);
                prev_server_work = Some(server_work);
            }
        }
    }

    /// Monotonicity on random DAGs with strictly positive random costs
    /// (no co-optimal ties to hide behind).
    #[test]
    fn shrinking_capacity_is_monotone_on_random_dags() {
        for_all("joint-capacity-monotone", 16, |rng| {
            let n = 4 + rng.index(14);
            let edges = random_layer_dag(rng, n, 0.3);
            let mut dag = Dag::new();
            for i in 0..n {
                dag.add_node(format!("v{i}"));
            }
            for (u, v) in edges {
                dag.add_edge(u, v, 0.0);
            }
            let xi_s: Vec<f64> = (0..n).map(|_| rng.range(1e-4, 5e-2)).collect();
            let costs = CostGraph {
                xi_d: xi_s.iter().map(|&s| s * rng.range(1.5, 20.0)).collect(),
                xi_s,
                act_bytes: (0..n).map(|_| rng.range(1e3, 1e7)).collect(),
                param_bytes: (0..n).map(|_| rng.range(1.0, 1e6)).collect(),
                n_loc: rng.range(1.0, 10.0).round(),
                dag,
            };
            let links: Vec<Link> = (0..4)
                .map(|_| Link {
                    up_bps: rng.range(1e4, 1e8),
                    down_bps: rng.range(1e4, 1e8),
                })
                .collect();
            let mut prev_makespan = 0.0f64;
            let mut prev_layers: Option<Vec<usize>> = None;
            for capacity in [4.0, 1.5, 0.8, 0.3] {
                let spec = FleetSpec::new(
                    vec![("only", costs.clone())],
                    vec![0; 4],
                );
                // Unreduced engine for the same single-solver-family
                // nesting reason as the zoo ladder above.
                let options = JointOptions {
                    server_capacity: capacity,
                    fleet: FleetOptions {
                        block_reduction: false,
                        ..FleetOptions::default()
                    },
                };
                let mut joint = JointPlanner::new(spec, options);
                let reqs: Vec<PlanRequest> = (0..4)
                    .map(|d| PlanRequest {
                        device: d,
                        tier: 0,
                        link: links[d],
                    })
                    .collect();
                let decisions = joint.plan(&reqs);
                let makespan = joint.makespan().unwrap();
                assert!(makespan >= prev_makespan * (1.0 - 1e-9));
                prev_makespan = makespan;
                let layers: Vec<usize> = decisions
                    .iter()
                    .map(|d| d.partition.device_layers())
                    .collect();
                if let Some(pl) = &prev_layers {
                    for d in 0..4 {
                        assert!(
                            layers[d] >= pl[d],
                            "device {d}: cut moved server-ward as capacity shrank to {capacity}"
                        );
                    }
                }
                prev_layers = Some(layers);
            }
        });
    }

    /// The parallel-sweep determinism pin, extended to joint plans: the
    /// joint solve canonicalizes its group order, and every price probe is
    /// group-local, so a batch and its reversal produce bit-identical
    /// per-device decisions and makespans — under the serial sweep and
    /// (since the wrapped λ=1 pass is pinned feature-on ≡ feature-off)
    /// under `--features parallel`, where CI runs this test again.
    #[test]
    fn joint_batched_plan_is_bit_identical_across_request_orders() {
        for capacity in [1.3, 0.6] {
            let mut a = JointPlanner::with_capacity(spec_for("googlenet", 8), capacity);
            let mut b = JointPlanner::with_capacity(spec_for("googlenet", 8), capacity);
            for epoch in 0..3u64 {
                let reqs = a.spec().requests(|t| Link {
                    up_bps: 2e5 * (1.0 + t as f64) * (1.0 + 0.41 * epoch as f64),
                    down_bps: 8e5 * (1.0 + t as f64) * (1.0 + 0.23 * epoch as f64),
                });
                let mut reversed = reqs.clone();
                reversed.reverse();
                let da = a.plan(&reqs);
                let db = b.plan(&reversed);
                assert_eq!(
                    a.makespan().unwrap().to_bits(),
                    b.makespan().unwrap().to_bits(),
                    "epoch {epoch}: makespan depends on request order"
                );
                for (r, d) in reqs.iter().zip(&da) {
                    let other = db
                        .iter()
                        .find(|x| x.device == r.device)
                        .expect("same devices");
                    assert_eq!(d.partition.device_set, other.partition.device_set);
                    assert_eq!(
                        d.partition.delay.to_bits(),
                        other.partition.delay.to_bits()
                    );
                    assert_eq!(d.cut_layer, other.cut_layer);
                }
            }
        }
    }

    /// Duplicate (tier, link) requests in a joint batch are bit-exact
    /// copies of their group's decision, with only the first marked as
    /// freshly solved — mirrors the fleet facade's cache contract.
    #[test]
    fn duplicate_requests_share_their_group_decision() {
        let mut joint = JointPlanner::with_capacity(spec_for("googlenet", 4), 0.8);
        let link = Link::symmetric(5e5);
        let reqs: Vec<PlanRequest> = (0..4)
            .map(|d| PlanRequest {
                device: d,
                tier: 0,
                link,
            })
            .collect();
        let decisions = joint.plan(&reqs);
        assert!(decisions[0].stats.refreshed);
        for d in &decisions[1..] {
            assert!(!d.stats.refreshed, "duplicate served from the group");
            assert_eq!(d.partition.device_set, decisions[0].partition.device_set);
            assert_eq!(
                d.partition.delay.to_bits(),
                decisions[0].partition.delay.to_bits()
            );
        }
    }

    #[test]
    fn empty_batch_is_a_noop_epoch() {
        let mut joint = JointPlanner::with_capacity(spec_for("block-residual", 4), 2.0);
        assert!(joint.plan(&[]).is_empty());
        assert!(joint.makespan().is_none());
        assert_eq!(joint.stats().joint_resolves, 0);
    }

    #[test]
    #[should_panic(expected = "server capacity must be positive")]
    fn rejects_non_positive_capacity() {
        let _ = JointPlanner::with_capacity(spec_for("lenet5", 2), 0.0);
    }
}
